"""Per-arch smoke tests (assignment: reduced config, one forward/train step
on CPU, output shapes + no NaNs) plus decode-vs-prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config, \
    get_smoke_config
from repro.models import (forward, head_weight, init_cache, init_params,
                          make_prefill_step, make_serve_step, make_train_step)
from repro.optim.adamw import AdamW

B, S = 2, 64


def _batch(cfg, key):
    if cfg.frontend == "audio_stub":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "labels": jnp.ones((B, S), jnp.int32),
                "mask": jnp.ones((B, S), bool)}
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    h, _, aux = forward(params, cfg, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(h.astype(jnp.float32)).any())
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    p2, o2, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.is_encoder:
        pytest.skip("encoder-only")
    key = jax.random.key(1)
    params = init_params(cfg, key)
    cache = init_cache(cfg, B, 32)
    step = jax.jit(make_serve_step(cfg))
    logits, cache2 = step(params, cache, jnp.ones((B, 1), jnp.int32),
                          jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-72b", "deepseek-v2-lite-16b",
                                  "rwkv6-7b", "zamba2-2.7b",
                                  "h2o-danube-3-4b", "command-r-plus-104b"])
def test_decode_matches_prefill(arch):
    """Feeding T tokens one-by-one through serve_step must reproduce the
    prefill logits at the last position — validates every cache path
    (GQA kv, MLA latent, SWA ring buffer, rwkv/mamba recurrent states).

    MoE capacity is raised so neither path drops tokens (GShard-capacity
    dropping is a training-time tradeoff and differs between batch sizes
    by design)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    t_len = 32
    key = jax.random.key(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, t_len), 0, cfg.vocab_size)

    prefill = jax.jit(make_prefill_step(cfg))
    ref_logits = prefill(params, {"tokens": toks})      # (1, T, V)

    serve = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, 1, t_len)
    outs = []
    for t in range(t_len):
        logits, cache = serve(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mla_absorb_decode_matches_naive():
    """The absorbed MLA decode (weight-absorption optimization) must be
    numerically equivalent to decompress-then-attend."""
    cfg = dataclasses.replace(get_smoke_config("deepseek-v2-lite-16b"),
                              dtype="float32")
    key = jax.random.key(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    naive = jax.jit(make_serve_step(cfg, absorb=False))
    absorb = jax.jit(make_serve_step(cfg, absorb=True))
    c1 = init_cache(cfg, 2, 16)
    c2 = init_cache(cfg, 2, 16)
    for t in range(8):
        l1, c1 = naive(params, c1, toks[:, t:t + 1], jnp.int32(t))
        l2, c2 = absorb(params, c2, toks[:, t:t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_unrolled_forward_matches_scan():
    """unroll=True (dry-run path) is numerically identical to lax.scan."""
    cfg = dataclasses.replace(get_smoke_config("llama4-maverick-400b-a17b"),
                              dtype="float32")
    key = jax.random.key(4)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    h1, _, a1 = forward(params, cfg, batch, unroll=False)
    h2, _, a2 = forward(params, cfg, batch, unroll=True)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(a1) - float(a2)) < 1e-5


def test_full_config_param_counts_match_published_sizes():
    """Analytic parameter counts of the FULL configs vs. published sizes."""
    expected = {
        "qwen2-72b": 72e9, "deepseek-coder-33b": 33e9,
        "h2o-danube-3-4b": 4e9, "command-r-plus-104b": 104e9,
        "chameleon-34b": 34e9, "deepseek-v2-lite-16b": 16e9,
        "llama4-maverick-400b-a17b": 400e9, "rwkv6-7b": 7e9,
        "zamba2-2.7b": 2.7e9, "hubert-xlarge": 1e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).n_params()
        assert 0.7 * want < got < 1.45 * want, (arch, got, want)


def test_cell_skip_rules():
    """The 40-cell matrix skip rules (DESIGN.md §4)."""
    runnable = {(a, s): cell_is_runnable(get_config(a), SHAPES[s])[0]
                for a in ARCH_NAMES for s in SHAPES}
    assert sum(runnable.values()) == 32          # 40 - 2 encoder - 6 long_500k
    assert not runnable[("hubert-xlarge", "decode_32k")]
    assert not runnable[("hubert-xlarge", "long_500k")]
    assert not runnable[("qwen2-72b", "long_500k")]
    assert runnable[("rwkv6-7b", "long_500k")]
    assert runnable[("zamba2-2.7b", "long_500k")]
    assert runnable[("h2o-danube-3-4b", "long_500k")]
