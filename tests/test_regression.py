"""Property tests for the paper's §III regression (gradient+Hessian recovery)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regression as reg

settings = dict(max_examples=20, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _random_quadratic(rng, n):
    A = rng.normal(size=(n, n))
    H = (A + A.T) / 2
    g = rng.normal(size=n)
    c = float(rng.normal())
    return c, g, H


@hypothesis.given(n=st.integers(2, 8), seed=st.integers(0, 10_000))
@hypothesis.settings(**settings)
def test_exact_recovery_on_quadratics(n, seed):
    """f(x'+δ)=c+gδ+½δHδ is recovered exactly from ≥ n_columns samples."""
    rng = np.random.default_rng(seed)
    c, g, H = _random_quadratic(rng, n)
    m = reg.n_columns(n) + 10
    deltas = jnp.asarray(rng.uniform(-1, 1, (m, n)), jnp.float32)
    d = np.asarray(deltas, np.float64)
    ys = jnp.asarray(c + d @ g + 0.5 * np.einsum("mi,ij,mj->m", d, H, d),
                     jnp.float32)
    c_hat, g_hat, H_hat = reg.fit_quadratic(deltas, ys)
    np.testing.assert_allclose(np.asarray(g_hat), g, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(H_hat), H, rtol=5e-3, atol=5e-3)
    assert abs(float(c_hat) - c) < 1e-2


@hypothesis.given(n=st.integers(2, 10))
@hypothesis.settings(**settings)
def test_column_count(n):
    deltas = jnp.zeros((3, n))
    x = reg.design_matrix(deltas)
    assert x.shape == (3, reg.n_columns(n))
    # paper's bound: n_columns <= n² + n (+1)
    assert reg.n_columns(n) <= n * n + n + 1


@hypothesis.given(n=st.integers(2, 8), seed=st.integers(0, 10_000))
@hypothesis.settings(**settings)
def test_newton_direction_is_descent(n, seed):
    """-(H+λI)⁻¹g must have negative inner product with g (damped), even for
    indefinite H."""
    rng = np.random.default_rng(seed)
    _, g, H = _random_quadratic(rng, n)
    d = reg.newton_direction(jnp.asarray(g, jnp.float32),
                             jnp.asarray(H, jnp.float32), damping=1e-3)
    assert float(jnp.dot(d, jnp.asarray(g, jnp.float32))) < 0.0


def test_newton_direction_matches_inverse_on_pd():
    rng = np.random.default_rng(0)
    n = 5
    A = rng.normal(size=(n, n))
    H = A @ A.T + n * np.eye(n)          # PD
    g = rng.normal(size=n)
    d = np.asarray(reg.newton_direction(jnp.asarray(g, jnp.float32),
                                        jnp.asarray(H, jnp.float32), 1e-9))
    np.testing.assert_allclose(d, -np.linalg.solve(H, g), rtol=1e-3, atol=1e-4)


def test_weights_drop_samples():
    """Weight-0 samples (failed evaluations) must not influence the fit."""
    rng = np.random.default_rng(3)
    n = 4
    c, g, H = _random_quadratic(rng, n)
    m = reg.n_columns(n) + 20
    d = rng.uniform(-1, 1, (m, n))
    ys = c + d @ g + 0.5 * np.einsum("mi,ij,mj->m", d, H, d)
    ys_bad = ys.copy()
    ys_bad[:5] = 1e6                                  # corrupted results
    w = np.ones(m); w[:5] = 0.0
    _, g_hat, H_hat = reg.fit_quadratic(jnp.asarray(d, jnp.float32),
                                        jnp.asarray(ys_bad, jnp.float32),
                                        jnp.asarray(w, jnp.float32))
    np.testing.assert_allclose(np.asarray(g_hat), g, rtol=5e-3, atol=5e-3)


def test_mad_outlier_weights_flag_corruption():
    rng = np.random.default_rng(4)
    ys = rng.normal(0, 1, 200)
    ys[7] = 1e5
    ys[100] = np.nan
    w = np.asarray(reg.mad_outlier_weights(jnp.asarray(ys, jnp.float32)))
    assert w[7] == 0.0 and w[100] == 0.0
    assert w.sum() >= 190
