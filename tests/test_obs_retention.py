"""Flight-recorder tests (DESIGN.md §14): durable retention, workunit
lifecycle tracing, and the windowed drift defense.

The §14 contract under test: the post-mortem plane writes everything it
sees into the §10 store family without ever entering the recovery
contract — snapshots/spans/anomalies are retained durably (epoch-marked
across restarts, torn-tail tolerant, size-bounded by compaction), trace
sampling is a pure function of (seed, search, wu) so observed runs stay
bit-identical, the stall detector's kills flow through the director seam
into the recorded anomaly schedule and replay bit-identically, and the
``subscribe_stats`` reply reports ring gaps explicitly (optionally
backfilled from the store) instead of silently skipping seqs.
"""
import io
import json
import threading

import numpy as np
import pytest

from repro.core.anm import AnmConfig
from repro.core.engine import identical_trajectories
from repro.core.grid import GridConfig
from repro.core.orchestrator import (FleetScheduler, SearchDirector,
                                     multi_start_specs)
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.launch.obs_dashboard import watch
from repro.launch.obs_postmortem import reconstruct
from repro.obs import (OBS_STORE_DB, OBS_STORE_NAME, STREAM_VERSION,
                       BackgroundSubscriber, MetricsHub, RetentionSink,
                       SnapshotStore, SqliteSnapshotStore, WorkUnitTracer,
                       obs_store_path, open_snapshot_store, wu_sampled)
from repro.server import protocol
from repro.server.sim import ServerSubstrate, smoke_problem

pytestmark = pytest.mark.obs


# -- shared small workload -----------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    return smoke_problem(n_stars=120, n_hosts=40, m=10, iterations=2)


@pytest.fixture(scope="module")
def backend(problem):
    _, _, f_batch = problem
    return InProcessEvalBackend(f_batch)


@pytest.fixture(scope="module")
def baseline(problem, backend):
    spec, fleet, _ = problem
    return ServerSubstrate(spec, fleet, backend).run()


def _same(a, b):
    ea, eb = a.engines[0], b.engines[0]
    return identical_trajectories(ea, eb) and ea.stats == eb.stats


# -- the snapshot store family -------------------------------------------------

class TestSnapshotStore:
    def test_roundtrip_epochs_and_read_only(self, tmp_path):
        p = str(tmp_path / "obs.jsonl")
        s1 = SnapshotStore(p)
        assert s1.epoch == 1
        s1.append("snap", {"seq": 0, "x": 1}, seq=0, now=10.0)
        s1.append("span", {"wu": 7}, now=11.0)
        s1.close()
        # a restored server reopens the SAME file under a fresh epoch
        s2 = SnapshotStore(p)
        assert s2.epoch == 2
        s2.append("snap", {"seq": 5}, seq=5, now=20.0)
        s2.close()
        # the post-mortem CLI opens read-only: NO new epoch marker
        ro = open_snapshot_store(p, read_only=True)
        assert ro.epoch == 2
        assert ro.epochs() == [1, 2]
        assert len(ro.records("snap", epoch=1)) == 1
        assert len(ro.records("span", epoch=1)) == 1
        assert [r["doc"]["seq"] for r in ro.records("snap", epoch=2)] == [5]
        assert ro.snapshots() == [{"seq": 0, "x": 1}, {"seq": 5}]
        with pytest.raises(RuntimeError, match="read-only"):
            ro.append("snap", {})
        # and because it never wrote, a THIRD append-open gets epoch 3
        assert SnapshotStore(p).epoch == 3

    def test_torn_tail_truncated_on_reopen(self, tmp_path):
        p = str(tmp_path / "obs.jsonl")
        s = SnapshotStore(p, flush_every=1)
        s.append("snap", {"seq": 0}, seq=0)
        s.append("snap", {"seq": 1}, seq=1)
        s.close()
        with open(p, "a") as f:
            f.write('{"t": "snap", "epoch": 1, "se')     # SIGKILL mid-write
        s2 = SnapshotStore(p)
        assert [r["doc"]["seq"] for r in s2.records("snap")] == [0, 1]
        s2.append("snap", {"seq": 2}, seq=2)
        s2.close()
        # the torn fragment is gone from disk, not just skipped in memory
        lines = open(p).read().splitlines()
        assert all(json.loads(ln) for ln in lines)

    def test_compaction_bounds_records_and_keeps_epoch_markers(self,
                                                               tmp_path):
        p = str(tmp_path / "obs.jsonl")
        s = SnapshotStore(p, max_records=20, flush_every=1)
        for i in range(30):               # > 1.25 * 20 triggers compaction
            s.append("snap", {"seq": i}, seq=i, now=float(i))
        assert len(s) <= 25
        kept = [r["doc"]["seq"] for r in s.records("snap")]
        assert kept == sorted(kept)
        assert kept[-1] == 29             # newest window survives
        s.close()
        # survivors still carry a marker for every surviving epoch
        reopened = open_snapshot_store(p, read_only=True)
        assert reopened.epochs() == [1]
        assert [r["doc"]["seq"] for r in reopened.records("snap")] == kept

    def test_max_age_drops_stale_window(self, tmp_path):
        s = SnapshotStore(str(tmp_path / "obs.jsonl"), max_records=10,
                          max_age=5.0)
        for i in range(40):
            s.append("snap", {"seq": i}, seq=i, now=float(i))
        s.compact()                       # age bound applies at compaction
        ages = [float(r["now"]) for r in s.records("snap")]
        assert ages and max(ages) - min(ages) <= 5.0
        assert max(ages) == 39.0          # newest record always survives
        s.close()

    def test_sqlite_store_same_contract(self, tmp_path):
        p = str(tmp_path / "obs.sqlite")
        s1 = open_snapshot_store(p)
        assert isinstance(s1, SqliteSnapshotStore) and s1.epoch == 1
        s1.append("snap", {"seq": 0}, seq=0, now=1.0)
        s1.append("anomaly", {"kind": "k"}, seq=0, now=1.0)
        s1.close()
        s2 = open_snapshot_store(p)
        assert s2.epoch == 2
        s2.append("snap", {"seq": 9}, seq=9, now=2.0)
        s2.close()
        ro = open_snapshot_store(p, read_only=True)
        assert ro.epochs() == [1, 2]
        assert ro.snapshots(epoch=2) == [{"seq": 9}]
        assert ro.summary()["by_type"] == {"snap": 2, "anomaly": 1}
        assert open_snapshot_store(p).epoch == 3

    def test_store_path_convention(self, tmp_path):
        d = str(tmp_path)
        assert obs_store_path(d).endswith(OBS_STORE_NAME)
        assert obs_store_path(d, "sqlite").endswith(OBS_STORE_DB)


# -- deterministic trace sampling + span lifecycle -----------------------------

class TestWorkUnitTracer:
    def test_sampling_is_a_pure_function_of_ids(self):
        picks = [wu_sampled(7, s, w, 0.5)
                 for s in range(4) for w in range(200)]
        assert picks == [wu_sampled(7, s, w, 0.5)
                         for s in range(4) for w in range(200)]
        frac = sum(picks) / len(picks)
        assert 0.35 < frac < 0.65         # keyed hash, roughly the rate
        assert all(wu_sampled(0, 0, w, 1.0) for w in range(10))
        assert not any(wu_sampled(0, 0, w, 0.0) for w in range(10))
        # a different seed picks a different population
        other = [wu_sampled(8, s, w, 0.5)
                 for s in range(4) for w in range(200)]
        assert other != picks

    def test_span_lifecycle_fields(self):
        tr = WorkUnitTracer(sample_rate=1.0)
        tr.on_issue(0, 3, host=5, now=10.0, phase=2, validates=None)
        tr.on_lapse(0, 3, now=40.0)
        tr.on_lapse(0, 3, now=50.0)       # only the FIRST lapse stamps
        tr.on_settle(0, 3, now=55.0, outcome="committed", late=True)
        tr.on_settle(0, 99, now=56.0, outcome="stale")   # unknown: ignored
        (span,) = tr.drain()
        assert span == {"trace_v": 1, "search": 0, "wu": 3, "host": 5,
                        "phase": 2, "validates": None, "issued_at": 10.0,
                        "lapsed_at": 40.0, "reported_at": 55.0,
                        "outcome": "committed", "late": True,
                        "turnaround": 45.0}
        assert tr.drain() == []           # drain pops
        assert tr.summary()["completed"] == 1

    def test_ring_bounds_completed_spans(self):
        tr = WorkUnitTracer(ring=4)
        for w in range(10):
            tr.on_issue(0, w, host=0, now=0.0, phase=0, validates=None)
            tr.on_settle(0, w, now=1.0, outcome="assimilated")
        spans = tr.drain()
        assert [s["wu"] for s in spans] == [6, 7, 8, 9]
        assert tr.ring_dropped == 6


# -- the retention sink --------------------------------------------------------

class TestRetentionSink:
    def test_sink_spills_snapshots_spans_and_anomalies(self, tmp_path):
        hub = MetricsHub(interval=1.0)
        store = SnapshotStore(str(tmp_path / "obs.jsonl"))
        tracer = WorkUnitTracer()
        sink = RetentionSink(hub, store, tracer=tracer)
        tracer.on_issue(0, 0, host=1, now=0.5, phase=0, validates=None)
        tracer.on_settle(0, 0, now=0.9, outcome="committed")
        hub.sample(1.0)                   # sample boundary drains the ring
        tracer.on_issue(0, 1, host=2, now=1.5, phase=0, validates=None)
        hub.sample(2.0)                   # span 1 still open: nothing new
        assert sink.snapshots_stored == 2
        assert sink.spans_stored == 1
        tracer.on_settle(0, 1, now=2.5, outcome="stale")
        sink.drain_remaining()            # end-of-run sweep
        assert sink.spans_stored == 2
        assert store.summary()["by_type"] == {"snap": 2, "span": 2}
        snaps = store.records("snap")
        assert [int(r["seq"]) for r in snaps] == [0, 1]
        store.close()


# -- ring gaps on the wire + retention backfill --------------------------------

class TestDroppedAndBackfill:
    def _server(self, problem, tmp_path, ring=4):
        from repro.server.server import WorkServer
        spec, fleet, _ = problem
        srv = WorkServer([spec], lease_timeout=8.0 * fleet.base_eval_time,
                         idle_retry=fleet.idle_retry)
        hub = MetricsHub(interval=5.0, ring=ring)
        srv.attach_hub(hub)
        store = SnapshotStore(str(tmp_path / "obs.jsonl"))
        sink = RetentionSink(hub, store)
        srv.attach_retention(store)
        return srv, hub, store, sink

    def test_reply_reports_ring_gap_explicitly(self, problem, tmp_path):
        srv, hub, store, _ = self._server(problem, tmp_path)
        for t in range(12):
            hub.sample(float(t))          # ring=4 retains seqs 8..11
        rep = srv.handle(protocol.subscribe_stats(-1))
        assert rep["kind"] == "stats"
        assert [s["seq"] for s in rep["snapshots"]] == [8, 9, 10, 11]
        assert rep["dropped"] == 8
        # a cursor INSIDE the retained window: no gap, no false alarm
        rep2 = srv.handle(protocol.subscribe_stats(9))
        assert [s["seq"] for s in rep2["snapshots"]] == [10, 11]
        assert rep2["dropped"] == 0
        store.close()

    def test_from_store_backfills_the_gap(self, problem, tmp_path):
        srv, hub, store, _ = self._server(problem, tmp_path)
        for t in range(12):
            hub.sample(float(t))
        rep = srv.handle(protocol.subscribe_stats(-1, from_store=True))
        # the store held what the ring dropped: the full history comes
        # back and the residual gap is zero
        assert [s["seq"] for s in rep["snapshots"]] == list(range(12))
        assert rep["dropped"] == 0
        assert rep["cursor"] == 11
        # mid-gap cursor backfills only the missing middle
        rep2 = srv.handle(protocol.subscribe_stats(3, from_store=True))
        assert [s["seq"] for s in rep2["snapshots"]] == list(range(4, 12))
        assert rep2["dropped"] == 0
        store.close()

    def test_status_surfaces_ring_and_interval(self, problem, tmp_path):
        srv, hub, store, _ = self._server(problem, tmp_path, ring=4)
        hub.sample(0.0)
        obs = srv.handle(protocol.status())["obs"]
        assert obs["ring"] == 4
        assert obs["interval"] == 5.0
        assert obs["snapshots"] == 1
        assert obs["retention"]["records"] == 1
        store.close()

    def test_tiny_ring_cursor_contract_via_construction_path(
            self, problem, backend, baseline, tmp_path):
        # satellite (c): ring size + cadence flow through the server
        # construction path; a ring of 2 still yields a gap-accounted,
        # strictly-increasing subscribed stream AND an untouched run
        spec, fleet, _ = problem
        # a tiny throttle (which rides the checkpointed handler) keeps the
        # warm-jit run from finishing before the subscriber's first
        # wall-clock poll lands
        res = ServerSubstrate(spec, fleet, backend, obs=True,
                              subscribe=True, stats_interval=10.0,
                              stats_ring=2, throttle_s=0.002,
                              ckpt_dir=str(tmp_path / "ckpt"),
                              snapshot_every=10_000).run()
        assert _same(baseline, res)
        assert res.obs["ring"] == 2
        sub = res.subscriber
        # the cursor contract survives a 2-slot ring: seqs strictly
        # increasing, every wrap accounted in ``dropped`` — nothing
        # silently vanished mid-stream
        assert sub["stamped_ok"]
        assert sub["snapshots"] > 0
        # every seq up to the last one received was either delivered or
        # counted in a gap: delivered + dropped == last_seq + 1
        assert sub["snapshots"] + sub["dropped"] == sub["last_seq"] + 1


# -- BackgroundSubscriber shutdown (satellite b) -------------------------------

class _BlockingConn:
    """A connection whose long-poll blocks until close() — the TCP recv
    stall the shutdown fix targets."""

    def __init__(self):
        self.closed = threading.Event()
        self.polled = threading.Event()

    def call(self, msg):
        self.polled.set()
        self.closed.wait(timeout=30.0)
        raise OSError("connection closed")

    def close(self):
        self.closed.set()


class TestBackgroundSubscriberShutdown:
    def test_stop_unblocks_a_thread_stuck_in_long_poll(self, capsys):
        conn = _BlockingConn()
        sub = BackgroundSubscriber(lambda: conn, poll_s=0.01).start()
        assert conn.polled.wait(timeout=10.0)   # thread is inside call()
        sub.stop()
        assert not sub._thread.is_alive()
        # the provoked teardown error is the EXPECTED shutdown path:
        # nothing recorded, nothing printed
        assert sub.summary()["errors"] == []
        assert capsys.readouterr().err == ""

    def test_stop_before_any_reply_is_clean(self):
        conn = _BlockingConn()
        sub = BackgroundSubscriber(lambda: conn, poll_s=0.01).start()
        conn.polled.wait(timeout=10.0)
        sub.stop()
        s = sub.summary()
        assert s["snapshots"] == 0 and s["errors"] == []


# -- dashboard JSON golden shape (satellite d) ---------------------------------

class _ScriptedConn:
    def __init__(self, replies):
        self._replies = list(replies)

    def call(self, msg):
        assert msg["kind"] == "subscribe_stats"
        if self._replies:
            return self._replies.pop(0)
        raise OSError("stream drained")

    def close(self):
        pass


class TestDashboardJsonMode:
    def _snaps(self, n=3):
        hub = MetricsHub(interval=1.0)
        hub.register_probe("server", lambda: {"messages": 10,
                                              "searches": []})
        return [hub.sample(float(t)) for t in range(n)]

    def test_json_lines_golden_shape(self):
        snaps = self._snaps()
        conn = _ScriptedConn([protocol.stats_reply(snaps, 2, 1.0,
                                                   STREAM_VERSION)])
        out = io.StringIO()
        shown = watch(lambda: conn, as_json=True, max_snapshots=3, out=out)
        assert shown == 3
        lines = [json.loads(ln) for ln in out.getvalue().splitlines()]
        assert len(lines) == 3
        for doc, snap in zip(lines, snaps):
            # the golden shape: exactly the hub's snapshot keys, stamped
            assert set(doc) == {"stream_v", "seq", "now", "counters",
                                "groups"}
            assert doc["stream_v"] == STREAM_VERSION
            assert doc == snap            # stamp-neutral passthrough

    def test_json_mode_emits_distinct_gap_record(self):
        snaps = self._snaps(2)
        conn = _ScriptedConn([protocol.stats_reply(snaps, 1, 1.0,
                                                   STREAM_VERSION,
                                                   dropped=7)])
        out = io.StringIO()
        watch(lambda: conn, as_json=True, max_snapshots=2, out=out)
        lines = [json.loads(ln) for ln in out.getvalue().splitlines()]
        assert lines[0] == {"kind": "gap", "dropped": 7}
        assert [ln["seq"] for ln in lines[1:]] == [0, 1]


# -- observed parity with the full §14 plane on (the tentpole gate) ------------

class TestRetainedRunParity:
    def test_retained_traced_run_is_bit_identical_and_durable(
            self, problem, backend, baseline, tmp_path):
        spec, fleet, _ = problem
        res = ServerSubstrate(spec, fleet, backend, stats_interval=10.0,
                              retain_dir=str(tmp_path),
                              trace_rate=1.0).run()
        assert _same(baseline, res)
        assert res.retention["snapshots_stored"] >= 2
        assert res.retention["spans_stored"] > 0
        assert res.trace["sampled"] > 0 and res.trace["skipped"] == 0
        store = open_snapshot_store(obs_store_path(str(tmp_path)),
                                    read_only=True)
        assert store.epochs() == [1]
        assert len(store.records("span")) == res.retention["spans_stored"]

    def test_sampled_tracing_traces_the_same_population_twice(
            self, problem, backend, baseline, tmp_path):
        spec, fleet, _ = problem
        runs = []
        for leg in ("a", "b"):
            d = str(tmp_path / leg)
            res = ServerSubstrate(spec, fleet, backend,
                                  stats_interval=10.0, retain_dir=d,
                                  trace_rate=0.5, trace_seed=11).run()
            assert _same(baseline, res)
            store = open_snapshot_store(obs_store_path(d), read_only=True)
            runs.append([r["doc"] for r in store.records("span")])
        # keyed sampling: both runs traced the exact same workunits
        assert runs[0] == runs[1]
        assert 0 < len(runs[0])

    def test_stall_kill_recorded_and_replayed_bit_identically(
            self, problem, backend, baseline):
        spec, fleet, _ = problem
        defended = ServerSubstrate(spec, fleet, backend,
                                   stats_interval=10.0,
                                   stall_window=3).run()
        d = defended.defense
        assert d["searches_killed"] == [0]
        assert d["by_action"]["kill_search"] >= 1
        # the kill truncated the search — NOT parity with the baseline
        assert defended.engines[0].iteration \
            < baseline.engines[0].iteration
        replayed = ServerSubstrate(spec, fleet, backend,
                                   stats_interval=10.0,
                                   defense_schedule=d["schedule"]).run()
        assert _same(defended, replayed)
        assert replayed.defense["mode"] == "replay"
        assert replayed.defense["searches_killed"] == [0]


# -- director-level kill schedule ----------------------------------------------

def _quad_backend(n=6, seed=3):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = jnp.asarray(A @ A.T + n * np.eye(n, dtype=np.float32))

    def f_batch(xs):
        return 0.5 * jnp.einsum("mi,ij,mj->m", xs, H, xs)

    return InProcessEvalBackend(f_batch), n


def _mini_portfolio(n_searches=3, **director_kw):
    backend, n = _quad_backend()
    fleet = GridConfig(n_hosts=64, failure_prob=0.1, malicious_prob=0.02,
                       seed=3)
    sched = FleetScheduler(backend, fleet)
    anm = AnmConfig(m_regression=8, m_line_search=8, max_iterations=2)
    specs = multi_start_specs(sched, np.ones(n), -10 * np.ones(n),
                              10 * np.ones(n), 0.5 * np.ones(n), anm,
                              n_searches, seed=0, jitter=0.3)
    return SearchDirector(sched, specs, **director_kw).run()


class TestDirectorKillSchedule:
    def test_scheduled_kill_retires_and_logs(self):
        base = _mini_portfolio()
        res = _mini_portfolio(kill_schedule={"search-1": 1})
        killed = next(o for o in res.outcomes if o.spec.name == "search-1")
        assert killed.status == "killed"
        assert killed.engine.iteration <= 1
        survivors = [o for o in res.outcomes if o.spec.name != "search-1"]
        for o, b in zip(survivors,
                        [o for o in base.outcomes if o.spec.name != "search-1"]):
            assert identical_trajectories(o.engine, b.engine)

    def test_kill_log_roundtrip(self):
        director_log = {}

        def run(schedule):
            backend, n = _quad_backend()
            fleet = GridConfig(n_hosts=64, failure_prob=0.1,
                               malicious_prob=0.02, seed=3)
            sched = FleetScheduler(backend, fleet)
            anm = AnmConfig(m_regression=8, m_line_search=8,
                            max_iterations=2)
            specs = multi_start_specs(sched, np.ones(n), -10 * np.ones(n),
                                      10 * np.ones(n), 0.5 * np.ones(n),
                                      anm, 3, seed=0, jitter=0.3)
            d = SearchDirector(sched, specs, kill_schedule=schedule)
            res = d.run()
            director_log[id(res)] = list(d.kill_log)
            return res

        first = run({"search-1": 1})
        log = director_log[id(first)]
        assert log == [{"name": "search-1", "round": 1}]
        # the recorded log IS a schedule: replaying it reproduces the run
        second = run({k["name"]: k["round"] for k in log})
        assert director_log[id(second)] == log
        for a, b in zip(first.outcomes, second.outcomes):
            assert a.status == b.status
            assert identical_trajectories(a.engine, b.engine)
            assert a.engine.stats == b.engine.stats


# -- post-mortem reconstruction ------------------------------------------------

class TestPostmortemReconstruct:
    def _store(self, tmp_path):
        p = str(tmp_path / "obs.jsonl")
        s = SnapshotStore(p)

        def snap(seq, now, phase, status, it, states):
            return {"stream_v": 1, "seq": seq, "now": now, "counters": {},
                    "groups": {
                        "server": {"searches": [
                            {"search_id": 0, "phase": phase,
                             "status": status, "iteration": it,
                             "best": 1.0}]},
                        "registry": {"states": states, "quarantined": 0,
                                     "reliable_set": sum(states.values()),
                                     "churn": {}}}}

        s.append("snap", snap(0, 10.0, 0, "running", 0,
                              {"alive": 4}), seq=0, now=10.0)
        s.append("snap", snap(1, 20.0, 0, "running", 0,
                              {"alive": 4}), seq=1, now=20.0)
        s.append("snap", snap(2, 30.0, 1, "running", 1,
                              {"alive": 3, "suspect": 1}), seq=2, now=30.0)
        for wu, ta in ((0, 5.0), (1, 25.0), (2, 15.0)):
            s.append("span", {"search": 0, "wu": wu, "host": wu,
                              "phase": 0, "issued_at": 0.0,
                              "lapsed_at": None, "reported_at": ta,
                              "outcome": "committed", "late": False,
                              "turnaround": ta}, now=ta)
        s.append("anomaly", {"seq": 2, "now": 30.0, "action": "page",
                             "kind": "stale_spike", "hosts": [],
                             "detail": {}}, seq=2, now=30.0)
        s.close()
        return p

    def test_reconstruct_is_read_only_and_complete(self, tmp_path):
        p = self._store(tmp_path)
        doc = reconstruct(p, top=2)
        # phase timeline: one entry per (phase, status, ...) transition
        assert [(t["seq"], t["phase"]) for t in doc["phases"]] == \
            [(0, 0), (2, 1)]
        assert [(c["seq"], c["states"]) for c in doc["cohorts"]] == \
            [(0, {"alive": 4}), (2, {"alive": 3, "suspect": 1})]
        assert doc["spans"] == 3
        assert doc["turnaround"]["max"] == 25.0
        assert [sp["wu"] for sp in doc["critical_paths"]] == [1, 2]
        assert len(doc["anomalies"]) == 1
        assert doc["epochs"][0]["snapshots"] == 3
        # reconstructing did NOT mark an epoch
        assert open_snapshot_store(p, read_only=True).epochs() == [1]

    def test_epoch_filter_separates_runs(self, tmp_path):
        p = self._store(tmp_path)
        s = SnapshotStore(p)              # "restored run" appends epoch 2
        s.append("snap", {"stream_v": 1, "seq": 7, "now": 70.0,
                          "counters": {}, "groups": {}}, seq=7, now=70.0)
        s.close()
        doc = reconstruct(p, epoch=1)
        assert {e["epoch"]: e["snapshots"] for e in doc["epochs"]} == \
            {1: 3, 2: 1}
        assert all(t["seq"] <= 2 for t in doc["phases"])
        doc2 = reconstruct(p, epoch=2)
        assert doc2["spans"] == 0
