"""Service-layer tests: wire protocol, host registry, engine checkpoints,
and the crash-recoverable work server (DESIGN.md §9).

The load-bearing contract here is bit-identical resume: a server killed at
ANY message boundary and restored from snapshot + replay log must commit
exactly the trajectory (and final engine stats) of an uninterrupted run —
on the loopback AND TCP transports, through the in-process AND pod-mesh
evaluation paths, with snapshots landing mid-bootstrap, mid-validation
and with speculative blocks in flight.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anm import AnmConfig
from repro.core.engine import (AnmEngine, EvalResult, identical_trajectories)
from repro.core.grid import GridConfig
from repro.core.orchestrator.director import SearchSpec
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.server import protocol
from repro.server.checkpoint import (CheckpointManager, ReplayLog,
                                     from_jsonable, to_jsonable)
from repro.server.registry import ALIVE, DEAD, SUSPECT, HostRegistry
from repro.server.sim import ServerSubstrate, SimulatedCrash, smoke_problem
from repro.server.transport import LoopbackTransport

pytestmark = pytest.mark.server


def _quad_fitness(n=4, seed=3):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = jnp.asarray(A @ A.T + n * np.eye(n, dtype=np.float32))
    x_opt = jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32))

    @jax.jit
    def f_batch(xs):
        d = xs - x_opt[None, :]
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, H, d)

    return f_batch


def _spec(n=4, m=8, iterations=2, engine_seed=11, grid_seed=5, n_hosts=24,
          failure=0.1, malicious=0.05, name="t"):
    fleet = GridConfig(n_hosts=n_hosts, failure_prob=failure,
                       malicious_prob=malicious, seed=grid_seed)
    spec = SearchSpec(
        name=name, x0=np.full(n, 1.0), lo=np.full(n, -10.0),
        hi=np.full(n, 10.0), step=np.full(n, 0.5),
        anm=AnmConfig(m_regression=m, m_line_search=m,
                      max_iterations=iterations),
        grid=fleet, engine_seed=engine_seed)
    return spec, fleet


@pytest.fixture(scope="module")
def f_batch():
    return _quad_fitness()


@pytest.fixture(scope="module")
def backend(f_batch):
    return InProcessEvalBackend(f_batch, n_dims=4, max_bucket=32)


# -- wire protocol ------------------------------------------------------------

def _codecs():
    cs = [protocol.CODEC_JSON]
    if protocol.msgpack is not None:
        cs.append(protocol.CODEC_MSGPACK)
    return cs


@pytest.mark.parametrize("codec", _codecs())
def test_protocol_roundtrip_exact(codec):
    pt = np.random.default_rng(0).uniform(-1, 1, 8)
    msgs = [
        protocol.register(3, 1.25),
        protocol.request_work(3, 2.5),
        protocol.report_result(3, 0, 17, -0.1234567890123456789, 3.75),
        protocol.heartbeat(3, 4.0),
        protocol.work_reply(1, 42, 7, pt, float("nan"), None, 99.5),
        protocol.work_reply(0, 43, 8, pt, 0.5, 42, 100.0),
        protocol.no_work_reply(5.0, False),
        protocol.ack_reply(True, 3, 1e-12),
    ]
    for msg in msgs:
        out = protocol.decode_message(protocol.encode_message(msg, codec))
        out.pop("v")
        for k, v in msg.items():
            got = out[k]
            if isinstance(v, float) and np.isnan(v):
                assert np.isnan(got)
            elif isinstance(v, list):
                # float64 must round-trip exactly — the resume contract
                assert [float(x) for x in got] == [float(x) for x in v]
            else:
                assert got == v


def test_protocol_version_mismatch_rejected():
    raw = protocol.encode_message(protocol.heartbeat(1, 0.0),
                                  protocol.CODEC_JSON)
    body = json.loads(raw[1:])
    body["v"] = 999
    bad = bytes([protocol.CODEC_JSON]) + json.dumps(body).encode()
    with pytest.raises(protocol.ProtocolError):
        protocol.decode_message(bad)
    with pytest.raises(protocol.ProtocolError):
        protocol.decode_message(bytes([77]) + b"{}")


def test_frame_decoder_reassembles_partial_feeds():
    payloads = [protocol.encode_message(protocol.heartbeat(i, float(i)))
                for i in range(3)]
    stream = b"".join(protocol.frame(p) for p in payloads)
    dec = protocol.FrameDecoder()
    got = []
    for i in range(0, len(stream), 5):     # drip-feed 5 bytes at a time
        got.extend(dec.feed(stream[i:i + 5]))
    assert [protocol.decode_message(p)["host_id"] for p in got] == [0, 1, 2]


# -- host registry ------------------------------------------------------------

def test_registry_cold_start_grace():
    """A brand-new host must not be excluded by the return-rate gate
    before it ever had a chance to report: the gate engages only after
    ``min_issued_for_rate`` issues (the satellite fix, pinned)."""
    reg = HostRegistry(min_return_rate=0.5, min_issued_for_rate=4)
    reg.on_issue(0, 0.0)
    assert reg.returns_work(0)            # 1 issued / 0 returned: grace
    reg.on_issue(0, 1.0)
    reg.on_issue(0, 2.0)
    assert reg.returns_work(0)            # 3 issued / 0 returned: grace
    reg.on_issue(0, 3.0)
    assert not reg.returns_work(0)        # 4 issued / 0 returned: excluded
    # returning work re-admits it once the rate clears the bar
    for t in (4.0, 5.0, 6.0, 7.0):
        reg.on_result(0, t, 1.0)
    assert reg.returns_work(0)


def test_registry_churn_states_and_revival():
    reg = HostRegistry(suspect_after=10.0, dead_after=50.0)
    reg.register(1, 0.0)
    reg.sweep(5.0)
    assert reg.hosts[1].state == ALIVE
    reg.sweep(20.0)
    assert reg.hosts[1].state == SUSPECT
    reg.sweep(100.0)
    assert reg.hosts[1].state == DEAD
    reg.touch(1, 101.0)                   # any contact revives
    assert reg.hosts[1].state == ALIVE
    assert reg.counts() == {ALIVE: 1, SUSPECT: 0, DEAD: 0}


def test_registry_latency_gate_prefers_fast_hosts():
    reg = HostRegistry(min_latency_samples=4)
    for h, ta in enumerate([1.0, 2.0, 3.0, 100.0]):
        reg.on_issue(h, 0.0)
        reg.on_result(h, ta, ta)
    assert reg.reliable(0) and reg.reliable(1)
    assert not reg.reliable(3)            # above-median turnaround
    assert reg.reliable(99)               # unknown host: benefit of doubt


def test_registry_state_roundtrip():
    reg = HostRegistry()
    reg.on_issue(4, 1.0)
    reg.on_result(4, 3.0, 2.0)
    reg.on_no_work(9, 4.0, 5.0)
    blob = json.dumps(to_jsonable(reg.state_dict()))
    reg2 = HostRegistry()
    reg2.load_state(from_jsonable(json.loads(blob)))
    assert reg2.state_dict() == reg.state_dict()
    assert reg2.hosts[9].nowork_streak == 1


# -- engine checkpoints (satellite: mid-phase snapshot edge cases) ------------

def _f_scalar(x):
    return float(np.sum(np.asarray(x, np.float64) ** 2))


def _drive(engine, steps=None):
    """Deterministic synchronous driver: the continuation is a pure
    function of engine state, so two engines in equal state must commit
    equal futures."""
    n = 0
    while not engine.done:
        reqs = engine.generate(4)
        if not reqs:
            break
        engine.assimilate([EvalResult(r, _f_scalar(r.point)) for r in reqs])
        n += 1
        if steps is not None and n >= steps:
            break
    return engine


def _engine():
    return AnmEngine(np.ones(3), -5 * np.ones(3), 5 * np.ones(3),
                     0.4 * np.ones(3),
                     AnmConfig(m_regression=10, m_line_search=10,
                               max_iterations=3), seed=2)


def _capture_until(predicate, max_steps=500):
    """Drive a fresh engine until ``predicate(engine)`` holds, then return
    (engine, json-round-tripped state)."""
    eng = _engine()
    for _ in range(max_steps):
        if predicate(eng):
            state = json.loads(json.dumps(to_jsonable(eng.state_dict())))
            return eng, from_jsonable(state)
        reqs = eng.generate(1)
        if not reqs:
            break
        eng.assimilate([EvalResult(r, _f_scalar(r.point)) for r in reqs])
    raise AssertionError("predicate never held")


@pytest.mark.parametrize("predicate, label", [
    (lambda e: e.phase == "validating" and e.bootstrapping,
     "mid_bootstrap_validation"),
    (lambda e: e.phase == "validating" and not e.bootstrapping
     and len(e._votes) == 2, "mid_linesearch_validation"),
    (lambda e: e.phase == "linesearch" and e._res_count == 5,
     "mid_linesearch"),
])
def test_engine_snapshot_restore_bit_identical(predicate, label):
    original, state = _capture_until(predicate)
    restored = _engine()
    restored.load_state(state)
    _drive(original)
    _drive(restored)
    assert identical_trajectories(original, restored)
    assert original.stats == restored.stats
    assert original.phase_id == restored.phase_id
    assert original._next_ticket == restored._next_ticket


def test_engine_snapshot_composes_with_block_speculation():
    """A snapshot taken with a speculative block in flight must restore
    the peek's rewind snapshot too: cancel_block() on the restored engine
    rewinds exactly like on the original (PR-3 seam)."""
    def mid_regression(e):
        return e.phase == "regression" and e._res_count == 4

    original, _ = _capture_until(mid_regression)
    block = original.peek_block(3)
    assert block is not None
    state = from_jsonable(json.loads(json.dumps(
        to_jsonable(original.state_dict()))))
    restored = _engine()
    restored.load_state(state)
    # both cancel: the rewind must land both engines on the same rng
    # stream, ticket counter and issuance stats
    original.cancel_block()
    restored.cancel_block()
    assert original._next_ticket == restored._next_ticket
    assert original.stats == restored.stats
    b1 = original.generate_block(3)
    b2 = restored.generate_block(3)
    np.testing.assert_array_equal(b1[2], b2[2])
    np.testing.assert_array_equal(b1[0], b2[0])
    _drive(original)
    _drive(restored)
    assert identical_trajectories(original, restored)


def test_engine_load_state_rejects_mismatch():
    eng = _engine()
    state = eng.state_dict()
    other = AnmEngine(np.ones(5), -np.ones(5), np.ones(5), 0.1 * np.ones(5))
    with pytest.raises(ValueError):
        other.load_state(state)
    cfg_changed = AnmEngine(np.ones(3), -np.ones(3), np.ones(3),
                            0.1 * np.ones(3),
                            AnmConfig(m_regression=99))
    with pytest.raises(ValueError):
        cfg_changed.load_state(state)


# -- crash/restore through the work server ------------------------------------

@pytest.fixture(scope="module")
def baseline(backend):
    spec, fleet = _spec()
    res = ServerSubstrate(spec, fleet, backend, warm=False).run()
    return spec, fleet, res


@pytest.mark.parametrize("frac", [0.05, 0.3, 0.6, 0.9])
def test_crash_restore_bit_identical(tmp_path, backend, baseline, frac):
    """Killed at an arbitrary message boundary (snapshot cadence of 25
    puts snapshots inside bootstrap, validation and line-search phases),
    the restored run must replay the uninterrupted future exactly."""
    spec, fleet, base = baseline
    crash_at = max(10, int(frac * base.pool.messages))
    d = str(tmp_path / f"ckpt_{crash_at}")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        snapshot_every=25, max_messages=crash_at).run()
    res = ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                          snapshot_every=25).run(resume=True)
    assert not res.recovered_done
    assert identical_trajectories(base.engines[0], res.engines[0])
    assert base.engines[0].stats == res.engines[0].stats


def test_crash_restore_pod_mesh_backend(tmp_path, f_batch, baseline):
    """The same kill/restore contract through the pod-mesh evaluation
    path (degenerate mesh on one CPU device) — and the pod run must also
    agree with the in-process baseline (row-independence, DESIGN.md §6)."""
    from repro.core.substrates.pod_mesh import PodMeshEvalBackend

    spec, fleet, base = baseline
    pod = PodMeshEvalBackend(f_batch)
    d = str(tmp_path / "ckpt_pod")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, pod, warm=False, ckpt_dir=d,
                        snapshot_every=25, max_messages=200).run()
    res = ServerSubstrate(spec, fleet, pod, warm=False, ckpt_dir=d,
                          snapshot_every=25).run(resume=True)
    assert identical_trajectories(base.engines[0], res.engines[0])
    assert base.engines[0].stats == res.engines[0].stats


def test_recovery_ignores_truncated_log_tail(tmp_path, backend, baseline):
    spec, fleet, base = baseline
    d = str(tmp_path / "ckpt_trunc")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        snapshot_every=25, max_messages=300).run()
    log = os.path.join(d, "replay.jsonl")
    with open(log, "a") as f:                 # the kill's half-append
        f.write('{"seq": 99999, "msg": {"kind": "report_')
    res = ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                          snapshot_every=25).run(resume=True)
    assert identical_trajectories(base.engines[0], res.engines[0])
    assert base.engines[0].stats == res.engines[0].stats


def test_double_crash_with_torn_log_line(tmp_path, backend, baseline):
    """A resumed run must not append onto the previous kill's torn
    half-line: recovery repairs the log tail, so even a SECOND crash and
    recovery replays every durable record and stays bit-identical."""
    spec, fleet, base = baseline
    d = str(tmp_path / "ckpt_double")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        snapshot_every=1000, max_messages=150).run()
    log = os.path.join(d, "replay.jsonl")
    with open(log, "a") as f:
        f.write('{"seq": 150, "msg": {"kind": "request_')  # torn append
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        snapshot_every=1000,
                        max_messages=200).run(resume=True)
    res = ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                          snapshot_every=1000).run(resume=True)
    # snapshot_every=1000 means NO snapshot ever landed: the final state
    # is rebuilt purely from the replay log across both crash epochs, so
    # a lost durable suffix would show up as a diverged trajectory here
    assert res.replayed > 150
    assert identical_trajectories(base.engines[0], res.engines[0])
    assert base.engines[0].stats == res.engines[0].stats


def test_tcp_malformed_frame_gets_error_reply(backend):
    """A well-formed frame missing required fields must produce an error
    REPLY on a still-usable connection, not a dead socket (untrusted
    clients are the whole point of a wire server)."""
    from repro.server.server import WorkServer
    from repro.server.transport import TcpTransport

    spec, _ = _spec(n_hosts=8, m=4, iterations=1)
    t = TcpTransport().start(WorkServer([spec]).handle)
    try:
        conn = t.connect()
        rep = conn.call({"kind": "register"})          # no host_id/now
        assert rep["kind"] == "error"
        assert "KeyError" in rep["error"]
        rep = conn.call(protocol.register(0, 0.0))     # connection lives
        assert rep["kind"] == "registered"
        conn.close()
    finally:
        t.stop()


def test_recovery_rejects_changed_server_knobs(tmp_path, backend):
    """Behavior-affecting server parameters are part of the checkpoint
    fingerprint: resuming under a different lease timeout must fail
    loudly instead of continuing plausibly-but-wrong."""
    spec, fleet = _spec()
    d = str(tmp_path / "ckpt_knobs")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        snapshot_every=10, max_messages=60).run()
    with pytest.raises(ValueError, match="fingerprint"):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        lease_timeout=1.0).run(resume=True)


def test_recovery_rejects_wrong_spec(tmp_path, backend):
    spec, fleet = _spec()
    d = str(tmp_path / "ckpt_fp")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend, warm=False, ckpt_dir=d,
                        snapshot_every=10, max_messages=60).run()
    other, _ = _spec(engine_seed=999)
    with pytest.raises(ValueError, match="fingerprint"):
        ServerSubstrate(other, fleet, backend, warm=False,
                        ckpt_dir=d).run(resume=True)


def test_replay_log_tolerates_corrupt_tail(tmp_path):
    path = str(tmp_path / "log.jsonl")
    log = ReplayLog(path)
    for i in range(3):
        log.append({"seq": i + 1, "msg": {"kind": "heartbeat"}})
    log.close()
    with open(path, "a") as f:
        f.write("not json at all\n")
    assert [r["seq"] for r in ReplayLog.replay(path)] == [1, 2, 3]


def test_tcp_transport_matches_loopback(backend):
    spec, fleet = _spec(n_hosts=16, m=6, iterations=1)
    a = ServerSubstrate(spec, fleet, backend, warm=False).run()
    b = ServerSubstrate(spec, fleet, backend, warm=False,
                        transport="tcp").run()
    assert identical_trajectories(a.engines[0], b.engines[0])
    assert a.engines[0].stats == b.engines[0].stats
    assert b.pool.messages == a.pool.messages


def test_lease_lapse_and_late_return_bookkeeping(backend):
    """Slow hosts outlive a tight lease deadline: their leases lapse, the
    eventual result is still assimilated (counted as a late return), and
    the run stays deterministic."""
    spec, fleet = _spec(n_hosts=16, m=6, iterations=1, failure=0.3)
    runs = [ServerSubstrate(spec, fleet, backend, warm=False,
                            lease_timeout=0.5 * fleet.base_eval_time).run()
            for _ in range(2)]
    c = runs[0].server.counters
    assert c.leases_lapsed > 0
    assert c.late_returns > 0
    assert identical_trajectories(runs[0].engines[0], runs[1].engines[0])
    assert dataclasses.asdict(c) == dataclasses.asdict(
        runs[1].server.counters)


def test_portfolio_server_routes_and_kills(backend, tmp_path):
    """One server fronting a 2-search portfolio: round-robin work routing,
    the orchestrator's dominated_cut kill rule, and crash/restore across
    the whole portfolio state."""
    good, fleet = _spec(name="good")
    bad, _ = _spec(name="bad", engine_seed=13)
    bad = dataclasses.replace(bad, x0=np.full(4, 8.0), step=np.full(4, 0.05))
    kw = dict(policy="portfolio", kill_margin=0.05, probation_iterations=1)
    base = ServerSubstrate([good, bad], fleet, backend, warm=False,
                           **kw).run()
    statuses = [e.status for e in base.server.searches]
    assert "killed" in statuses           # the bad start gets retired
    assert base.server.counters.dropped_results >= 0
    d = str(tmp_path / "ckpt_portfolio")
    with pytest.raises(SimulatedCrash):
        ServerSubstrate([good, bad], fleet, backend, warm=False,
                        ckpt_dir=d, snapshot_every=25, max_messages=300,
                        **kw).run()
    res = ServerSubstrate([good, bad], fleet, backend, warm=False,
                          ckpt_dir=d, snapshot_every=25, **kw).run(
                              resume=True)
    for e_base, e_res in zip(base.engines, res.engines):
        assert identical_trajectories(e_base, e_res)
        assert e_base.stats == e_res.stats
    assert [e.status for e in res.server.searches] == statuses


def test_malicious_clients_corrupt_and_get_rejected(backend):
    """Malicious sim clients lie through the same sign-safe on-device
    corruption lanes as the grid substrates, and the engine's quorum
    validation catches the winners — through the full protocol stack."""
    spec, fleet = _spec(n_hosts=32, m=10, iterations=2, malicious=0.3)
    res = ServerSubstrate(spec, fleet, backend, warm=False).run()
    eng = res.engines[0]
    assert res.pool.corrupted > 0
    assert eng.stats.validations_failed >= 1
    assert eng.stats.candidates_rejected >= 1
    assert np.isfinite(eng.best_fitness)


def test_server_status_message_is_read_only(backend):
    spec, fleet = _spec(n_hosts=8, m=4, iterations=1)
    from repro.server.server import WorkServer
    srv = WorkServer([spec])
    t = LoopbackTransport().start(srv.handle)
    conn = t.connect()
    conn.call(protocol.register(0, 0.0))
    before = json.dumps(to_jsonable(srv.state_dict()), sort_keys=True)
    rep = conn.call(protocol.status())
    assert rep["kind"] == "status"
    assert rep["searches"][0]["phase"] == "bootstrap"
    after = json.dumps(to_jsonable(srv.state_dict()), sort_keys=True)
    assert before == after


def test_substrate_registry_names():
    """The one registry dict the dryrun CLI and scalability derive from."""
    from repro.launch.substrates import SUBSTRATES, list_substrates
    assert {"pod_mesh", "multi_search", "server"} <= set(SUBSTRATES)
    for s in SUBSTRATES.values():
        mod, fn = s.runner.split(":")
        assert mod and fn
    assert "server" in list_substrates()
