"""Persistent cross-search evaluation cache (DESIGN.md §10).

The contracts under test:

  * bit-exact-only serving — cache-on runs commit bit-identical iterates
    and identical final ``EngineStats`` to cache-off runs, solo and in a
    coalesced multi-search portfolio, on both evaluation backends;
  * key canonicalization — NaN payloads and -0.0 collapse to one key,
    float64 points share the key of their staged f32 row, and the
    objective fingerprint isolates caches sharing one store;
  * malicious lanes are NEVER cached and NEVER served (quorum validation
    must keep re-evaluating suspect results);
  * persistence — JSONL/sqlite stores round-trip float64 exactly, survive
    a SIGKILL-torn tail, and compose with the checkpoint layer so a
    crashed-and-restored server comes back warm AND bit-identical;
  * the coalescer's intra-bucket dedup evaluates identical honest lanes
    once without changing what any search observes.
"""
import dataclasses
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anm import AnmConfig
from repro.core.engine import AnmEngine, identical_trajectories
from repro.core.grid import GridConfig
from repro.core.orchestrator import (CoalescingSubmitter, FleetScheduler,
                                     SearchDirector, multi_start_specs)
from repro.core.substrates.batched_grid import BatchedVolunteerGrid
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.core.substrates.eval_cache import (CachingSubmitter, EvalCache,
                                              JsonlCacheStore,
                                              MemoryCacheStore,
                                              SqliteCacheStore,
                                              canonical_block)
from repro.core.substrates.pod_mesh import PodMeshEvalBackend

pytestmark = pytest.mark.cache


def _quad_fitness(n=8, seed=3):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = jnp.asarray(A @ A.T + n * np.eye(n, dtype=np.float32))
    x_opt = jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32))

    @jax.jit
    def f_batch(xs):
        d = xs - x_opt[None, :]
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, H, d)

    return f_batch, n


def _f32(bits: int) -> np.float32:
    return np.frombuffer(struct.pack("<I", bits), np.float32)[0]


# -- key canonicalization -----------------------------------------------------

def test_negative_zero_and_zero_share_a_key():
    c = EvalCache(fingerprint="z")
    assert c.key(np.array([0.0, 1.0])) == c.key(np.array([-0.0, 1.0]))


def test_nan_payloads_collapse_to_one_key():
    """Quiet NaN, payload-carrying NaN and negative NaN canonicalize to
    the same staged bytes — the objective cannot distinguish them, so the
    cache must not either."""
    nans = [_f32(0x7FC00000), _f32(0x7FC00ABC), _f32(0xFFC00000)]
    rows = [np.array([v, np.float32(1.0)], np.float32) for v in nans]
    blocks = [canonical_block(r).tobytes() for r in rows]
    assert blocks[0] == blocks[1] == blocks[2]


def test_float64_points_key_on_their_staged_f32_row():
    """The backend stages float32 (``buf[:k] = pts``), so two float64
    points that round to the same f32 row are the SAME evaluation —
    and two that round differently are not."""
    c = EvalCache(fingerprint="f")
    assert c.key(np.array([0.1])) == \
        c.key(np.array([float(np.float32(0.1))]))
    next_f32 = float(np.nextafter(np.float32(0.1), np.float32(2.0)))
    assert c.key(np.array([0.1])) != c.key(np.array([next_f32]))


def test_fingerprint_isolates_objectives_sharing_one_store():
    store = MemoryCacheStore()
    a = EvalCache(store, fingerprint="objective-a")
    b = EvalCache(store, fingerprint="objective-b")
    pt = np.ones(4)
    store.put(a.key(pt), 42.0)
    assert store.get(a.key(pt)) == 42.0
    assert store.get(b.key(pt)) is None


# -- the memo layer ------------------------------------------------------------

def test_hits_strip_lanes_shrink_buckets_and_splice_back():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch, n_dims=n, max_bucket=64)
    ref_be = InProcessEvalBackend(f_batch, n_dims=n, max_bucket=64)
    dispatched = []
    orig = be.submit
    be.submit = lambda *a, **k: (dispatched.append(len(a[0])),
                                 orig(*a, **k))[1]
    cs = CachingSubmitter(be, EvalCache(fingerprint="t"))
    pts = np.random.default_rng(0).normal(size=(24, n))
    y1 = cs(pts)
    assert np.array_equal(y1, ref_be(pts))
    assert np.array_equal(y1, cs(pts))
    # third submit is fully served: no dispatch at all, handle width 0
    h = cs.submit(pts)
    assert h.inner is None and h.kp == 0
    assert np.array_equal(cs.collect(h), y1)
    # a half-new bucket dispatches ONLY the misses, at the smaller width
    mixed = np.concatenate([pts[:20], pts[:4] + 100.0])
    hm = cs.submit(mixed)
    assert dispatched[-1] == 4 and hm.kp == 8   # 24 lanes -> 4, bucket 8
    ym = cs.collect(hm)
    assert np.array_equal(ym, ref_be(mixed))
    st = cs.cache.stats
    assert st.hits == 24 + 24 + 20 and st.full_buckets == 2
    assert st.hit_rate() > 0.5


def test_malicious_lanes_are_never_cached_and_never_served():
    """THE quorum pin: a mal_u lane must bypass the cache both ways —
    its corrupted value never lands in the store, and a stored honest
    value is never served in its place."""
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch, n_dims=n, max_bucket=16)
    cache = EvalCache(fingerprint="mal")
    cs = CachingSubmitter(be, cache)
    pts = np.random.default_rng(1).normal(size=(8, n))
    honest = cs(pts)                       # seeds the cache honestly
    size0 = len(cache)
    mal_u = np.full(8, np.nan)
    mal_u[3] = 0.5
    served = cs(pts, mal_u)
    # the mal lane carries the on-device lie, not the cached honest value
    ref = be(pts, mal_u)
    assert np.array_equal(served, ref)
    assert served[3] != honest[3]
    # ... and the lie was not stored
    assert len(cache) == size0
    assert cache.stats.mal_bypassed == 1


def test_status_doc_reports_the_satellite_counters():
    cache = EvalCache(fingerprint="doc")
    doc = cache.status()
    assert {"hits", "misses", "lanes_saved", "store_size",
            "hit_rate"} <= set(doc)
    assert doc["lanes_saved"] == doc["hits"] == 0


# -- run-level parity: cache-on == cache-off ----------------------------------

def _solo(backend, anm, grid_cfg, n, seed=7):
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), anm, seed=seed)
    BatchedVolunteerGrid(None, grid_cfg, backend=backend,
                         pipelined=True).run(engine)
    return engine


def test_cached_solo_run_matches_uncached_bit_identically():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    anm = AnmConfig(m_regression=24, m_line_search=24, max_iterations=3)
    grid_cfg = GridConfig(n_hosts=256, failure_prob=0.1,
                          malicious_prob=0.02, seed=5)
    e_off = _solo(be, anm, grid_cfg, n)
    cs = CachingSubmitter(be, EvalCache(fingerprint="solo"))
    e_on = _solo(cs, anm, grid_cfg, n)
    assert identical_trajectories(e_off, e_on)
    assert e_off.stats == e_on.stats
    # the warm rerun serves (nearly) everything and STILL matches
    misses0 = cs.cache.stats.misses
    e_warm = _solo(cs, anm, grid_cfg, n)
    assert identical_trajectories(e_off, e_warm)
    assert e_off.stats == e_warm.stats
    assert cs.cache.stats.misses == misses0     # zero new evaluations
    assert cs.cache.stats.hits > 0


@pytest.mark.parametrize("make_backend", [
    lambda f: InProcessEvalBackend(f),
    lambda f: PodMeshEvalBackend(f),
], ids=["in_process", "pod_mesh"])
def test_cached_portfolio_matches_uncached_on_both_backends(make_backend):
    """8-search coalesced portfolio, cache below the coalescer: every
    search must commit bit-identical iterates and identical final stats
    to the cache-off portfolio on the same backend."""
    f_batch, n = _quad_fitness()
    backend = make_backend(f_batch)
    fleet = GridConfig(n_hosts=512, failure_prob=0.1,
                       malicious_prob=0.02, seed=3)
    anm = AnmConfig(m_regression=16, m_line_search=16, max_iterations=2)

    def portfolio(cache):
        sched = FleetScheduler(backend, fleet, cache=cache)
        specs = multi_start_specs(sched, np.ones(n), -10 * np.ones(n),
                                  10 * np.ones(n), 0.5 * np.ones(n), anm,
                                  8, seed=0, jitter=0.3)
        return SearchDirector(sched, specs).run()

    off = portfolio(None)
    cache = EvalCache(fingerprint="portfolio")
    on = portfolio(cache)
    for a, b in zip(off.outcomes, on.outcomes):
        assert identical_trajectories(a.engine, b.engine)
        assert a.engine.stats == b.engine.stats
    # warm rerun: the whole portfolio replays out of the cache
    misses0 = cache.stats.misses
    warm = portfolio(cache)
    for a, b in zip(off.outcomes, warm.outcomes):
        assert identical_trajectories(a.engine, b.engine)
        assert a.engine.stats == b.engine.stats
    assert cache.stats.misses == misses0
    assert cache.stats.hits > 0 and cache.stats.full_buckets > 0


def test_uncoalesced_cached_scheduler_matches_solo():
    """The cache also rides the uncoalesced path (shared ring guard over
    the caching submitter)."""
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    fleet = GridConfig(n_hosts=256, failure_prob=0.1,
                       malicious_prob=0.02, seed=3)
    anm = AnmConfig(m_regression=16, m_line_search=16, max_iterations=2)
    sched = FleetScheduler(be, fleet, coalesce=False,
                           cache=EvalCache(fingerprint="unco"))
    specs = multi_start_specs(sched, np.ones(n), -10 * np.ones(n),
                              10 * np.ones(n), 0.5 * np.ones(n), anm,
                              4, seed=0, jitter=0.3)
    res = SearchDirector(sched, specs).run()
    for o in res.outcomes:
        solo = o.spec.solo_run(be)
        assert identical_trajectories(o.engine, solo)
        assert o.engine.stats == solo.stats


# -- intra-bucket dedup (coalescer satellite) ---------------------------------

def test_coalescer_dedups_identical_honest_lanes_across_searches():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch, n_dims=n, max_bucket=64)
    co = CoalescingSubmitter(be)
    pts = np.random.default_rng(2).normal(size=(6, n))
    s0, s1 = co.lane_submitter(0), co.lane_submitter(1)
    l0 = s0.submit(pts)
    l1 = s1.submit(pts.copy())             # identical points, other search
    co.flush()
    y0, y1 = s0.collect(l0), s1.collect(l1)
    ref = be(pts)
    assert np.array_equal(y0, ref) and np.array_equal(y1, ref)
    assert co.stats.lanes_deduped == 6
    assert l0.kp == 8                      # 12 lanes dispatched as 6


def test_dedup_never_merges_malicious_lanes():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch, n_dims=n, max_bucket=64)
    co = CoalescingSubmitter(be)
    pts = np.random.default_rng(4).normal(size=(4, n))
    mal = np.full(4, np.nan)
    mal[0] = 0.5
    s0, s1 = co.lane_submitter(0), co.lane_submitter(1)
    l0 = s0.submit(pts, mal)               # lane 0 malicious
    l1 = s1.submit(pts.copy())             # all honest duplicates
    co.flush()
    y0, y1 = s0.collect(l0), s1.collect(l1)
    # the mal lane keeps its own lie; its honest twin gets the true value
    assert np.array_equal(y0, be(pts, mal))
    assert np.array_equal(y1, be(pts))
    assert y0[0] != y1[0]
    assert co.stats.lanes_deduped == 3     # the mal pair never merged


def test_deduped_portfolio_still_matches_solo_runs():
    """Two searches with the SAME engine seed and start submit identical
    early blocks — dedup fires, and both searches still commit exactly
    their solo trajectories."""
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    fleet = GridConfig(n_hosts=256, failure_prob=0.1,
                       malicious_prob=0.02, seed=3)
    anm = AnmConfig(m_regression=16, m_line_search=16, max_iterations=2)
    sched = FleetScheduler(be, fleet)
    specs = multi_start_specs(sched, np.ones(n), -10 * np.ones(n),
                              10 * np.ones(n), 0.5 * np.ones(n), anm,
                              2, seed=0, jitter=0.0)
    specs = [dataclasses.replace(s, engine_seed=7) for s in specs]
    res = SearchDirector(sched, specs).run()
    assert res.coalesce_stats.lanes_deduped > 0
    for o in res.outcomes:
        solo = o.spec.solo_run(be)
        assert identical_trajectories(o.engine, solo)
        assert o.engine.stats == solo.stats


# -- persistence --------------------------------------------------------------

def _fill(store, cache, values):
    for i, v in enumerate(values):
        store.put(cache.key(np.full(3, float(i))), v)


def test_jsonl_store_round_trips_exact_float64(tmp_path):
    path = str(tmp_path / "cache.jsonl")
    cache = EvalCache(fingerprint="p")
    values = [0.1, -1.0 / 3.0, 1e-300, 4503599627370497.0]
    store = JsonlCacheStore(path, flush_every=2)
    _fill(store, cache, values)
    store.close()
    loaded = JsonlCacheStore(path)
    for i, v in enumerate(values):
        got = loaded.get(cache.key(np.full(3, float(i))))
        assert got == v and np.float64(got).tobytes() == \
            np.float64(v).tobytes()
    assert len(loaded) == len(values)
    loaded.close()


def test_jsonl_store_tolerates_and_repairs_a_torn_tail(tmp_path):
    path = str(tmp_path / "cache.jsonl")
    cache = EvalCache(fingerprint="torn")
    store = JsonlCacheStore(path)
    _fill(store, cache, [1.0, 2.0, 3.0])
    store.close()
    with open(path, "a") as f:
        f.write('{"k": "dead')           # the kill's half-append
    survivor = JsonlCacheStore(path)
    assert len(survivor) == 3
    # the torn fragment was truncated: new appends start on a fresh line
    survivor.put(cache.key(np.full(3, 9.0)), 9.0)
    survivor.close()
    assert len(JsonlCacheStore(path)) == 4


def test_sqlite_store_round_trips(tmp_path):
    path = str(tmp_path / "cache.sqlite")
    cache = EvalCache(fingerprint="sq")
    store = SqliteCacheStore(path, flush_every=2)
    _fill(store, cache, [0.1, 7.0])
    assert store.put(cache.key(np.full(3, 0.0)), 99.0) is False  # absent-only
    store.close()
    loaded = SqliteCacheStore(path)
    assert loaded.get(cache.key(np.full(3, 0.0))) == 0.1
    assert len(loaded) == 2
    loaded.close()


# -- server composition: warm cache after crash + restore ---------------------

@pytest.mark.server
def test_crashed_server_restores_warm_and_bit_identical(tmp_path):
    """The §10 recovery contract: a crashed run's cache store survives in
    the checkpoint dir; the restored process warms from it, serves the
    re-leased in-flight points it already paid for, and still commits
    bit-identical iterates to an uninterrupted cache-off run."""
    from repro.server import protocol
    from repro.server.checkpoint import eval_cache_path
    from repro.server.server import WorkServer
    from repro.server.sim import (ServerSubstrate, SimulatedCrash,
                                  smoke_problem)

    spec, fleet, f_batch = smoke_problem(n_stars=120, n_hosts=64, m=12,
                                         iterations=3)
    be = InProcessEvalBackend(f_batch)
    base = ServerSubstrate(spec, fleet, be).run()

    ckpt = str(tmp_path / "ckpt")
    fp = "smoke-cache"
    crashed = EvalCache(JsonlCacheStore(eval_cache_path(ckpt)),
                        fingerprint=fp)
    sub = ServerSubstrate(
        spec, fleet, be, ckpt_dir=ckpt, snapshot_every=50,
        max_messages=int(0.4 * base.pool.messages), cache=crashed)
    with pytest.raises(SimulatedCrash):
        sub.run()
    assert crashed.stats.stores > 0

    # a fresh process: reload the surviving store from the checkpoint dir
    warm = EvalCache(JsonlCacheStore(eval_cache_path(ckpt)),
                     fingerprint=fp)
    assert len(warm.store) > 0
    sub2 = ServerSubstrate(spec, fleet, be, ckpt_dir=ckpt,
                           snapshot_every=50, cache=warm)
    res = sub2.run(resume=True)
    assert identical_trajectories(res.engines[0], base.engines[0])
    assert res.engines[0].stats == base.engines[0].stats
    assert warm.stats.hits > 0              # the warm cache actually served
    assert res.cache["hits"] == warm.stats.hits

    # ... and the wire status surfaces the counters (satellite)
    srv = WorkServer([spec])
    assert srv.handle(protocol.status())["cache"] is None
    srv.attach_cache(warm)
    assert srv.handle(protocol.status())["cache"] == warm.status()
