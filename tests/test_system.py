"""End-to-end behaviour tests for the paper's system.

1. ANM on the (synthetic) SDSS stream-fitting problem — the paper's own
   workload — beats its starting point and approaches the generating truth.
2. The full FGDO volunteer-grid path converges under faults.
3. ANM uses dramatically fewer *iterations* than CGD from the same start
   (the paper's headline claim, §VI).
4. The training driver round-trips through a simulated crash + restart.
5. The roofline HLO parser extracts collective bytes from real HLO text.
"""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper_anm
from repro.core.anm import AnmConfig, anm_minimize
from repro.core.fgdo import FgdoAnmServer
from repro.core.grid import GridConfig, VolunteerGrid
from repro.data import sdss
from repro.optim.cgd import cgd_minimize

SMOKE = paper_anm.smoke()


@pytest.fixture(scope="module")
def stripe():
    return sdss.make_stripe("test-stripe", n_stars=2500, seed=17)


@pytest.fixture(scope="module")
def fitness(stripe):
    return sdss.make_fitness(stripe)


def _start_point(stripe, scale=0.25, seed=5):
    rng = np.random.default_rng(seed)
    x0 = stripe.truth + rng.normal(0, scale, 8).astype(np.float32) * \
        (sdss.HI - sdss.LO) * 0.25
    return np.clip(x0, sdss.LO, sdss.HI)


def test_anm_fits_stream_model(stripe, fitness):
    f_batch, f_single = fitness
    x0 = _start_point(stripe)
    f0 = float(f_single(jnp.asarray(x0)))
    f_truth = float(f_single(jnp.asarray(stripe.truth)))
    state = anm_minimize(
        f_batch, x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
        AnmConfig(m_regression=200, m_line_search=200, max_iterations=15),
        jax.random.key(0))
    # recover >= 60% of the optimality gap (preliminary-results standard)
    assert state.best_fitness < f0 - 0.6 * (f0 - f_truth)


def test_fgdo_grid_on_stream_problem(stripe, fitness):
    _, f_single = fitness
    x0 = _start_point(stripe, seed=6)
    f0 = float(f_single(jnp.asarray(x0)))
    f_truth = float(f_single(jnp.asarray(stripe.truth)))
    server = FgdoAnmServer(
        x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
        AnmConfig(m_regression=100, m_line_search=100, max_iterations=8),
        seed=2)
    grid = VolunteerGrid(lambda p: float(f_single(jnp.asarray(p, jnp.float32))),
                         GridConfig(n_hosts=48, failure_prob=0.1,
                                    malicious_prob=0.03, seed=3))
    grid.run(server)
    assert server.best_fitness < f0 - 0.5 * (f0 - f_truth)


def test_anm_beats_cgd_iteration_count(stripe, fitness):
    """Paper §VI: CGD takes 'hundreds of iterations'; ANM 5–20.

    Statistical claim → aggregated over three starts.  Both methods get the
    same user step vector (paper §II/§III); iterations count toward a 50%
    optimality-gap target; a start that never reaches target costs the
    method its iteration cap."""
    f_batch, f_single = fitness
    fnp = lambda p: float(f_single(jnp.asarray(p, jnp.float32)))
    f_truth = float(f_single(jnp.asarray(stripe.truth)))
    CAP_ANM, CAP_CGD = 20, 60
    anm_total, cgd_total, anm_hits = 0, 0, 0
    for seed in [11, 23, 99]:
        rng = np.random.default_rng(seed)
        x0 = np.clip(stripe.truth + rng.normal(0, 1.0, 8).astype(np.float32)
                     * (sdss.HI - sdss.LO) * 0.15, sdss.LO, sdss.HI)
        f0 = fnp(x0)
        target = f0 - 0.5 * (f0 - f_truth)
        state = anm_minimize(
            f_batch, x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
            AnmConfig(m_regression=150, m_line_search=150,
                      max_iterations=CAP_ANM),
            jax.random.key(1))
        it = next((r.iteration for r in state.history
                   if r.best_fitness <= target), None)
        anm_total += it if it is not None else CAP_ANM
        anm_hits += it is not None
        cgd = cgd_minimize(fnp, x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
                           max_iterations=CAP_CGD)
        cit = next((i for i, v in enumerate(cgd.history) if v <= target), None)
        cgd_total += cit if cit is not None else CAP_CGD
    assert anm_hits >= 2, "ANM should reach target from most starts"
    assert anm_total < cgd_total, (anm_total, cgd_total)


def test_train_crash_restart(tmp_path):
    """Simulated node failure mid-run; restart resumes from checkpoint."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    ckdir = str(tmp_path / "ck")
    r1 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--preset", "tiny",
         "--steps", "12", "--ckpt-every", "4", "--ckpt-dir", ckdir,
         "--crash-at", "9", "--batch", "2", "--seq", "32"],
        capture_output=True, text=True, env=env, cwd="/root/repo", timeout=600)
    assert r1.returncode == 42, r1.stdout + r1.stderr
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--preset", "tiny",
         "--steps", "12", "--ckpt-every", "4", "--ckpt-dir", ckdir,
         "--resume", "--batch", "2", "--seq", "32"],
        capture_output=True, text=True, env=env, cwd="/root/repo", timeout=600)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 8" in r2.stdout
    assert '"step": 12' in r2.stdout


def test_collective_parser_on_hlo_text():
    from repro.roofline.analysis import collective_bytes_from_hlo
    hlo = """
HloModule jit_step
  %p = bf16[16,4096]{1,0} parameter(0)
  %ar = bf16[16,4096]{1,0} all-reduce(%p), replica_groups={}
  %ag = f32[64,128]{1,0} all-gather(%x), dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = u8[1024]{0} collective-permute(%z)
  %add = bf16[16,4096]{1,0} add(%ar, %ar)
"""
    st = collective_bytes_from_hlo(hlo)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["all-reduce"] == 2 * 16 * 4096 * 2  # 2x ring
    assert st.bytes_by_kind["all-gather"] == 64 * 128 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 4 * 128 * 4
    assert st.bytes_by_kind["collective-permute"] == 1024
    assert st.count_by_kind["all-to-all"] == 0
