"""FGDO runtime tests: asynchrony, fault tolerance, validation, determinism."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.anm import AnmConfig
from repro.core.fgdo import FgdoAnmServer
from repro.core.grid import GridConfig, VolunteerGrid


def _quad_problem(n=4, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n))
    H = A @ A.T + n * np.eye(n)
    x_opt = rng.uniform(-0.5, 0.5, n)

    def f(x):
        d = np.asarray(x, np.float64) - x_opt
        return float(0.5 * d @ H @ d)

    return f, x_opt, n


def _run(f, n, grid_cfg, anm_cfg=None, seed=1):
    server = FgdoAnmServer(
        x0=np.ones(n), lo=-10 * np.ones(n), hi=10 * np.ones(n),
        step=0.5 * np.ones(n),
        cfg=anm_cfg or AnmConfig(m_regression=80, m_line_search=80,
                                 max_iterations=6),
        seed=seed)
    grid = VolunteerGrid(f, grid_cfg)
    grid.run(server)
    return server, grid


def test_converges_on_reliable_grid():
    f, x_opt, n = _quad_problem()
    server, _ = _run(f, n, GridConfig(n_hosts=32, failure_prob=0.0,
                                      malicious_prob=0.0, seed=2))
    assert server.best_fitness < 1e-2 * f(np.ones(n))
    assert server.iteration >= 3


def test_fault_tolerance_failures_and_malice():
    """20% of results never return + 10% malicious hosts: still converges,
    stale results are discarded, corrupted candidates rejected by quorum."""
    f, x_opt, n = _quad_problem(seed=3)
    server, grid = _run(f, n, GridConfig(n_hosts=48, failure_prob=0.2,
                                         malicious_prob=0.1, seed=5))
    assert server.best_fitness < 5e-2 * f(np.ones(n))
    assert grid.stats.failed > 0
    assert grid.stats.corrupted > 0
    # malicious "best" line-search results must have been caught at least once
    # (they under-report fitness by 20-80%, far outside validation rtol)
    assert server.stats.validations_failed >= 1


def test_determinism():
    f, _, n = _quad_problem(seed=7)
    cfg = GridConfig(n_hosts=24, failure_prob=0.1, malicious_prob=0.05, seed=9)
    s1, _ = _run(f, n, cfg, seed=11)
    s2, _ = _run(f, n, cfg, seed=11)
    assert s1.best_fitness == s2.best_fitness
    assert [r.best_fitness for r in s1.history] == \
        [r.best_fitness for r in s2.history]
    np.testing.assert_array_equal(s1.center, s2.center)


def test_phase_advances_on_first_m_results():
    """The server must never wait for stragglers: with heterogeneity spread
    over 100x speeds, iterations still complete (stale > 0 proves late
    results arrived after phase advance and were dropped, not blocking)."""
    f, _, n = _quad_problem(seed=8)
    server, grid = _run(f, n, GridConfig(n_hosts=64, speed_sigma=1.5,
                                         failure_prob=0.05,
                                         malicious_prob=0.0, seed=13))
    assert server.done
    assert server.stats.stale > 0
    assert server.best_fitness < 0.1 * f(np.ones(n))


def test_malicious_best_rejected():
    """A malicious result that would win the line search must be caught by
    quorum validation and the next-best candidate promoted (paper §V /
    FGDO validation-reduction)."""
    calls = {"n": 0}

    def f(x):
        return float(np.sum(np.asarray(x) ** 2))

    server = FgdoAnmServer(x0=np.ones(2), lo=-5 * np.ones(2), hi=5 * np.ones(2),
                           step=0.3 * np.ones(2),
                           cfg=AnmConfig(m_regression=30, m_line_search=30,
                                         max_iterations=1),
                           seed=1, validation_quorum=2)
    # drive manually: bootstrap f(x0), then regression with honest results
    now = 0.0
    while server.phase in ("bootstrap", "regression"):
        wu = server.generate_work(0, now)
        server.assimilate(wu, f(wu.point), 0, now)
        now += 1
    # line-search phase: honest results, then one lying "perfect" result
    wus = [server.generate_work(0, now + i) for i in range(29)]
    lie_wu = server.generate_work(0, now + 30)
    for i, wu in enumerate(wus):
        server.assimilate(wu, f(wu.point), 0, now + i)
    server.assimilate(lie_wu, -1000.0, 666, now + 31)      # malicious winner
    assert server.validating
    # quorum re-evaluations return the TRUTH for the lying point
    while server.validating and not server.done:
        wu = server.generate_work(1, now)
        if wu is None:
            break
        server.assimilate(wu, f(wu.point), 1, now)
        now += 1
    assert server.stats.validations_failed >= 1
    # committed fitness must be a real value, not the lie
    assert server.history[-1].best_fitness > -100.0


def test_new_host_cold_start_grace():
    """The return-rate gate must NOT exclude a brand-new host: with 1
    issued / 0 returned it sits at a 0% return rate it never had a chance
    to improve, so the gate only engages after ``min_issued_for_rate``
    (default 4) issues.  Regression pin for the reliable-host cold start:
    below the threshold the host keeps receiving work (validation work
    included), at the threshold with nothing returned it stops."""
    def f(x):
        return float(np.sum(np.asarray(x) ** 2))

    server = FgdoAnmServer(x0=np.ones(2), lo=-5 * np.ones(2),
                           hi=5 * np.ones(2), step=0.3 * np.ones(2),
                           cfg=AnmConfig(m_regression=30, m_line_search=30,
                                         max_iterations=1),
                           seed=1, validation_quorum=2)
    now = 0.0
    worker, rookie, blackhole = 1, 42, 66
    # drive to the LINE-SEARCH validation (the bootstrap probe has its own
    # earlier quorum round, during which the rookies aren't fed yet)
    while not (server.validating and not server.engine.bootstrapping):
        # the rookie picks up 3 workunits it hasn't returned YET; the
        # black hole grabs 5 and will never return any
        if server.phase in ("regression", "linesearch"):
            if server._host_issued.get(rookie, 0) < 3:
                server.generate_work(rookie, now)
            if server._host_issued.get(blackhole, 0) < 5:
                server.generate_work(blackhole, now)
        wu = server.generate_work(worker, now)
        if wu is not None:
            server.assimilate(wu, f(wu.point), worker, now + 1.0)
        now += 1
    assert server._host_issued[rookie] == 3
    assert server._host_returned.get(rookie, 0) == 0
    assert server._host_issued[blackhole] == 5
    # 3 issued / 0 returned is INSIDE the grace window: the rookie stays
    # eligible and actually receives a validation replica ...
    assert server._host_returns(rookie)
    assert server.generate_work(rookie, now) is not None
    # ... while 5 issued / 0 returned is past it: gate engaged
    assert not server._host_returns(blackhole)
    assert server.generate_work(blackhole, now) is None


def test_vanishing_fast_host_loses_reliable_status():
    """A host that takes work and never returns must stop receiving
    latency-critical validation replicas.  Turnaround tracking alone is
    failure-blind: a vanishing host records NO turnaround, so it stayed
    'reliable' forever before the return-rate guard."""
    def f(x):
        return float(np.sum(np.asarray(x) ** 2))

    server = FgdoAnmServer(x0=np.ones(2), lo=-5 * np.ones(2),
                           hi=5 * np.ones(2), step=0.3 * np.ones(2),
                           cfg=AnmConfig(m_regression=30, m_line_search=30,
                                         max_iterations=1),
                           seed=1, validation_quorum=2)
    now = 0.0
    black_hole = 0                 # the fast host that drops everything
    workers = [1, 2, 3, 4]
    # interleave: the black hole grabs work instantly and never returns it;
    # honest workers complete the phases
    while server.phase in ("bootstrap", "regression"):
        server.generate_work(black_hole, now)              # vanishes
        h = workers[int(now) % len(workers)]
        wu = server.generate_work(h, now)
        if wu is not None:
            server.assimilate(wu, f(wu.point), h, now + 1.0)
        now += 1
    while server.phase == "linesearch" and not server.validating:
        server.generate_work(black_hole, now)              # vanishes
        h = workers[int(now) % len(workers)]
        wu = server.generate_work(h, now)
        if wu is not None:
            server.assimilate(wu, f(wu.point), h, now + 1.0)
        now += 1
    assert server.validating
    assert server._host_issued[black_hole] >= 4
    assert server._host_returned.get(black_hole, 0) == 0
    # the black hole is refused validation work; a returning host is not
    assert not server._host_reliable(black_hole)
    assert server.generate_work(black_hole, now) is None
    assert server.generate_work(workers[0], now) is not None
