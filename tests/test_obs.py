"""Observability-plane tests (DESIGN.md §13).

The §13 contract under test: attaching the metrics hub, a live
``subscribe_stats`` subscriber, or the anomaly-driven fleet defense must
never change what the engines commit — observed runs (including under
chaos fault plans) are bit-identical to unobserved ones, monitoring
messages are stamp-free and never logged, and a defended run is
solo-reproducible from its recorded anomaly schedule.  The supporting
layers get their own pins: hub ring/cursor semantics, probe rates,
registry churn counters + cold-start "warming" accounting, quarantine
gates, one-page-per-cohort-transition, and the rate-detector latches.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.engine import identical_trajectories
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.obs import (PAGE, QUARANTINE, RELEASE, STREAM_VERSION,
                       FleetDefense, MetricsHub)
from repro.server import protocol
from repro.server.registry import DEAD, SUSPECT, HostRegistry
from repro.server.server import SequencedIntake, WorkServer
from repro.server.sim import ServerSubstrate, smoke_problem

pytestmark = pytest.mark.obs


# -- shared small workload -----------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    return smoke_problem(n_stars=120, n_hosts=40, m=10, iterations=2)


@pytest.fixture(scope="module")
def backend(problem):
    _, _, f_batch = problem
    return InProcessEvalBackend(f_batch)


@pytest.fixture(scope="module")
def baseline(problem, backend):
    spec, fleet, _ = problem
    return ServerSubstrate(spec, fleet, backend).run()


def _same(a, b):
    ea, eb = a.engines[0], b.engines[0]
    return identical_trajectories(ea, eb) and ea.stats == eb.stats


# -- MetricsHub ----------------------------------------------------------------

class TestMetricsHub:
    def test_counters_and_probe_groups(self):
        hub = MetricsHub(interval=5.0)
        hub.inc("widgets")
        hub.inc("widgets", 2)
        assert hub.counter("widgets") == 3
        hub.register_probe("layer", lambda: {"depth": np.int64(4),
                                             17: "int-key"})
        snap = hub.sample(0.0)
        assert snap["stream_v"] == STREAM_VERSION
        assert snap["counters"]["widgets"] == 3
        # codec-proofing: numpy scalars become python ints, dict keys
        # become strings (msgpack would keep int keys, JSON would not)
        assert snap["groups"]["layer"]["depth"] == 4
        assert type(snap["groups"]["layer"]["depth"]) is int
        assert snap["groups"]["layer"]["17"] == "int-key"

    def test_maybe_sample_interval_is_virtual_time(self):
        hub = MetricsHub(interval=10.0)
        assert hub.maybe_sample(3.0) is not None    # first call samples
        assert hub.maybe_sample(5.0) is None
        assert hub.maybe_sample(12.9) is None
        assert hub.maybe_sample(13.0) is not None
        assert hub.seq == 2

    def test_rates_derived_from_snapshot_deltas(self):
        hub = MetricsHub(interval=1.0)
        state = {"messages": 0}
        hub.register_probe("srv", lambda: dict(state), rates=("messages",))
        hub.sample(0.0)
        state["messages"] = 50
        snap = hub.sample(10.0)
        assert snap["groups"]["srv"]["messages_per_s"] == pytest.approx(5.0)

    def test_ring_bounds_memory_and_cursor_resumes(self):
        hub = MetricsHub(interval=1.0, ring=8)
        for t in range(20):
            hub.sample(float(t))
        assert hub.seq == 20
        snaps, cursor, dropped = hub.since(-1)
        # fell off the ring: resume at the oldest retained snapshot, and
        # the reply SAYS how many were lost rather than silently skipping
        assert [s["seq"] for s in snaps] == list(range(12, 20))
        assert cursor == 19
        assert dropped == 12
        again, cursor2, d2 = hub.since(cursor)
        assert again == [] and cursor2 == 19 and d2 == 0
        hub.sample(20.0)
        fresh, cursor3, d3 = hub.since(cursor2)
        assert [s["seq"] for s in fresh] == [20] and cursor3 == 20
        assert d3 == 0

    def test_series_and_on_sample_callbacks(self):
        hub = MetricsHub(interval=1.0)
        depth = {"v": 0}
        hub.register_probe("g", lambda: {"depth": depth["v"]})
        seen = []
        hub.on_sample(lambda s: seen.append(s["seq"]))
        for t in range(3):
            depth["v"] = t * t
            hub.sample(float(t))
        assert hub.series("g", "depth") == [(0.0, 0.0), (1.0, 1.0),
                                            (2.0, 4.0)]
        assert seen == [0, 1, 2]


# -- registry churn, warming, quarantine ---------------------------------------

class TestRegistryChurn:
    def test_transition_counters_count_each_edge(self):
        reg = HostRegistry(suspect_after=10.0, dead_after=100.0)
        for h in range(3):
            reg.touch(h, 0.0)
        reg.sweep(50.0)                   # all alive -> suspect
        assert reg.churn_to_suspect == 3 and reg.churn_to_dead == 0
        reg.sweep(60.0)                   # still suspect: NOT recounted
        assert reg.churn_to_suspect == 3
        reg.sweep(200.0)                  # suspect -> dead
        assert reg.churn_to_dead == 3
        reg.touch(1, 201.0)               # any contact revives
        assert reg.churn_revived == 1
        assert reg.hosts[1].state == "alive"
        reg.sweep(300.0)                  # host 1 decays again
        assert reg.churn_to_suspect == 4
        d = reg.summary()["churn"]
        assert d == {"to_suspect": 4, "to_dead": 3, "revived": 1}

    def test_churn_counters_survive_state_roundtrip(self):
        reg = HostRegistry(suspect_after=1.0, dead_after=10.0)
        reg.touch(0, 0.0)
        reg.sweep(5.0)
        reg.quarantine(0)
        clone = HostRegistry()
        clone.load_state(reg.state_dict())
        assert clone.churn_to_suspect == 1
        assert clone.hosts[0].quarantined
        assert not clone.reliable(0)

    def test_pre_obs_snapshot_loads_with_default_quarantine(self):
        reg = HostRegistry()
        reg.touch(3, 1.0)
        state = reg.state_dict()
        del state["churn"]                # pre-obs snapshots have neither
        del state["hosts"]["3"]["quarantined"]
        clone = HostRegistry()
        clone.load_state(state)
        assert clone.churn_to_suspect == 0
        assert not clone.hosts[3].quarantined

    def test_warming_hosts_counted_not_omitted(self):
        reg = HostRegistry(min_latency_samples=2)
        for h in range(4):
            reg.touch(h, 0.0)
        reg.on_result(0, 1.0, turnaround=5.0)
        s = reg.summary()
        # the cold-start fix: hosts with no EWMA yet are "warming" and
        # still inside the reliable-set gauge (benefit of the doubt),
        # not silently dropped from it
        assert s["warming"] == 3
        assert s["reliable_set"] == 4

    def test_reliable_set_matches_per_host_gate(self):
        rng = np.random.default_rng(5)
        reg = HostRegistry(min_latency_samples=3)
        for h in range(12):
            reg.touch(h, 0.0)
            for _ in range(int(rng.integers(0, 4))):
                reg.on_issue(h, 1.0)
            if rng.random() < 0.7:
                reg.on_result(h, 2.0, turnaround=float(rng.uniform(1, 50)))
        reg.quarantine(5)
        expect = sorted(h for h in reg.hosts if reg.reliable(h))
        assert reg.reliable_set() == expect

    def test_quarantine_gates_reliable_and_is_idempotent(self):
        reg = HostRegistry()
        reg.touch(0, 0.0)
        assert reg.reliable(0)
        assert reg.quarantine(0) is True
        assert reg.quarantine(0) is False      # re-page is a no-op
        assert not reg.reliable(0)
        assert reg.release(0) is True
        assert reg.release(0) is False
        assert reg.reliable(0)


# -- anomaly detection + paging ------------------------------------------------

def _registry_hub(reg, interval=1.0):
    hub = MetricsHub(interval=interval)
    hub.register_probe("registry", lambda: {
        **reg.summary(), "suspect_ids": reg.ids(SUSPECT),
        "dead_ids": reg.ids(DEAD)})
    return hub


class TestFleetDefense:
    def test_pages_exactly_once_per_cohort_transition(self):
        reg = HostRegistry(suspect_after=10.0, dead_after=1000.0)
        hub = _registry_hub(reg)
        defense = FleetDefense(reg, hub)
        for h in range(4):
            reg.touch(h, 0.0)
        reg.sweep(20.0)
        hub.sample(20.0)
        assert [e.action for e in defense.events] == [QUARANTINE]
        assert defense.events[0].hosts == [0, 1, 2, 3]
        assert all(not reg.reliable(h) for h in range(4))
        hub.sample(21.0)                  # cohort still down: no re-page
        hub.sample(22.0)
        assert len(defense.events) == 1
        reg.touch(0, 23.0)                # revival
        hub.sample(23.0)
        assert [e.action for e in defense.events] == [QUARANTINE, RELEASE]
        assert defense.events[1].hosts == [0]
        assert reg.reliable(0)
        hub.sample(24.0)                  # no double-release
        assert len(defense.events) == 2
        reg.sweep(40.0)                   # host 0 decays AGAIN
        hub.sample(40.0)                  # fresh transition: pages again
        assert [e.action for e in defense.events] == \
            [QUARANTINE, RELEASE, QUARANTINE]
        assert defense.events[2].hosts == [0]

    def test_rate_detectors_latch_on_edges(self):
        reg_doc = {"returned": 0, "stale_returns": 0}
        srv_doc = {"duplicate_reports": 0}
        cache_doc = {"hit_rate": 0.9}
        hub = MetricsHub(interval=1.0)
        hub.register_probe("registry", lambda: {**reg_doc,
                                                "suspect_ids": [],
                                                "dead_ids": []})
        hub.register_probe("server", lambda: dict(srv_doc))
        hub.register_probe("cache", lambda: dict(cache_doc))
        defense = FleetDefense(HostRegistry(), hub, stale_rate_spike=0.5,
                               dup_spike=3, hit_rate_floor=0.2)
        hub.sample(0.0)                   # baseline window
        reg_doc.update(returned=10, stale_returns=8)
        hub.sample(1.0)
        kinds = [e.kind for e in defense.events]
        assert kinds == ["stale_spike"]
        reg_doc.update(returned=20, stale_returns=16)
        hub.sample(2.0)                   # sustained spike: still latched
        assert [e.kind for e in defense.events] == ["stale_spike"]
        reg_doc.update(returned=30, stale_returns=16)
        hub.sample(3.0)                   # clears -> re-arms
        reg_doc.update(returned=40, stale_returns=26)
        srv_doc["duplicate_reports"] = 10
        cache_doc["hit_rate"] = 0.05      # collapse after having been high
        hub.sample(4.0)
        kinds = sorted(e.kind for e in defense.events)
        assert kinds == ["cache_collapse", "dup_spike", "stale_spike",
                         "stale_spike"]
        assert all(e.action == PAGE and e.hosts == []
                   for e in defense.events)

    def test_cache_collapse_needs_prior_health(self):
        hub = MetricsHub(interval=1.0)
        cache_doc = {"hit_rate": 0.0}
        hub.register_probe("cache", lambda: dict(cache_doc))
        defense = FleetDefense(HostRegistry(), hub, hit_rate_floor=0.2)
        hub.sample(0.0)
        hub.sample(1.0)
        # a cache that was NEVER healthy (cold start) is not a collapse
        assert defense.events == []

    def test_schedule_roundtrips_and_replay_applies_gate_actions(self):
        reg = HostRegistry(suspect_after=10.0, dead_after=1000.0)
        hub = _registry_hub(reg)
        live = FleetDefense(reg, hub)
        for h in range(3):
            reg.touch(h, 0.0)
        reg.sweep(20.0)
        hub.sample(20.0)
        doc = live.schedule_doc()
        assert doc["v"] == 1 and len(doc["events"]) == 1

        reg2 = HostRegistry(suspect_after=10.0, dead_after=1000.0)
        hub2 = _registry_hub(reg2)
        replay = FleetDefense.replay(reg2, hub2, doc)
        assert not replay.live
        for h in range(3):
            reg2.touch(h, 0.0)
        hub2.sample(5.0)                  # seq 0: the recorded event fires
        assert [e.action for e in replay.events] == [QUARANTINE]
        assert all(not reg2.reliable(h) for h in range(3))
        assert replay.summary()["mode"] == "replay"

    def test_replay_rejects_wrong_schedule_version(self):
        hub = MetricsHub(interval=1.0)
        with pytest.raises(ValueError, match="version"):
            FleetDefense.replay(HostRegistry(), hub,
                                {"v": 99, "events": []})


# -- the wire extension + stamp neutrality -------------------------------------

class TestSubscribeStats:
    def _server(self, problem, with_hub=True):
        spec, fleet, _ = problem
        srv = WorkServer([spec], lease_timeout=8.0 * fleet.base_eval_time,
                         idle_retry=fleet.idle_retry)
        hub = None
        if with_hub:
            hub = MetricsHub(interval=5.0)
            srv.attach_hub(hub)
        return srv, hub

    def test_error_reply_without_hub(self, problem):
        srv, _ = self._server(problem, with_hub=False)
        rep = srv.handle(protocol.subscribe_stats())
        assert rep["kind"] == "error"

    def test_cursor_long_poll_over_the_handler(self, problem):
        srv, hub = self._server(problem)
        srv.handle(protocol.register(0, 1.0, cs=0))
        srv.handle(protocol.request_work(0, 1.0, cs=1))
        rep = srv.handle(protocol.subscribe_stats(-1))
        assert rep["kind"] == "stats" and rep["stream_v"] == STREAM_VERSION
        assert len(rep["snapshots"]) >= 1
        assert rep["snapshots"][0]["groups"]["server"]["messages"] >= 1
        assert "lease_depth" in rep["snapshots"][0]["groups"]["server"]
        cursor = rep["cursor"]
        again = srv.handle(protocol.subscribe_stats(cursor))
        assert again["snapshots"] == [] and again["cursor"] == cursor

    def test_monitoring_is_unstamped_uncounted_unlogged(self, problem,
                                                        tmp_path):
        from repro.server.checkpoint import CheckpointManager
        srv, hub = self._server(problem)
        mgr = CheckpointManager(str(tmp_path / "ckpt"), snapshot_every=10)
        msg = protocol.register(0, 1.0, cs=0)
        srv.handle(msg)
        mgr.record(msg, srv)
        before_messages = srv.counters.messages
        before_seq = hub.seq
        rep = srv.handle(protocol.subscribe_stats(-1))
        mgr.record({"kind": "subscribe_stats", "since": -1}, srv)
        assert rep["kind"] == "stats"
        # a monitoring poll consumes nothing: no message count, no log
        # record, no extra hub sample, and last_applied stays False so
        # even the fallback logging path would skip it
        assert srv.counters.messages == before_messages
        assert hub.seq == before_seq
        assert srv.last_applied is False
        assert mgr.seq == 1               # only the register was logged
        mgr.close()

    def test_sequenced_intake_handles_unstamped_poll_inline(self, problem):
        srv, hub = self._server(problem)
        intake = SequencedIntake(srv.handle)
        srv.attach_intake(intake)
        rep = intake.submit(protocol.subscribe_stats(-1))
        assert rep["kind"] == "stats"
        assert intake.next_seq == 0       # no stamp consumed
        # the status satellite: service pressure rides the status reply
        status = intake.submit(protocol.status())
        assert status["intake"] == {"next_seq": 0, "parked": 0,
                                    "out_of_band": 0}
        assert "leases" in status

    def test_status_intake_is_none_without_intake(self, problem):
        srv, _ = self._server(problem)
        assert srv.handle(protocol.status())["intake"] is None


# -- observed-run parity (the tentpole gate) -----------------------------------

class TestObservedParity:
    def test_observed_serial_run_is_bit_identical(self, problem, backend,
                                                  baseline):
        spec, fleet, _ = problem
        res = ServerSubstrate(spec, fleet, backend, obs=True,
                              stats_interval=10.0).run()
        assert _same(baseline, res)
        assert res.obs["snapshots"] >= 2

    @pytest.mark.parametrize("preset", ["drop_dup", "reset_torn"])
    def test_observed_subscribed_chaos_run_is_bit_identical(
            self, problem, backend, baseline, preset):
        spec, fleet, _ = problem
        res = ServerSubstrate(spec, fleet, backend, obs=True,
                              subscribe=True, stats_interval=10.0,
                              transport="tcp", concurrent=4,
                              chaos=preset).run()
        assert _same(baseline, res)
        assert res.subscriber["snapshots"] >= 2
        assert res.subscriber["stamped_ok"]
        assert not res.subscriber["errors"]

    def test_defense_shrinks_reliable_set_and_replays_identically(
            self, problem, backend):
        spec, fleet, _ = problem
        silence = dict(silence_at=120.0, silence_frac=0.25)
        undefended = ServerSubstrate(spec, fleet, backend, **silence).run()
        defended = ServerSubstrate(spec, fleet, backend, defense=True,
                                   stats_interval=10.0, **silence).run()
        d = defended.defense
        assert d["mode"] == "live" and d["quarantined_now"] > 0
        assert (defended.server.registry.summary()["reliable_set"]
                < undefended.server.registry.summary()["reliable_set"])
        replayed = ServerSubstrate(spec, fleet, backend,
                                   defense_schedule=d["schedule"],
                                   stats_interval=10.0, **silence).run()
        assert _same(defended, replayed)
        assert replayed.defense["mode"] == "replay"
        assert (replayed.defense["quarantined_now"]
                == d["quarantined_now"])


# -- dashboard rendering -------------------------------------------------------

class TestDashboard:
    def test_render_is_pure_and_complete(self):
        from repro.launch.obs_dashboard import render, sparkline
        snap = {"stream_v": 1, "seq": 7, "now": 123.4, "counters": {},
                "groups": {
                    "server": {"messages": 99, "messages_per_s": 4.5,
                               "lease_depth": 3, "lapsed_depth": 1,
                               "searches": [{"search_id": 0,
                                             "status": "running",
                                             "phase": "regression",
                                             "iteration": 2,
                                             "best": 1.25}]},
                    "registry": {"hosts": 8,
                                 "states": {"alive": 6, "suspect": 2,
                                            "dead": 0},
                                 "warming": 1, "reliable_set": 5,
                                 "quarantined": 2,
                                 "churn": {"to_suspect": 2, "to_dead": 0,
                                           "revived": 0}}}}
        out = render(snap, [1.0, 2.0, 4.5])
        for needle in ("seq=7", "99 messages", "4.5 msg/s", "3 leases",
                       "suspect 2", "quarantined 2", "phase=regression",
                       "best=1.250000"):
            assert needle in out, needle
        assert sparkline([]) == ""
        assert len(sparkline(list(range(100)), width=24)) == 24
        assert sparkline([5.0, 5.0]) == "▁▁"    # flat series: no div-by-0
