"""Checkpoint/restart, retention, resharding, and data-pipeline tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck
from repro.data.pipeline import DataConfig, SyntheticLM


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": {"w": jax.random.normal(k1, (8, 4), jnp.bfloat16)},
            "b": [jax.random.normal(k2, (3,)), jnp.int32(7)]}


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.key(0))
    ck.save(str(tmp_path), 5, tree, extras={"note": "x"})
    got, step, extras = ck.restore(str(tmp_path), tree)
    assert step == 5 and extras["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_retention(tmp_path):
    tree = _tree(jax.random.key(1))
    for s in [1, 2, 3, 4, 5]:
        ck.save(str(tmp_path), s, tree, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_template_mismatch_raises(tmp_path):
    tree = _tree(jax.random.key(2))
    ck.save(str(tmp_path), 1, tree)
    bad = {"a": tree["a"]}
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), bad)


def test_restore_with_sharding_placement(tmp_path):
    """Elastic restore: leaves are placed onto provided shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(str(tmp_path), 3, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _, _ = ck.restore(str(tmp_path), tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_resume_is_bitwise_identical(tmp_path):
    """Train 6 steps straight vs. 3 + checkpoint + restore + 3: identical."""
    from repro.configs import get_smoke_config
    from repro.models import init_params, make_train_step
    from repro.optim.adamw import AdamW

    cfg = get_smoke_config("qwen2-72b")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=2, seed=3))
    opt = AdamW(lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def run(params, opt_state, lo, hi):
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
            params, opt_state, _ = step_fn(params, opt_state, batch)
        return params, opt_state

    p0 = init_params(cfg, jax.random.key(0))
    o0 = opt.init(p0)
    p_straight, _ = run(p0, o0, 0, 6)

    p3, o3 = run(p0, o0, 0, 3)
    ck.save(str(tmp_path), 3, {"params": p3, "opt": o3})
    restored, step, _ = ck.restore(str(tmp_path), {"params": p3, "opt": o3})
    p_resumed, _ = run(restored["params"], restored["opt"], step, 6)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_restart_purity():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=11)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for s in [0, 5, 117]:
        b1, b2 = d1.batch(s), d2.batch(s)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    b = d1.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_host_sharding_disjoint():
    full = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=5)
    h0 = SyntheticLM(DataConfig(vocab_size=1000, seq_len=32, global_batch=8,
                                seed=5, n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(vocab_size=1000, seq_len=32, global_batch=8,
                                seed=5, n_hosts=2, host_id=1))
    b0, b1 = h0.batch(3), h1.batch(3)
    assert b0["tokens"].shape == (4, 32)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
