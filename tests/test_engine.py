"""Tests for the unified AnmEngine and its substrates (DESIGN.md §1).

The refactor's contract: one phase machine, three substrates.  These tests
pin (1) sync/async parity — the synchronous driver and the FGDO adapter
reach the same optimum from the shared engine; (2) the explicit stale-phase
and failed-validation paths; (3) the vectorized batched-grid substrate
(convergence, determinism, actually-batched evaluation); (4) the kernel
routing of the regression's normal equations.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regression as reg
from repro.core.anm import AnmConfig, anm_minimize
from repro.core.engine import AnmEngine, EvalResult
from repro.core.fgdo import FgdoAnmServer
from repro.core.grid import GridConfig, VolunteerGrid
from repro.core.substrates.batched_grid import BatchedVolunteerGrid


def _quad_problem(n=4, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n))
    H = A @ A.T + n * np.eye(n)
    x_opt = rng.uniform(-0.5, 0.5, n)

    def f(x):
        d = np.asarray(x, np.float64) - x_opt
        return float(0.5 * d @ H @ d)

    def f_batch(xs):
        d = np.asarray(xs, np.float64) - x_opt[None, :]
        return jnp.asarray(0.5 * np.einsum("mi,ij,mj->m", d, H, d))

    return f, f_batch, x_opt, n


def _assimilate_all(engine, reqs, f):
    return engine.assimilate([EvalResult(r, f(r.point)) for r in reqs])


# -- sync/async parity ------------------------------------------------------

def test_sync_and_fgdo_reach_same_center():
    """Paper's core claim: the state machine is substrate-independent.  On a
    seeded convex quadratic with a faultless grid, the synchronous driver and
    the FGDO adapter (both thin layers over AnmEngine) converge to the same
    center."""
    f, f_batch, x_opt, n = _quad_problem(seed=42)
    cfg = AnmConfig(m_regression=80, m_line_search=80, max_iterations=8)

    state = anm_minimize(f_batch, np.ones(n), -10 * np.ones(n),
                         10 * np.ones(n), 0.5 * np.ones(n), cfg,
                         key=jax.random.key(0))

    server = FgdoAnmServer(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), cfg, seed=1)
    VolunteerGrid(f, GridConfig(n_hosts=32, failure_prob=0.0,
                                malicious_prob=0.0, seed=2)).run(server)

    c_sync = np.asarray(state.center, np.float64)
    c_async = server.center
    np.testing.assert_allclose(c_sync, x_opt, atol=5e-2)
    np.testing.assert_allclose(c_async, x_opt, atol=5e-2)
    np.testing.assert_allclose(c_sync, c_async, atol=5e-2)
    f0 = f(np.ones(n))
    assert state.best_fitness < 1e-3 * f0
    assert server.best_fitness < 1e-3 * f0


def test_sync_driver_runs_quorum_validation():
    """Unification gives the synchronous driver the validation path the old
    standalone implementation lacked: every commit is preceded by quorum
    re-evaluation of the winning point."""
    _, f_batch, _, n = _quad_problem(seed=5)
    hits = {"n": 0}

    def counting(xs):
        hits["n"] += 1
        return f_batch(xs)

    cfg = AnmConfig(m_regression=60, m_line_search=60, max_iterations=3)
    state = anm_minimize(counting, np.ones(n), -10 * np.ones(n),
                         10 * np.ones(n), 0.5 * np.ones(n), cfg,
                         key=jax.random.key(3))
    # per iteration: regression batch + line batch + >=1 quorum batch,
    # plus the initial f(x0) evaluation
    assert hits["n"] >= 3 * state.iteration + 1
    assert state.history, "driver must record committed iterations"


# -- explicit stale-phase path ----------------------------------------------

def test_stale_phase_results_are_discarded():
    f, _, _, n = _quad_problem(seed=1)
    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=4)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0)
    reqs = engine.generate(41)               # one more than the phase needs
    straggler = reqs[-1]
    _assimilate_all(engine, reqs[:40], f)    # phase advances at m=40
    assert engine.phase == "linesearch"
    buffered = len(engine.results)
    stale_before = engine.stats.stale
    _assimilate_all(engine, [straggler], f)  # late arrival from old phase
    assert engine.stats.stale == stale_before + 1
    assert len(engine.results) == buffered   # did not leak into the new phase
    assert engine.phase == "linesearch"


# -- explicit failed-validation path ----------------------------------------

def test_failed_validation_rejects_candidate_and_promotes_next():
    f, _, _, n = _quad_problem(seed=2)
    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=1)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0, validation_quorum=2)
    _assimilate_all(engine, engine.generate(), f)          # regression phase
    assert engine.phase == "linesearch"
    reqs = engine.generate()
    honest = [EvalResult(r, f(r.point)) for r in reqs[:-1]]
    lie = EvalResult(reqs[-1], -1e6)                       # malicious winner
    engine.assimilate(honest + [lie])
    assert engine.validating
    first_candidate = engine._candidate
    assert first_candidate[0] == -1e6, "the lie must rank first"
    # quorum replicas return the TRUTH for the lying point -> rejected
    while engine.validating and not engine.done:
        replicas = engine.generate()
        if not replicas:
            break
        _assimilate_all(engine, replicas, f)
    assert engine.stats.validations_failed >= 1
    assert engine.stats.candidates_rejected >= 1
    assert engine.history[-1].best_fitness > -1e5          # lie never committed


def test_lost_validation_replicas_can_be_reissued():
    f, _, _, n = _quad_problem(seed=3)
    cfg = AnmConfig(m_regression=30, m_line_search=30, max_iterations=1)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0, validation_quorum=2)
    _assimilate_all(engine, engine.generate(), f)
    _assimilate_all(engine, engine.generate(), f)
    if engine.done:                                        # committed already
        return
    assert engine.validating
    engine.generate()                                      # replicas... lost
    assert engine.validation_pending == 0
    assert engine.generate() == []                         # budget exhausted
    r1, r2 = engine.reissue_validation(), engine.reissue_validation()
    assert r1 is not None and r2 is not None
    _assimilate_all(engine, [r1, r2], f)
    assert engine.done and engine.iteration == 1


# -- batched grid substrate --------------------------------------------------

def _run_batched(n_hosts=512, seed=7, **grid_kw):
    f, f_batch, x_opt, n = _quad_problem(seed=11)
    cfg = AnmConfig(m_regression=60, m_line_search=60, max_iterations=6)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=seed)
    calls = {"n": 0, "pts": 0}

    def counting(xs):
        calls["n"] += 1
        calls["pts"] += xs.shape[0]
        return f_batch(xs)

    grid = BatchedVolunteerGrid(
        counting, GridConfig(n_hosts=n_hosts, seed=3, **grid_kw))
    stats = grid.run(engine)
    return engine, stats, calls, f, x_opt, n


def test_batched_grid_converges_and_batches():
    engine, stats, calls, f, x_opt, n = _run_batched(
        failure_prob=0.05, malicious_prob=0.01)
    assert engine.done
    assert engine.best_fitness < 1e-2 * f(np.ones(n))
    np.testing.assert_allclose(engine.center, x_opt, atol=0.1)
    # the point of the substrate: many results per fitness call
    assert calls["pts"] / max(calls["n"], 1) > 8
    assert stats.batch_calls == calls["n"]
    assert stats.completed > 0 and stats.failed > 0


def test_batched_grid_deterministic():
    e1, s1, *_ = _run_batched(failure_prob=0.1, malicious_prob=0.02)
    e2, s2, *_ = _run_batched(failure_prob=0.1, malicious_prob=0.02)
    assert e1.best_fitness == e2.best_fitness
    np.testing.assert_array_equal(e1.center, e2.center)
    assert s1.sim_time == s2.sim_time
    assert [r.best_fitness for r in e1.history] == \
        [r.best_fitness for r in e2.history]


def test_batched_grid_survives_malice():
    engine, stats, _, f, _, n = _run_batched(
        n_hosts=256, failure_prob=0.2, malicious_prob=0.1)
    assert stats.corrupted > 0
    assert engine.best_fitness < 5e-2 * f(np.ones(n))


# -- kernel-routed normal equations ------------------------------------------

def test_fit_quadratic_kernel_path_matches_jnp():
    rng = np.random.default_rng(0)
    n, m = 8, 512
    A = rng.normal(size=(n, n))
    H = (A + A.T) / 2
    g = rng.normal(size=n)
    d = rng.uniform(-1, 1, (m, n))
    ys = 1.5 + d @ g + 0.5 * np.einsum("mi,ij,mj->m", d, H, d)
    w = np.ones(m)
    w[:7] = 0.0
    ys[:7] = 1e6                                          # dropped corruption
    args = (jnp.asarray(d, jnp.float32), jnp.asarray(ys, jnp.float32),
            jnp.asarray(w, jnp.float32))
    c_j, g_j, h_j = reg.fit_quadratic(*args, use_kernel=False)
    c_k, g_k, h_k = reg.fit_quadratic(*args, use_kernel=True)
    np.testing.assert_allclose(float(c_k), float(c_j), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_j),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_j),
                               rtol=1e-3, atol=1e-3)


def test_fit_quadratic_auto_threshold_routes_large_fits():
    # below threshold -> jnp path; above -> kernel path; both must agree
    n = 10
    cols = reg.n_columns(n)
    big_m = (reg.GRAM_KERNEL_MIN_ELEMENTS // cols) + 1
    rng = np.random.default_rng(1)
    d = rng.uniform(-1, 1, (big_m, n))
    ys = np.sum(d * d, axis=1)
    c_auto, g_auto, h_auto = reg.fit_quadratic(
        jnp.asarray(d, jnp.float32), jnp.asarray(ys, jnp.float32))
    c_ref, g_ref, h_ref = reg.fit_quadratic(
        jnp.asarray(d, jnp.float32), jnp.asarray(ys, jnp.float32),
        use_kernel=False)
    np.testing.assert_allclose(np.asarray(g_auto), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_auto), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)
