"""Tests for the unified AnmEngine and its substrates (DESIGN.md §1).

The refactor's contract: one phase machine, three substrates.  These tests
pin (1) sync/async parity — the synchronous driver and the FGDO adapter
reach the same optimum from the shared engine; (2) the explicit stale-phase
and failed-validation paths; (3) the vectorized batched-grid substrate
(convergence, determinism, actually-batched evaluation); (4) the kernel
routing of the regression's normal equations.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regression as reg
from repro.core.anm import AnmConfig, anm_minimize
from repro.core.engine import AnmEngine, EvalResult
from repro.core.fgdo import FgdoAnmServer
from repro.core.grid import GridConfig, VolunteerGrid, malicious_lie
from repro.core.substrates.batched_grid import BatchedVolunteerGrid


def _quad_problem(n=4, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n))
    H = A @ A.T + n * np.eye(n)
    x_opt = rng.uniform(-0.5, 0.5, n)
    H_j = jnp.asarray(H, jnp.float32)
    x_opt_j = jnp.asarray(x_opt, jnp.float32)

    def f(x):
        d = np.asarray(x, np.float64) - x_opt
        return float(0.5 * d @ H @ d)

    def f_batch(xs):
        # jit-friendly on purpose: evaluation backends TRACE f_batch inside
        # their bucket finalization since the async/pipelined refactor
        d = xs - x_opt_j[None, :]
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, H_j, d)

    return f, f_batch, x_opt, n


def _assimilate_all(engine, reqs, f):
    return engine.assimilate([EvalResult(r, f(r.point)) for r in reqs])


def _bootstrap(engine, f):
    """Complete the engine's phase-0 f(x0) evaluation, including its
    quorum round (every run starts with it since the first-commit guard
    moved into the engine; the probe is validated like any result the
    engine uses)."""
    assert engine.phase == "bootstrap"
    _assimilate_all(engine, engine.generate(), f)   # the probe
    while engine.validating:
        reqs = engine.generate()
        if not reqs:
            break
        _assimilate_all(engine, reqs, f)            # quorum replicas
    assert engine.phase == "regression"


# -- sync/async parity ------------------------------------------------------

def test_sync_and_fgdo_reach_same_center():
    """Paper's core claim: the state machine is substrate-independent.  On a
    seeded convex quadratic with a faultless grid, the synchronous driver and
    the FGDO adapter (both thin layers over AnmEngine) converge to the same
    center."""
    f, f_batch, x_opt, n = _quad_problem(seed=42)
    cfg = AnmConfig(m_regression=80, m_line_search=80, max_iterations=8)

    state = anm_minimize(f_batch, np.ones(n), -10 * np.ones(n),
                         10 * np.ones(n), 0.5 * np.ones(n), cfg,
                         key=jax.random.key(0))

    server = FgdoAnmServer(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), cfg, seed=1)
    VolunteerGrid(f, GridConfig(n_hosts=32, failure_prob=0.0,
                                malicious_prob=0.0, seed=2)).run(server)

    c_sync = np.asarray(state.center, np.float64)
    c_async = server.center
    np.testing.assert_allclose(c_sync, x_opt, atol=5e-2)
    np.testing.assert_allclose(c_async, x_opt, atol=5e-2)
    np.testing.assert_allclose(c_sync, c_async, atol=5e-2)
    f0 = f(np.ones(n))
    assert state.best_fitness < 1e-3 * f0
    assert server.best_fitness < 1e-3 * f0


def test_sync_driver_runs_quorum_validation():
    """Unification gives the synchronous driver the validation path the old
    standalone implementation lacked: every commit is preceded by quorum
    re-evaluation of the winning point."""
    _, f_batch, _, n = _quad_problem(seed=5)
    hits = {"n": 0}

    def counting(xs):
        hits["n"] += 1
        return f_batch(xs)

    cfg = AnmConfig(m_regression=60, m_line_search=60, max_iterations=3)
    state = anm_minimize(counting, np.ones(n), -10 * np.ones(n),
                         10 * np.ones(n), 0.5 * np.ones(n), cfg,
                         key=jax.random.key(3))
    # per iteration: regression batch + line batch + >=1 quorum batch,
    # plus the initial f(x0) evaluation
    assert hits["n"] >= 3 * state.iteration + 1
    assert state.history, "driver must record committed iterations"


# -- explicit stale-phase path ----------------------------------------------

def test_stale_phase_results_are_discarded():
    f, _, _, n = _quad_problem(seed=1)
    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=4)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0)
    _bootstrap(engine, f)
    reqs = engine.generate(41)               # one more than the phase needs
    straggler = reqs[-1]
    _assimilate_all(engine, reqs[:40], f)    # phase advances at m=40
    assert engine.phase == "linesearch"
    buffered = len(engine.results)
    stale_before = engine.stats.stale
    _assimilate_all(engine, [straggler], f)  # late arrival from old phase
    assert engine.stats.stale == stale_before + 1
    assert len(engine.results) == buffered   # did not leak into the new phase
    assert engine.phase == "linesearch"


# -- explicit failed-validation path ----------------------------------------

def test_failed_validation_rejects_candidate_and_promotes_next():
    f, _, _, n = _quad_problem(seed=2)
    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=1)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0, validation_quorum=2)
    _bootstrap(engine, f)
    _assimilate_all(engine, engine.generate(), f)          # regression phase
    assert engine.phase == "linesearch"
    reqs = engine.generate()
    honest = [EvalResult(r, f(r.point)) for r in reqs[:-1]]
    lie = EvalResult(reqs[-1], -1e6)                       # malicious winner
    engine.assimilate(honest + [lie])
    assert engine.validating
    first_candidate = engine._candidate
    assert first_candidate[0] == -1e6, "the lie must rank first"
    # quorum replicas return the TRUTH for the lying point -> rejected
    while engine.validating and not engine.done:
        replicas = engine.generate()
        if not replicas:
            break
        _assimilate_all(engine, replicas, f)
    assert engine.stats.validations_failed >= 1
    assert engine.stats.candidates_rejected >= 1
    assert engine.history[-1].best_fitness > -1e5          # lie never committed


def test_lost_validation_replicas_can_be_reissued():
    f, _, _, n = _quad_problem(seed=3)
    cfg = AnmConfig(m_regression=30, m_line_search=30, max_iterations=1)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0, validation_quorum=2)
    _bootstrap(engine, f)
    _assimilate_all(engine, engine.generate(), f)
    _assimilate_all(engine, engine.generate(), f)
    if engine.done:                                        # committed already
        return
    assert engine.validating
    engine.generate()                                      # replicas... lost
    assert engine.validation_pending == 0
    assert engine.generate() == []                         # budget exhausted
    r1, r2 = engine.reissue_validation(), engine.reissue_validation()
    assert r1 is not None and r2 is not None
    _assimilate_all(engine, [r1, r2], f)
    assert engine.done and engine.iteration == 1


# -- bootstrap guard: first commit can never accept a worse point ------------

def test_first_candidate_worse_than_start_is_not_committed():
    """With x0 AT the optimum, every candidate is worse than the start.
    Before the engine-side bootstrap, async substrates compared the first
    commit against inf and moved the center to a strictly worse point; now
    f(x0) is evaluated as a phase-0 request on every substrate, so the
    center must never move and best_fitness must equal f(x0)."""
    f, f_batch, x_opt, n = _quad_problem(seed=21)
    f0 = f(x_opt)
    lo, hi, step = -10 * np.ones(n), 10 * np.ones(n), 0.5 * np.ones(n)
    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=3)

    # synchronous driver
    state = anm_minimize(f_batch, x_opt.copy(), lo, hi, step, cfg,
                         key=jax.random.key(1))
    assert state.best_fitness <= f0 + 1e-12
    np.testing.assert_allclose(np.asarray(state.center), x_opt, atol=1e-12)

    # FGDO adapter on the per-event grid (could NOT seed f(x0) before)
    server = FgdoAnmServer(x_opt.copy(), lo, hi, step, cfg, seed=1)
    VolunteerGrid(f, GridConfig(n_hosts=16, failure_prob=0.0,
                                malicious_prob=0.0, seed=2)).run(server)
    assert server.best_fitness <= f0 + 1e-12
    np.testing.assert_array_equal(server.center, x_opt)
    assert all(r.best_fitness <= f0 + 1e-12 for r in server.history)

    # batched grid (could NOT seed f(x0) before either)
    engine = AnmEngine(x_opt.copy(), lo, hi, step, cfg, seed=1)
    BatchedVolunteerGrid(lambda xs: f_batch(xs),
                         GridConfig(n_hosts=64, failure_prob=0.05,
                                    malicious_prob=0.0, seed=3)).run(engine)
    assert engine.best_fitness <= f0 + 1e-12
    np.testing.assert_array_equal(engine.center, x_opt)


def test_malicious_bootstrap_probe_cannot_poison_threshold():
    """The f(x0) claim gates every commit, so it is quorum-validated like
    any other result the engine uses: a lying probe must be rejected (and
    the bootstrap re-run) instead of freezing the run with a fabricated
    improvement threshold below the global optimum."""
    f, _, _, n = _quad_problem(seed=9)
    cfg = AnmConfig(m_regression=30, m_line_search=30, max_iterations=2)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0, validation_quorum=2)
    probe = engine.generate()[0]
    truth = f(probe.point)
    lie = float(malicious_lie(truth, 0.8)) - 100.0   # below the optimum
    engine.assimilate([EvalResult(probe, lie)])
    assert engine.validating and engine.bootstrapping
    _assimilate_all(engine, engine.generate(), f)    # honest replicas
    assert engine.phase == "bootstrap"               # lie rejected, retry
    assert engine.stats.validations_failed >= 1
    assert engine.best_fitness == float("inf")
    _bootstrap(engine, f)                            # honest second round
    assert engine.best_fitness == truth


# -- staleness counters: phase-stale vs validation-stale ---------------------

def test_validation_stale_counted_separately_from_phase_stale():
    """A quorum replica landing after its candidate was decided is NOT a
    phase-stale result: it must bump validations_stale, not stale."""
    f, _, _, n = _quad_problem(seed=2)
    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=1)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=0, validation_quorum=2)
    _bootstrap(engine, f)
    _assimilate_all(engine, engine.generate(), f)          # regression
    reqs = engine.generate()                               # line search
    honest = [EvalResult(r, f(r.point)) for r in reqs[:-1]]
    lie = EvalResult(reqs[-1], -1e6)                       # fake winner
    engine.assimilate(honest + [lie])
    assert engine.validating
    replicas = engine.generate()                           # quorum for the lie
    extra = engine.reissue_validation()                    # a third, late copy
    stale_before = engine.stats.stale
    # honest replicas reject the lie and promote the next candidate
    _assimilate_all(engine, replicas, f)
    assert engine.stats.candidates_rejected >= 1
    vstale_before = engine.stats.validations_stale
    _assimilate_all(engine, [extra], f)    # replica for the DECIDED candidate
    assert engine.stats.validations_stale == vstale_before + 1
    assert engine.stats.stale == stale_before              # not conflated


# -- sign-safe malicious lie ---------------------------------------------------

def test_malicious_lie_fakes_improvement_for_any_sign():
    """The corruption model must under-report fitness for positive,
    negative and zero truths — the old multiplicative lie y*u was harmless
    for y <= 0, so fault-tolerance tests near an optimum tested nothing."""
    for y in (-25.0, -1e-9, 0.0, 3.0):
        for u in (0.2, 0.5, 0.8):
            lie = float(malicious_lie(y, u))
            assert lie < y - 0.19 * (abs(y) + 1.0)
    arr = malicious_lie(np.array([-2.0, 0.0, 2.0]), np.array([0.5, 0.5, 0.5]))
    assert (arr < np.array([-2.0, 0.0, 2.0])).all()


def test_corrupted_result_rejected_when_true_fitness_nonpositive():
    """Quorum validation must reject a lie even when the TRUE fitness at
    the lying point is <= 0 (the regime the old lie could not attack)."""
    _, _, x_opt, n = _quad_problem(seed=4)

    def f(x):                                   # shifted: optimum region < 0
        d = np.asarray(x, np.float64) - x_opt
        return float(d @ d) - 5.0

    cfg = AnmConfig(m_regression=40, m_line_search=40, max_iterations=1)
    engine = AnmEngine(x_opt + 0.05, -10 * np.ones(n), 10 * np.ones(n),
                       0.1 * np.ones(n), cfg, seed=0, validation_quorum=2)
    _bootstrap(engine, f)
    assert engine.best_fitness < 0            # the regime under test
    _assimilate_all(engine, engine.generate(), f)
    reqs = engine.generate()
    honest = [EvalResult(r, f(r.point)) for r in reqs[:-1]]
    truth = f(reqs[-1].point)
    assert truth <= 0
    corrupted = EvalResult(reqs[-1], float(malicious_lie(truth, 0.5)))
    assert corrupted.y < truth                # the lie still ranks first
    engine.assimilate(honest + [corrupted])
    assert engine.validating
    assert engine._candidate[0] == corrupted.y
    while engine.validating and not engine.done:
        replicas = engine.generate()
        if not replicas:
            break
        _assimilate_all(engine, replicas, f)  # replicas return the truth
    assert engine.stats.validations_failed >= 1
    assert engine.stats.candidates_rejected >= 1
    assert engine.best_fitness > corrupted.y  # the lie never committed
    # whatever committed is a REAL fitness of the committed center
    assert abs(engine.best_fitness - f(engine.center)) <= \
        1e-6 * max(1.0, abs(engine.best_fitness))


# -- array fast path == object path -------------------------------------------

def test_assimilate_arrays_matches_object_path():
    """The block fast path must drive the engine through the identical
    state trajectory as element-wise EvalResults — including mid-block
    phase flips and stale tails."""
    f, _, _, n = _quad_problem(seed=6)
    cfg = AnmConfig(m_regression=30, m_line_search=30, max_iterations=2)

    def drive(use_arrays):
        engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), cfg, seed=0)
        while not engine.done:
            reqs = engine.generate()
            if not reqs:
                break
            # deliver 7 extra stale-to-be results after the flip
            extra = engine.generate(7) if engine.phase in (
                "regression", "linesearch") else []
            batch = reqs + extra
            if use_arrays:
                engine.assimilate_arrays(
                    np.array([r.phase_id for r in batch]),
                    np.array([r.ticket for r in batch]),
                    np.stack([r.point for r in batch]),
                    np.array([r.alpha for r in batch]),
                    np.array([-1 if r.validates is None else r.validates
                              for r in batch]),
                    np.array([f(r.point) for r in batch]))
            else:
                _assimilate_all(engine, batch, f)
        return engine

    a, b = drive(True), drive(False)
    assert a.iteration == b.iteration
    np.testing.assert_array_equal(a.center, b.center)
    assert a.best_fitness == b.best_fitness
    assert a.stats == b.stats
    assert [r.best_fitness for r in a.history] == \
        [r.best_fitness for r in b.history]


# -- batched grid substrate --------------------------------------------------

def _run_batched(n_hosts=512, seed=7, max_iterations=6, **grid_kw):
    f, f_batch, x_opt, n = _quad_problem(seed=11)
    cfg = AnmConfig(m_regression=60, m_line_search=60,
                    max_iterations=max_iterations)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=seed)
    grid = BatchedVolunteerGrid(
        f_batch, GridConfig(n_hosts=n_hosts, seed=3, **grid_kw))
    stats = grid.run(engine)
    return engine, stats, f, x_opt, n


def test_batched_grid_converges_and_batches():
    engine, stats, f, x_opt, n = _run_batched(
        failure_prob=0.05, malicious_prob=0.01)
    assert engine.done
    assert engine.best_fitness < 1e-2 * f(np.ones(n))
    np.testing.assert_allclose(engine.center, x_opt, atol=0.1)
    # the point of the substrate: many results per bucket submission
    assert stats.batched_evals / max(stats.batch_calls, 1) > 8
    assert stats.batch_calls > 0
    assert sum(stats.bucket_hist.values()) == stats.batch_calls
    assert stats.completed > 0 and stats.failed > 0


def test_batched_grid_deterministic():
    e1, s1, *_ = _run_batched(failure_prob=0.1, malicious_prob=0.02)
    e2, s2, *_ = _run_batched(failure_prob=0.1, malicious_prob=0.02)
    assert e1.best_fitness == e2.best_fitness
    np.testing.assert_array_equal(e1.center, e2.center)
    assert s1.sim_time == s2.sim_time
    assert [r.best_fitness for r in e1.history] == \
        [r.best_fitness for r in e2.history]


def test_batched_grid_survives_malice():
    # 10% malicious + 20% loss: heavier faults cost iterations (rejected
    # candidates, shrink recoveries), so give the run more room than the
    # faultless cases — the claim is convergence DESPITE corruption
    engine, stats, f, _, n = _run_batched(
        n_hosts=256, failure_prob=0.2, malicious_prob=0.1, max_iterations=10)
    assert stats.corrupted > 0
    assert engine.best_fitness < 5e-2 * f(np.ones(n))


# -- kernel-routed normal equations ------------------------------------------

def test_fit_quadratic_kernel_path_matches_jnp():
    rng = np.random.default_rng(0)
    n, m = 8, 512
    A = rng.normal(size=(n, n))
    H = (A + A.T) / 2
    g = rng.normal(size=n)
    d = rng.uniform(-1, 1, (m, n))
    ys = 1.5 + d @ g + 0.5 * np.einsum("mi,ij,mj->m", d, H, d)
    w = np.ones(m)
    w[:7] = 0.0
    ys[:7] = 1e6                                          # dropped corruption
    args = (jnp.asarray(d, jnp.float32), jnp.asarray(ys, jnp.float32),
            jnp.asarray(w, jnp.float32))
    c_j, g_j, h_j = reg.fit_quadratic(*args, use_kernel=False)
    c_k, g_k, h_k = reg.fit_quadratic(*args, use_kernel=True)
    np.testing.assert_allclose(float(c_k), float(c_j), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_j),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_j),
                               rtol=1e-3, atol=1e-3)


def test_fit_quadratic_auto_threshold_routes_large_fits():
    # below threshold -> jnp path; above -> kernel path; both must agree
    n = 10
    cols = reg.n_columns(n)
    big_m = (reg.GRAM_KERNEL_MIN_ELEMENTS // cols) + 1
    rng = np.random.default_rng(1)
    d = rng.uniform(-1, 1, (big_m, n))
    ys = np.sum(d * d, axis=1)
    c_auto, g_auto, h_auto = reg.fit_quadratic(
        jnp.asarray(d, jnp.float32), jnp.asarray(ys, jnp.float32))
    c_ref, g_ref, h_ref = reg.fit_quadratic(
        jnp.asarray(d, jnp.float32), jnp.asarray(ys, jnp.float32),
        use_kernel=False)
    np.testing.assert_allclose(np.asarray(g_auto), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_auto), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)
