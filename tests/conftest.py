import os

# Tests run on the single real CPU device — the 512-device override is
# applied ONLY inside repro.launch.dryrun (see the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running end-to-end tests (subprocess smokes)")
    config.addinivalue_line(
        "markers", "orchestrator: tier-1 multi-search orchestrator tests "
                   "(run in CI's cached-venv tier-1 job; select with "
                   "-m orchestrator)")
    config.addinivalue_line(
        "markers", "server: tier-1 service-layer tests (wire protocol, "
                   "host registry, crash-recoverable work server; CI's "
                   "server-smoke job selects them with -m server)")
    config.addinivalue_line(
        "markers", "cache: tier-1 eval-cache tests (bit-exact memo layer, "
                   "key canonicalization, persistence + warm restore; "
                   "select with -m cache)")
    config.addinivalue_line(
        "markers", "chaos: tier-1 fault-injection tests (sequenced intake, "
                   "idempotent retry, concurrent-TCP chaos parity, "
                   "malformed-frame fuzz; CI's chaos-smoke job selects "
                   "them with -m chaos)")
    config.addinivalue_line(
        "markers", "obs: tier-1 observability-plane tests (metrics hub, "
                   "subscribe_stats stream, anomaly-driven fleet defense, "
                   "stamp-neutrality + observed-run parity; CI's obs-smoke "
                   "job selects them with -m obs)")
