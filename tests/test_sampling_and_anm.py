"""Tests for §IV sampling (eq. 6), bound clipping, and the sync ANM driver."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sampling
from repro.core.anm import AnmConfig, anm_minimize

settings = dict(max_examples=25, deadline=None)


@hypothesis.given(seed=st.integers(0, 10_000), n=st.integers(1, 8))
@hypothesis.settings(**settings)
def test_line_samples_stay_in_bounds(seed, n):
    """Paper §IV: α range is shrunk so no point leaves [lo, hi]."""
    rng = np.random.default_rng(seed)
    lo = jnp.asarray(rng.uniform(-5, -1, n), jnp.float32)
    hi = jnp.asarray(rng.uniform(1, 5, n), jnp.float32)
    center = jnp.asarray(rng.uniform(-1, 1, n), jnp.float32)
    direction = jnp.asarray(rng.normal(0, 2, n), jnp.float32)
    a_lo, a_hi = sampling.clip_alpha_range(center, direction, lo, hi, 0.0, 3.0)
    pts, alphas = sampling.sample_line(jax.random.key(seed), center, direction,
                                       a_lo, a_hi, 64)
    eps = 1e-4
    assert bool(jnp.all(pts >= lo - eps)) and bool(jnp.all(pts <= hi + eps))
    assert bool(jnp.all(alphas >= -eps)) and bool(jnp.all(alphas <= 3.0 + eps))


@hypothesis.given(seed=st.integers(0, 10_000))
@hypothesis.settings(**settings)
def test_box_samples_centered(seed):
    center = jnp.asarray([1.0, -2.0, 0.5])
    step = jnp.asarray([0.1, 0.2, 0.3])
    pts = sampling.sample_box(jax.random.key(seed), center, step, 128)
    assert bool(jnp.all(jnp.abs(pts - center) <= step + 1e-6))


def test_anm_converges_on_quadratic_in_few_iterations():
    """On an exact quadratic the regression is exact, so ANM needs O(1)
    iterations — the paper's core efficiency claim in its cleanest form."""
    rng = np.random.default_rng(0)
    n = 6
    A = rng.normal(size=(n, n))
    H = A @ A.T + n * np.eye(n)
    x_opt = rng.uniform(-0.5, 0.5, n)

    def f_batch(xs):
        d = xs - jnp.asarray(x_opt, jnp.float32)
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, jnp.asarray(H, jnp.float32), d)

    x0 = x_opt + rng.uniform(-1, 1, n)
    state = anm_minimize(
        f_batch, x0, lo=-10 * np.ones(n), hi=10 * np.ones(n),
        step=0.5 * np.ones(n),
        cfg=AnmConfig(m_regression=120, m_line_search=200, max_iterations=8,
                      alpha_max=1.5),
        key=jax.random.key(1))
    f0 = float(f_batch(jnp.asarray(x0, jnp.float32)[None])[0])
    assert state.best_fitness < 1e-2 * f0
    # and the quadratic model should get most of the way in ~3 iterations
    assert state.history[2].best_fitness < 0.2 * f0


def test_randomized_line_search_escapes_local_optimum():
    """Paper Fig. 3: a multi-modal slice along the search direction — the
    randomized line search finds the far (global) basin that a sequential
    nearest-optimum search cannot."""
    # f(x) = small local basin at x=0.2·d, much deeper one at x=1.4·d
    def f1d(t):
        return (0.5 * (t - 0.2) ** 2
                - 1.5 * jnp.exp(-30.0 * (t - 1.4) ** 2))

    def f_batch(xs):
        return f1d(xs[:, 0])

    state = anm_minimize(
        f_batch, x0=np.array([0.0]), lo=np.array([-2.0]), hi=np.array([2.0]),
        step=np.array([0.05]),
        cfg=AnmConfig(m_regression=64, m_line_search=400, max_iterations=4,
                      alpha_max=40.0),
        key=jax.random.key(2))
    # the global basin is near t=1.4 with f ≈ -0.78; local-only methods stall
    # at t≈0.2 with f≈0.0
    assert state.best_fitness < -0.5, state.best_fitness
