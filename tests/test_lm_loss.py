"""LM-loss evaluation backend: the model stack as the engine's fitness.

Pins the DESIGN.md §11 contracts on the single real CPU device (the
512-device pod variant is exercised by ``--substrate lm_subspace``):
shared subspace machinery, zero compiles after warm, bucket-width
invariance, sync == pipelined trajectories, and unmodified composition
with ``CachingSubmitter`` and the work server.
"""
import numpy as np
import pytest

from repro.core.subspace import (SubspaceProjection, orthonormal_basis,
                                 ravel_pytree, tree_lift)
from repro.core.substrates.lm_loss import (LmLossEvalBackend, LmWorkload,
                                           make_lm_workload)

K = 4


@pytest.fixture(scope="module")
def workload() -> LmWorkload:
    # tiny on purpose: 1×16 tokens through the 2-layer rwkv6 smoke config
    # keeps each lane's forward in the milliseconds
    return make_lm_workload("rwkv6-7b", k=K, batch_size=1, seq_len=16,
                            seed=1)


@pytest.fixture(scope="module")
def backend(workload) -> LmLossEvalBackend:
    return LmLossEvalBackend(workload, n_dims=K, max_bucket=16)


def _eval(backend, pts, mal_u=None, tags=None):
    pts = np.atleast_2d(pts)
    if mal_u is None:
        mal_u = np.full(len(pts), np.nan)
    if tags is None:
        tags = list(range(len(pts)))
    return backend.collect(backend.submit(pts, mal_u, tags))


# ---------------------------------------------------------------------------
# the shared subspace chart
# ---------------------------------------------------------------------------

class TestSubspaceProjection:
    def test_basis_orthonormal(self, workload):
        basis = workload.proj.basis
        gram = basis @ basis.T
        np.testing.assert_allclose(np.asarray(gram), np.eye(K), atol=1e-5)

    def test_lift_zero_is_theta0(self, workload):
        proj = workload.proj
        lifted = proj.lift(np.zeros(K, np.float32))
        np.testing.assert_array_equal(np.asarray(ravel_pytree(lifted)[0]),
                                      np.asarray(proj.flat0))

    def test_tree_lift_matches_flat_lift(self, workload):
        # the leaf-wise lift (what the backend shards) and the flat lift
        # (what the optimizer steps along) are the same map
        # (both lifts round back to the leaf dtypes — bf16 for the smoke
        # configs — so compare after the same unravel round-trip)
        proj = workload.proj
        c = np.asarray(np.linspace(-0.3, 0.4, K), np.float32)
        flat_of_tree, _ = ravel_pytree(proj.lift(c))
        flat_of_flat, _ = ravel_pytree(proj.unravel(proj.lift_flat(c)))
        np.testing.assert_allclose(np.asarray(flat_of_tree),
                                   np.asarray(flat_of_flat),
                                   rtol=1e-5, atol=1e-6)

    def test_anchor_first_row(self):
        rng = np.random.default_rng(5)
        anchor = rng.normal(size=32).astype(np.float32)
        import jax
        basis = orthonormal_basis(jax.random.key(0), 32, 3, anchor=anchor)
        unit = anchor / np.linalg.norm(anchor)
        cos = np.abs(np.asarray(basis)[0] @ unit)
        assert cos > 1 - 1e-5

    def test_optimizer_consumes_same_machinery(self, workload):
        # the in-process subspace-Newton step builds its chart from the
        # SAME module — one lift, two consumers
        import jax

        from repro.core import subspace_newton as sn
        key = jax.random.key(2)
        flat = workload.proj.flat0
        mom = np.asarray(np.ones_like(flat))
        np.testing.assert_array_equal(
            np.asarray(sn.make_basis(key, flat, mom, 3)),
            np.asarray(orthonormal_basis(key, flat.shape[0], 3,
                                         anchor=mom)))


# ---------------------------------------------------------------------------
# the backend contract
# ---------------------------------------------------------------------------

class TestLmLossBackend:
    def test_zero_compiles_after_warm(self, workload):
        be = LmLossEvalBackend(workload)
        be.warm(K, 16)
        c0 = be.compile_count
        assert c0 > 0
        rng = np.random.default_rng(0)
        for n in (1, 3, 7, 12):
            _eval(be, rng.uniform(-0.5, 0.5, (n, K)))
        assert be.compile_count == c0

    def test_loss_matches_direct_forward(self, workload, backend):
        # lane value == an independent jit of loss(lift(c)) — the backend
        # adds framing, not arithmetic
        import jax
        import jax.numpy as jnp
        from repro.models import transformer as T

        loss_fn = T.make_loss_fn(workload.cfg)
        batch = {k: jnp.asarray(v) for k, v in workload.batch.items()}
        c = np.asarray([0.2, -0.1, 0.4, -0.3])
        direct = jax.jit(lambda cc: loss_fn(
            tree_lift(workload.proj.theta0, workload.proj.basis_tree, cc),
            batch)[0])(jnp.asarray(c, jnp.float32))
        ys = _eval(backend, c)
        np.testing.assert_allclose(ys[0], float(direct), rtol=0, atol=0)

    def test_bucket_width_invariance(self, backend):
        # the same point rides buckets of width 8 and 16 bitwise-unchanged
        # (per-lane lax.map: width cannot touch a lane's program)
        rng = np.random.default_rng(1)
        pts = rng.uniform(-0.5, 0.5, (12, K))
        wide = _eval(backend, pts)
        narrow = np.concatenate([_eval(backend, pts[:4]),
                                 _eval(backend, pts[4:8]),
                                 _eval(backend, pts[8:])])
        np.testing.assert_array_equal(wide, narrow)

    def test_malicious_lanes_corrupted(self, backend):
        pts = np.tile(np.asarray([0.1, 0.2, -0.2, 0.3]), (2, 1))
        honest = _eval(backend, pts)
        lied = _eval(backend, pts, mal_u=np.asarray([np.nan, 0.5]))
        assert honest[0] == lied[0]          # honest lane untouched
        assert lied[1] != honest[1]          # corrupted on-device
        assert np.isfinite(lied[1])

    def test_engine_box_shape(self, workload):
        assert workload.x0.shape == (K,)
        assert np.all(workload.lo < workload.hi)
        assert np.all(workload.step > 0)

    def test_caching_submitter_unmodified(self, workload, backend):
        # the §10 memo layer in front of THIS backend: bit-equal values,
        # warm resubmission fully served
        from repro.core.substrates.eval_cache import (CachingSubmitter,
                                                      EvalCache)
        cache = EvalCache(fingerprint="test_lm_loss")
        sub = CachingSubmitter(backend, cache)
        rng = np.random.default_rng(2)
        pts = rng.uniform(-0.5, 0.5, (6, K))
        mal = np.full(6, np.nan)
        cold = sub.collect(sub.submit(pts, mal))
        np.testing.assert_array_equal(cold, _eval(backend, pts))
        misses = cache.stats.misses
        warm = sub.collect(sub.submit(pts, mal))
        np.testing.assert_array_equal(cold, warm)
        assert cache.stats.misses == misses
        assert cache.stats.hits >= len(pts)


class TestGridTrajectories:
    @pytest.fixture(scope="class")
    def problem(self):
        from repro.server.sim import lm_problem
        # the canonical problem builder the dryrun smoke and example use,
        # scaled down: 16 hosts, one iteration
        spec, fleet, wl = lm_problem(arch="rwkv6-7b", k=K, n_hosts=16,
                                     m=6, iterations=1)
        return spec, fleet, wl

    def test_pipelined_equals_sync(self, problem):
        from repro.core.engine import identical_trajectories
        from repro.core.substrates.batched_grid import BatchedVolunteerGrid
        spec, fleet, wl = problem
        be = LmLossEvalBackend(wl, n_dims=wl.k, max_bucket=32)

        def run(pipelined):
            engine = spec.build_engine()
            BatchedVolunteerGrid(None, fleet, backend=be,
                                 pipelined=pipelined).run(engine)
            return engine

        e_sync, e_pipe = run(False), run(True)
        assert identical_trajectories(e_sync, e_pipe)
        assert np.isfinite(e_sync.best_fitness)

    @pytest.mark.server
    def test_work_server_unmodified(self, problem):
        # the full wire-protocol stack over the LM objective, crash and
        # restore included (SimulatedCrash, no subprocess)
        import tempfile

        from repro.server.sim import (ServerSubstrate, SimulatedCrash,
                                      result_doc)
        spec, fleet, wl = problem
        be = LmLossEvalBackend(wl)
        base = result_doc(ServerSubstrate(spec, fleet, be).run())
        assert base["iteration"] >= 1
        kill_after = max(40, int(0.4 * base["pool"]["messages"]))
        with tempfile.TemporaryDirectory() as ckpt:
            with pytest.raises(SimulatedCrash):
                ServerSubstrate(spec, fleet, be, ckpt_dir=ckpt,
                                snapshot_every=20,
                                max_messages=kill_after).run()
            res = result_doc(ServerSubstrate(spec, fleet, be,
                                             ckpt_dir=ckpt).run(resume=True))
        assert res["history"] == base["history"]
        assert res["engine_stats"] == base["engine_stats"]
