"""Pod-mesh evaluation backend: bucket framing, shard_map parity, and the
dryrun forced-host-device smoke (DESIGN.md §6).

The contract under test: WHERE a workunit block is evaluated is invisible
to the engine — the pod-mesh backend must commit bit-identical iterates to
the in-process backend at the same engine seed and grid config.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anm import AnmConfig
from repro.core.engine import AnmEngine
from repro.core.grid import GridConfig
from repro.core.substrates.batched_grid import BatchedVolunteerGrid
from repro.core.substrates.eval_backend import (InProcessEvalBackend,
                                                bucket_size)
from repro.core.substrates.pod_mesh import PodMeshEvalBackend, make_data_mesh


def _quad_fitness(n=8, seed=3):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = jnp.asarray(A @ A.T + n * np.eye(n, dtype=np.float32))
    x_opt = jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32))

    @jax.jit
    def f_batch(xs):
        d = xs - x_opt[None, :]
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, H, d)

    return f_batch, n


# -- bucket framing -----------------------------------------------------------

def test_bucket_size_power_of_two_with_floor():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(500) == 512
    assert bucket_size(3, min_bucket=16) == 16
    with pytest.raises(ValueError):
        bucket_size(4, min_bucket=12)        # not a power of two


def test_backend_pads_to_buckets_and_masks_remainder():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    kps = []
    for k in (1, 5, 8, 13, 64, 100):
        pts = np.random.default_rng(k).uniform(-1, 1, (k, n))
        handle = be.submit(pts)
        kps.append(handle.kp)
        ys = be.collect(handle)
        assert ys.shape == (k,)              # remainder masked, not dropped
        ref = np.asarray(f_batch(jnp.asarray(pts, jnp.float32)), np.float64)
        np.testing.assert_array_equal(ys, ref)
    assert kps == [bucket_size(k) for k in (1, 5, 8, 13, 64, 100)]


def test_min_bucket_validated_directly():
    """The floor argument is validated as a power of two — not silently
    rounded through bucket_size — and lives in one documented place."""
    from repro.core.substrates.eval_backend import DEFAULT_MIN_BUCKET
    f_batch, _ = _quad_fitness()
    assert InProcessEvalBackend(f_batch).min_bucket == DEFAULT_MIN_BUCKET
    assert InProcessEvalBackend(f_batch, min_bucket=2).min_bucket == 2
    for bad in (0, 3, 12, -8):
        with pytest.raises(ValueError):
            InProcessEvalBackend(f_batch, min_bucket=bad)


def test_pod_backend_bucket_floor_is_shard_count():
    f_batch, _ = _quad_fitness()
    pod = PodMeshEvalBackend(f_batch)
    assert pod.min_bucket >= pod.n_shards
    assert pod.min_bucket & (pod.min_bucket - 1) == 0


# -- backend value parity ------------------------------------------------------

def test_pod_backend_values_match_in_process_exactly():
    f_batch, n = _quad_fitness()
    inp = InProcessEvalBackend(f_batch)
    pod = PodMeshEvalBackend(f_batch, mesh=make_data_mesh())
    for k in (1, 7, 32, 200):
        pts = np.random.default_rng(k).uniform(-2, 2, (k, n))
        np.testing.assert_array_equal(inp(pts), pod(pts))


# -- end-to-end committed-iterate parity ---------------------------------------

def test_pod_and_in_process_backends_commit_identical_iterates():
    """Same engine seed + same grid config => bit-identical committed
    centers, fitness history, iteration counts and sim time, whichever
    backend evaluates the buckets."""
    f_batch, n = _quad_fitness()
    cfg = AnmConfig(m_regression=48, m_line_search=48, max_iterations=4)
    grid_cfg = GridConfig(n_hosts=256, failure_prob=0.1,
                          malicious_prob=0.02, seed=3)

    def run(backend):
        engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), cfg, seed=7)
        stats = BatchedVolunteerGrid(f_batch, grid_cfg,
                                     backend=backend).run(engine)
        return engine, stats

    e_in, s_in = run(None)                    # default in-process
    e_pod, s_pod = run(PodMeshEvalBackend(f_batch))
    assert e_in.iteration == e_pod.iteration
    assert len(e_in.history) == len(e_pod.history)
    for a, b in zip(e_in.history, e_pod.history):
        np.testing.assert_array_equal(a.center, b.center)
        assert a.best_fitness == b.best_fitness
    assert s_in.sim_time == s_pod.sim_time
    assert s_in.completed == s_pod.completed


# -- the real partitioning, under dryrun's forced 512-device mesh --------------

@pytest.mark.slow
def test_dryrun_pod_mesh_smoke_parity(tmp_path):
    """Run the `--substrate pod_mesh` dryrun in a subprocess (it forces
    XLA_FLAGS=--xla_force_host_platform_device_count=512 before importing
    jax) and require the bit-identical parity report on the production
    16x16 mesh."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--substrate", "pod_mesh", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=repo)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads((tmp_path / "substrate_pod_mesh.json").read_text())
    assert report["parity_ok"] is True
    assert report["pipelined_parity_ok"] is True
    assert report["pod_parity_ok"] is True
    assert report["centers_equal"] is True
    assert report["fitness_equal"] is True
    assert report["data_shards"] == 16
    assert report["iterations"]["in_process"] == \
        report["iterations"]["pod_mesh"] == \
        report["iterations"]["in_process_pipelined"]
