"""Tests for the pod-mode paper adaptations: subspace Newton, parallel line
search, gradient compression, and the sharding spec mirrors."""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.parallel_line_search import LineSearchConfig, randomized_line_search
from repro.core import subspace_newton as subn
from repro.optim.compression import (compress_grads, dequantize_int8,
                                     init_error_state, quantize_int8)


def _quad_loss(target):
    def loss(params):
        return sum(jnp.sum((p - t) ** 2) for p, t in
                   zip(jax.tree.leaves(params), jax.tree.leaves(target)))
    return loss


def test_subspace_newton_descends_quadratic():
    key = jax.random.key(0)
    target = {"w": jnp.ones((20,)), "b": jnp.full((5,), -2.0)}
    params = {"w": jnp.zeros((20,)), "b": jnp.zeros((5,))}
    loss = _quad_loss(target)
    cfg = subn.SubspaceNewtonConfig(k=4, sample_scale=0.3, alpha_max=3.0,
                                    p_line=32)
    state = subn.init_state(params)
    l0 = float(loss(params))
    losses = []
    for i in range(12):
        key, sk = jax.random.split(key)
        params, state, info = subn.subspace_newton_step(loss, params, state,
                                                        cfg, sk)
        losses.append(float(loss(params)))
    # expected rate for random k-dim subspace Newton on an n-dim quadratic
    # is ~(1 - k/n) per step: (1 - 4/25)^12 ≈ 0.12
    assert losses[-1] < 0.3 * l0, losses
    # monotone non-increasing (line search rejects bad steps)
    assert all(b <= a + 1e-5 for a, b in zip([l0] + losses, losses))


def test_subspace_newton_tolerates_dropped_samples():
    """first-m-of-M semantics: 30% of sample evaluations never return."""
    key = jax.random.key(1)
    target = {"w": jnp.full((12,), 0.7)}
    params = {"w": jnp.zeros((12,))}
    loss = _quad_loss(target)
    cfg = subn.SubspaceNewtonConfig(k=3, sample_scale=0.3, alpha_max=3.0,
                                    p_line=16)
    state = subn.init_state(params)
    m = cfg.m_resolved()
    l0 = float(loss(params))
    for i in range(12):
        key, sk, mk = jax.random.split(key, 3)
        mask = jax.random.uniform(mk, (m,)) > 0.3
        params, state, _ = subn.subspace_newton_step(loss, params, state, cfg,
                                                     sk, completed_mask=mask)
    assert float(loss(params)) < 0.35 * l0


def test_parallel_line_search_improves_over_fixed_step():
    key = jax.random.key(2)
    params = {"w": jnp.zeros((10,))}
    target = {"w": jnp.ones((10,))}
    loss = _quad_loss(target)
    # deliberately mis-scaled update (too small): line search should stretch it
    update = {"w": jnp.full((10,), 0.3)}
    new_params, alpha, best = randomized_line_search(
        loss, params, update, key, LineSearchConfig(p=32, alpha_max=4.0))
    assert float(best) < float(loss({"w": params["w"] + update["w"]}))
    assert alpha > 1.0


def test_line_search_respects_completed_mask():
    key = jax.random.key(3)
    params = {"w": jnp.zeros(4)}
    loss = _quad_loss({"w": jnp.zeros(4)})         # any move is worse
    update = {"w": jnp.ones(4)}
    mask = jnp.zeros((8,), bool).at[0].set(True)   # only α=1 returned
    _, alpha, _ = randomized_line_search(loss, params, update, key,
                                         LineSearchConfig(p=8), mask)
    assert float(alpha) == 1.0


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    key = jax.random.key(4)
    x = jax.random.normal(key, (1000,)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_error_feedback_accumulates_residual():
    grads = {"w": jnp.array([1e-4, 2e-4, -1e-4])}  # below quantization step
    err = init_error_state(grads)
    g1, err = compress_grads(grads, err)
    # residual carried so repeated application eventually transmits signal
    total = jax.tree.map(jnp.zeros_like, grads)
    for _ in range(50):
        g, err = compress_grads(grads, err)
        total = jax.tree.map(lambda t, x: t + x, total, g)
    avg = total["w"] / 50
    np.testing.assert_allclose(np.asarray(avg), np.asarray(grads["w"]),
                               rtol=0.2, atol=2e-5)


# ---------------------------------------------------------------------------
# sharding spec mirrors
# ---------------------------------------------------------------------------

class _FakeMesh:
    """Just enough Mesh interface for the spec builders."""
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)
        self.size = int(np.prod(list(shape.values())))


@pytest.mark.parametrize("arch", ["qwen2-72b", "deepseek-coder-33b",
                                  "deepseek-v2-lite-16b", "rwkv6-7b",
                                  "zamba2-2.7b", "hubert-xlarge",
                                  "llama4-maverick-400b-a17b"])
@pytest.mark.parametrize("mesh_shape", [{"data": 16, "model": 16},
                                        {"pod": 2, "data": 16, "model": 16}])
def test_param_specs_mirror_structure(arch, mesh_shape):
    import functools
    from repro.configs import get_config
    from repro.models import param_specs
    from repro.models.transformer import init_params

    cfg = get_config(arch)
    mesh = _FakeMesh(mesh_shape)
    specs = param_specs(cfg, mesh)
    shapes = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.key(0))
    # same tree structure
    assert jax.tree.structure(specs) == jax.tree.structure(shapes)
    # every spec entry is either None or a known mesh axis, with rank <= leaf rank
    for spec, leaf in zip(jax.tree.leaves(specs), jax.tree.leaves(shapes)):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)
        for entry in spec:
            if entry is not None:
                assert entry in mesh.axis_names


@pytest.mark.parametrize("arch", ["qwen2-72b", "deepseek-v2-lite-16b",
                                  "command-r-plus-104b"])
def test_fsdp_specs_shard_all_large_params(arch):
    """FSDP mode must put the 'data' axis on every >=1M-element param that
    has a data-divisible free dim (storage fits 16GB HBM; see §Perf)."""
    import functools
    from repro.configs import get_config
    from repro.models import param_specs
    from repro.models.transformer import init_params

    cfg = get_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = param_specs(cfg, mesh, fsdp=True)
    shapes = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.key(0))
    n_large = n_fsdp = 0
    for spec, leaf in zip(jax.tree.leaves(specs), jax.tree.leaves(shapes)):
        if leaf.size < (1 << 20):
            continue
        n_large += 1
        flat = [e for e in spec for e in ((e,) if not isinstance(e, tuple) else e)]
        if "data" in flat:
            n_fsdp += 1
        # sharded dims must still divide
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            assert dim % total == 0, (arch, spec, leaf.shape)
    assert n_large > 0 and n_fsdp == n_large, (arch, n_fsdp, n_large)


@pytest.mark.parametrize("arch,shape_name", [
    ("qwen2-72b", "decode_32k"), ("rwkv6-7b", "long_500k"),
    ("h2o-danube-3-4b", "long_500k"), ("zamba2-2.7b", "decode_32k"),
    ("deepseek-v2-lite-16b", "decode_32k")])
def test_cache_specs_mirror_structure(arch, shape_name):
    from repro.configs import SHAPES, get_config
    from repro.models import cache_specs
    from repro.models.transformer import init_cache

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = _FakeMesh({"data": 16, "model": 16})
    specs = cache_specs(cfg, shape, mesh)
    sds = init_cache(cfg, shape.global_batch, shape.seq_len, as_shape=True)
    assert jax.tree.structure(specs) == jax.tree.structure(sds)
    for spec, leaf in zip(jax.tree.leaves(specs), jax.tree.leaves(sds)):
        assert len(spec) <= leaf.ndim
        # sharded dims must divide evenly (caches are hot state)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            assert dim % total == 0, (arch, shape_name, spec, leaf.shape)
