"""Pipelined bucket evaluation: async submit/collect, block speculation,
and the sync == pipelined parity contract (DESIGN.md §7).

The contracts under test:

  * WHEN a bucket is collected is invisible to the engine — at a given
    engine seed the pipelined tick loop must commit bit-identical iterates
    (and identical final engine stats) to the synchronous loop, on both
    evaluation backends, across fleet sizes, tick widths and fault rates;
  * a warmed backend performs ZERO compiles mid-run (the bucket ladder is
    compiled at construction) — pinned by the ``compile_count`` probe;
  * speculative blocks are exactly revertible: a phase flip discards the
    block and ``cancel_block`` leaves no trace on the rng stream, tickets
    or stats;
  * malicious corruption and pad masking are applied on-device from the
    mask lanes shipped with the bucket.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anm import AnmConfig
from repro.core.engine import AnmEngine, EvalResult, identical_trajectories
from repro.core.grid import GridConfig, malicious_lie
from repro.core.substrates.batched_grid import BatchedVolunteerGrid
from repro.core.substrates.eval_backend import (InProcessEvalBackend,
                                                bucket_size)
from repro.core.substrates.pod_mesh import PodMeshEvalBackend


def _quad_fitness(n=8, seed=3):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = jnp.asarray(A @ A.T + n * np.eye(n, dtype=np.float32))
    x_opt = jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32))

    @jax.jit
    def f_batch(xs):
        d = xs - x_opt[None, :]
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, H, d)

    return f_batch, n


def _run_grid(f_batch, n, *, pipelined, n_hosts=256, tick_batch=None,
              failure_prob=0.1, malicious_prob=0.02, m=48, iters=4,
              backend=None, grid_seed=3, engine_seed=7):
    cfg = AnmConfig(m_regression=m, m_line_search=m, max_iterations=iters)
    gcfg = GridConfig(n_hosts=n_hosts, failure_prob=failure_prob,
                      malicious_prob=malicious_prob, seed=grid_seed)
    engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                       0.5 * np.ones(n), cfg, seed=engine_seed)
    grid = BatchedVolunteerGrid(f_batch, gcfg, tick_batch=tick_batch,
                                backend=backend, pipelined=pipelined)
    stats = grid.run(engine)
    return engine, stats


# -- pipelined == sync parity --------------------------------------------------

@pytest.mark.parametrize("n_hosts,tick_batch,failure_prob,malicious_prob", [
    (256, None, 0.0, 0.0),
    (256, 4, 0.1, 0.02),
    (128, 8, 0.3, 0.1),
    (512, 16, 0.05, 0.01),
])
def test_pipelined_matches_sync_seeded_sweep(n_hosts, tick_batch,
                                             failure_prob, malicious_prob):
    """Bit-identical committed iterates, sim time and final engine stats,
    whether buckets are collected synchronously or ride the pipeline."""
    f_batch, n = _quad_fitness()
    kw = dict(n_hosts=n_hosts, tick_batch=tick_batch,
              failure_prob=failure_prob, malicious_prob=malicious_prob)
    e_pipe, s_pipe = _run_grid(f_batch, n, pipelined=True, **kw)
    e_sync, s_sync = _run_grid(f_batch, n, pipelined=False, **kw)
    assert identical_trajectories(e_pipe, e_sync)
    assert e_pipe.stats == e_sync.stats
    assert s_pipe.sim_time == s_sync.sim_time
    assert s_pipe.completed == s_sync.completed
    assert s_pipe.ticks == s_sync.ticks
    assert s_pipe.corrupted == s_sync.corrupted


def test_pipelined_matches_sync_on_pod_backend():
    f_batch, n = _quad_fitness()
    e_pipe, _ = _run_grid(f_batch, n, pipelined=True, tick_batch=4,
                          backend=PodMeshEvalBackend(f_batch))
    e_sync, _ = _run_grid(f_batch, n, pipelined=False, tick_batch=4)
    assert identical_trajectories(e_pipe, e_sync)


def test_pipeline_actually_runs_deep_and_speculates():
    """A fleet tight relative to the overcommit cap splits issuance across
    ticks, so mid-phase top-ups must ride the speculative peek path while
    earlier buckets are still in flight — and parity must still hold."""
    f_batch, n = _quad_fitness()
    kw = dict(n_hosts=128, tick_batch=8, m=128, iters=3,
              failure_prob=0.15, malicious_prob=0.02)
    e_pipe, s_pipe = _run_grid(f_batch, n, pipelined=True, **kw)
    e_sync, _ = _run_grid(f_batch, n, pipelined=False, **kw)
    assert s_pipe.max_in_flight > 1        # the pipeline really ran ahead
    assert s_pipe.spec_blocks > 0          # speculative issuance engaged
    assert s_pipe.spec_discarded == 0      # exact no-flip prediction
    assert identical_trajectories(e_pipe, e_sync)


# -- zero compiles after construction -----------------------------------------

@pytest.mark.parametrize("backend_cls", [InProcessEvalBackend,
                                         PodMeshEvalBackend])
def test_warmed_backend_never_compiles_mid_run(backend_cls):
    """Constructing with n_dims/max_bucket compiles the whole bucket
    ladder up front; a full grid run (both loop modes) must not add a
    single trace."""
    f_batch, n = _quad_fitness()
    be = backend_cls(f_batch, n_dims=n, max_bucket=128)
    warmed = be.compile_count
    assert warmed > 0
    _run_grid(f_batch, n, pipelined=True, m=48, backend=be)
    _run_grid(f_batch, n, pipelined=False, m=48, backend=be)
    assert be.compile_count == warmed


# -- block speculation: peek / cancel -----------------------------------------

def _engine_pair(n=4, m=20):
    cfg = AnmConfig(m_regression=m, m_line_search=m, max_iterations=3)
    mk = lambda: AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), cfg, seed=5)
    return mk(), mk()


def _skip_bootstrap(engine, f):
    reqs = engine.generate()
    engine.assimilate([EvalResult(r, f(r.point)) for r in reqs])
    while engine.validating:
        reqs = engine.generate()
        if not reqs:
            break
        engine.assimilate([EvalResult(r, f(r.point)) for r in reqs])


def test_peek_then_cancel_is_invisible():
    """cancel_block rewinds the rng stream, ticket counter and issuance
    stat: a twin engine that never speculated generates the identical
    block afterwards."""
    f = lambda p: float(np.sum(np.asarray(p) ** 2))
    a, b = _engine_pair()
    _skip_bootstrap(a, f)
    _skip_bootstrap(b, f)
    peeked = a.peek_block(7)
    assert peeked is not None
    a.cancel_block()
    assert a.stats.issued == b.stats.issued
    blk_a, blk_b = a.generate_block(7), b.generate_block(7)
    np.testing.assert_array_equal(blk_a[0], blk_b[0])      # tickets
    np.testing.assert_array_equal(blk_a[2], blk_b[2])      # points
    np.testing.assert_array_equal(blk_a[3], blk_b[3])      # alphas
    assert a.stats.issued == b.stats.issued


def test_phase_flip_discards_speculative_block():
    """The pipelined grid's bet: a block peeked for phase P is discarded
    when assimilation flips the phase.  After cancel_block the engine must
    continue exactly like a twin that never speculated — same line-search
    blocks, same stats."""
    f = lambda p: float(np.sum(np.asarray(p) ** 2))
    spec, plain = _engine_pair(m=20)
    issued = {}
    for e in (spec, plain):
        _skip_bootstrap(e, f)
        assert e.phase == "regression"
        # the whole regression phase is issued up front (identical draws)
        issued[e] = e.generate_block(20)
    for e, (tk, ph, pts, al) in issued.items():
        # 19 of 20 results land: one short of the flip
        e.assimilate_arrays(ph + np.zeros(19, np.int64), tk[:19], pts[:19],
                            al[:19], np.full(19, -1),
                            np.sum(pts[:19] ** 2, axis=1))
    # the speculating engine peeks the next block, betting on no flip...
    peeked = spec.peek_block(6)
    assert peeked is not None and peeked[1] == spec.phase_id
    # ...but the m-th result lands and the phase flips to the line search
    for e, (tk, ph, pts, al) in issued.items():
        e.assimilate_arrays(np.array([ph]), tk[19:], pts[19:], al[19:],
                            np.array([-1]),
                            np.sum(pts[19:] ** 2, axis=1))
        assert e.phase == "linesearch"
    # the peeked block is stale under the new phase id: discard it
    assert peeked[1] != spec.phase_id
    spec.cancel_block()
    # from here, both engines must be indistinguishable
    assert spec.phase == plain.phase
    assert spec.stats == plain.stats
    ba, bb = spec.generate_block(10), plain.generate_block(10)
    np.testing.assert_array_equal(ba[0], bb[0])      # tickets
    np.testing.assert_array_equal(ba[2], bb[2])      # points
    np.testing.assert_array_equal(ba[3], bb[3])      # alphas


def test_peek_cancel_while_validation_pending_rewinds_ticket_state():
    """A peek taken while quorum replicas are pending generates nothing
    (blocks only exist in regression/line-search), but the cancel must
    rewind the validation ticket state too — the snapshot carries
    ``validations_issued`` and the pending-replica budget, so a substrate
    interleaving many engines can peek anywhere without corrupting a
    pending quorum."""
    f = lambda p: float(np.sum(np.asarray(p) ** 2))
    spec, plain = _engine_pair()
    first = {}
    for e in (spec, plain):
        reqs = e.generate()                    # the f(x0) bootstrap probe
        e.assimilate([EvalResult(r, f(r.point)) for r in reqs])
        assert e.validating and e.validation_pending == e.quorum
        # hand out ONE replica: validation tickets are now mid-stream
        [r1] = e.generate(1)
        assert r1.validates is not None and e.validation_pending == e.quorum - 1
        first[e] = r1
    # the speculating engine peeks mid-validation...
    assert spec.peek_block(5) is None
    spec.cancel_block()
    # ...and must be indistinguishable from the twin that never did
    assert spec.validation_pending == plain.validation_pending
    assert spec.stats == plain.stats
    assert spec._next_ticket == plain._next_ticket
    # the remaining replica and the rest of the validation line up exactly
    [ra], [rb] = spec.generate(), plain.generate()
    assert ra.ticket == rb.ticket and ra.validates == rb.validates
    for e, r in ((spec, ra), (plain, rb)):
        e.assimilate([EvalResult(q, f(q.point)) for q in (first[e], r)])
    assert spec.phase == plain.phase == "regression"
    assert spec.stats == plain.stats


def test_peek_cancel_during_linesearch_validation_keeps_quorum_exact():
    """Same contract deeper in the run: drive a full regression + line
    search to the candidate-validation phase, peek/cancel there, and
    check the twin still validates and commits identically."""
    f = lambda p: float(np.sum(np.asarray(p) ** 2))
    spec, plain = _engine_pair(m=12)
    for e in (spec, plain):
        _skip_bootstrap(e, f)
        while not e.validating:                # regression + line search
            reqs = e.generate()
            e.assimilate([EvalResult(r, f(r.point)) for r in reqs])
        assert e.validation_pending == e.quorum
    assert spec.peek_block() is None
    spec.cancel_block()
    assert spec.validation_pending == plain.validation_pending
    assert spec.stats == plain.stats
    for e in (spec, plain):                    # finish the validation
        reqs = e.generate()
        e.assimilate([EvalResult(r, f(r.point)) for r in reqs])
    assert spec.phase == plain.phase
    assert spec.iteration == plain.iteration
    assert spec.best_fitness == plain.best_fitness
    assert spec.stats == plain.stats


# -- on-device corruption and masking -----------------------------------------

def test_submit_applies_corruption_lanes_on_device():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    pts = np.random.default_rng(0).uniform(-1, 1, (13, n))
    honest = be(pts)
    u = np.full(13, np.nan)
    u[[2, 5, 11]] = [0.2, 0.5, 0.8]
    ys = be(pts, u)
    lied = ~np.isnan(u)
    np.testing.assert_array_equal(ys[~lied], honest[~lied])
    # the lie is computed in the device's f32 lanes — compare against the
    # same formula evaluated at f32 precision
    expect = np.asarray(malicious_lie(honest[lied].astype(np.float32),
                                      u[lied].astype(np.float32)), np.float64)
    np.testing.assert_allclose(ys[lied], expect, rtol=1e-6)
    assert (ys[lied] < honest[lied]).all()   # always an under-report


def test_async_submit_collect_matches_sync_call():
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    rng = np.random.default_rng(1)
    blocks = [rng.uniform(-1, 1, (k, n)) for k in (3, 17, 64)]
    handles = [be.submit(p) for p in blocks]       # all in flight at once
    for p, h in zip(blocks, handles):
        np.testing.assert_array_equal(be.collect(h), be(p))


def test_staging_ring_survives_deep_inflight_reuse():
    """Many in-flight submissions of the SAME bucket shape must not
    corrupt each other (CPU zero-copy aliasing is real: the ring exists
    for exactly this)."""
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    rng = np.random.default_rng(2)
    blocks = [rng.uniform(-1, 1, (16, n)) for _ in range(6)]
    expected = [np.asarray(f_batch(jnp.asarray(p, jnp.float32)), np.float64)
                for p in blocks]
    handles = [be.submit(p) for p in blocks]
    for h, ref in zip(handles, expected):
        np.testing.assert_array_equal(be.collect(h), ref)


def test_staging_ring_overrun_raises_instead_of_corrupting():
    """Restaging a slot whose bucket is uncollected would silently alias
    a buffer the device may still read — submit must refuse loudly, slot
    by slot, so out-of-order collects cannot defeat the guard."""
    from repro.core.substrates.eval_backend import STAGING_RING
    f_batch, n = _quad_fitness()
    be = InProcessEvalBackend(f_batch)
    pts = np.random.default_rng(0).uniform(-1, 1, (16, n))
    handles = [be.submit(pts) for _ in range(STAGING_RING)]
    with pytest.raises(RuntimeError, match="uncollected"):
        be.submit(pts)
    # freeing an arbitrary LATER slot must not unblock the ring: the next
    # submit would restage slot 0, whose bucket is still in flight
    be.collect(handles[5])
    with pytest.raises(RuntimeError, match="uncollected"):
        be.submit(pts)
    be.collect(handles[0])                      # the aliased slot itself
    be.collect(be.submit(pts))
    for i, h in enumerate(handles):
        if i not in (0, 5):
            be.collect(h)


def test_host_s_sane_across_repeated_runs():
    """Stats accumulate across run() calls; host_s must stay a sane
    per-run accumulation, not go negative from mixing a per-run wall
    clock with the cumulative device-blocked total."""
    f_batch, n = _quad_fitness()
    cfg = AnmConfig(m_regression=24, m_line_search=24, max_iterations=2)
    gcfg = GridConfig(n_hosts=64, failure_prob=0.05, malicious_prob=0.0,
                      seed=3)
    grid = BatchedVolunteerGrid(f_batch, gcfg)
    for seed in (1, 2):
        engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), cfg, seed=seed)
        stats = grid.run(engine)
        assert stats.host_s >= 0.0
