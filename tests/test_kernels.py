"""Pallas kernel sweeps vs. the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import wkv6_chunked, _LOG_DECAY_MIN


def _expand_gqa(q, k, v):
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qe = q.reshape(b, s, hkv, g, d).transpose(0, 2, 3, 1, 4).reshape(b, hq, s, d)
    ke = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
    ve = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
    return qe, ke, ve


def _unexpand(o, b, s, hq, d):
    return o.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (2, 256, 4, 2, 64),
    (1, 128, 8, 8, 128),
    (2, 256, 6, 2, 120),     # non-MXU-aligned head dim (danube) -> padded
    (1, 512, 2, 1, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, hq, hkv, d, dtype):
    ks = jax.random.split(jax.random.key(b * s + hq + d), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    qe, ke, ve = _expand_gqa(q, k, v)
    want = _unexpand(ref.attention_ref(qe, ke, ve, causal=True), b, s, hq, d)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    b, s, hq, hkv, d = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.key(window), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    qe, ke, ve = _expand_gqa(q, k, v)
    want = _unexpand(ref.attention_ref(qe, ke, ve, causal=True, window=window),
                     b, s, hq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, d = 1, 128, 4, 64
    ks = jax.random.split(jax.random.key(9), 3)
    q, k, v = (jax.random.normal(ks[i], (b, s, h, d)) for i in range(3))
    out = ops.flash_attention(q, k, v, causal=False)
    qe, ke, ve = _expand_gqa(q, k, v)
    want = _unexpand(ref.attention_ref(qe, ke, ve, causal=False), b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,t,h,kk", [(2, 64, 2, 16), (1, 128, 4, 32),
                                      (2, 96, 3, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_sweep(b, t, h, kk, dtype):
    ks = jax.random.split(jax.random.key(t * h + kk), 5)
    r = jax.random.normal(ks[0], (b, t, h, kk), dtype)
    k = jax.random.normal(ks[1], (b, t, h, kk), dtype)
    v = jax.random.normal(ks[2], (b, t, h, kk), dtype)
    lw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, t, h, kk))),
                  _LOG_DECAY_MIN, -1e-6).astype(dtype)
    u = (jax.random.normal(ks[4], (h, kk)) * 0.1).astype(dtype)
    got = ops.wkv6(r, k, v, lw, u, chunk=32)
    want, _ = ref.wkv6_ref(r, k, v, lw, u)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_wkv6_chunked_matches_ref():
    """The jnp chunked-parallel form (training path) vs sequential oracle."""
    b, t, h, kk = 2, 128, 4, 16
    ks = jax.random.split(jax.random.key(0), 5)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, kk)) for i in range(3))
    lw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, t, h, kk))),
                  _LOG_DECAY_MIN, -1e-6)
    u = jax.random.normal(ks[4], (h, kk)) * 0.1
    got, s_got = wkv6_chunked(r, k, v, lw, u)
    want, s_want = ref.wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,c", [(256, 45), (1024, 153), (300, 20), (512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sweep(m, c, dtype):
    ks = jax.random.split(jax.random.key(m + c), 2)
    x = jax.random.normal(ks[0], (m, c), dtype)
    y = jax.random.normal(ks[1], (m,), dtype)
    g, r = ops.gram(x, y)
    g_ref, r_ref = ref.gram_ref(x, y)
    tol = 2e-3 if dtype == jnp.float32 else 1.0
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=tol, atol=tol * 8)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref),
                               rtol=tol, atol=tol * 8)


def test_gram_feeds_regression():
    """End-to-end: kernel gram products solve the same normal equations."""
    from repro.core import regression as reg
    rng = np.random.default_rng(1)
    n = 6
    A = rng.normal(size=(n, n)); H = (A + A.T) / 2
    gvec = rng.normal(size=n)
    m = 512
    d = rng.uniform(-1, 1, (m, n))
    ys = d @ gvec + 0.5 * np.einsum("mi,ij,mj->m", d, H, d)
    X = reg.design_matrix(jnp.asarray(d, jnp.float32))
    G, r = ops.gram(X, jnp.asarray(ys, jnp.float32))
    lam = 1e-7 * float(jnp.max(jnp.diagonal(G)))
    beta = jnp.linalg.solve(G + lam * jnp.eye(G.shape[0]), r)
    _, g_hat, H_hat = reg.unpack(beta, n)
    np.testing.assert_allclose(np.asarray(g_hat), gvec, rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(H_hat), H, rtol=5e-2, atol=5e-2)
