"""Multi-search orchestrator: coalesced buckets, fleet scheduling, and the
search-level parity contract (DESIGN.md §8).

The contracts under test:

  * orchestration changes WHEN lanes are evaluated, never what an engine
    sees — every search in a coalesced multi-search run commits
    bit-identical iterates (and identical final ``EngineStats``) to the
    same spec run alone, on both evaluation backends;
  * coalescing actually amortizes: in the long-phase regime one device
    dispatch serves many per-search blocks, and lane tags demux the shared
    bucket back to the right searches bit-exactly;
  * portfolio policies only stop stepping searches: a killed search's
    committed history is a PREFIX of its solo run; restarts are fresh
    deterministic specs whose trajectories are solo-reproducible too;
  * a warmed shared backend stays zero-compile through a coalesced
    multi-search run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.anm import AnmConfig
from repro.core.engine import AnmEngine, identical_trajectories
from repro.core.grid import GridConfig
from repro.core.orchestrator import (CoalescingSubmitter, FleetScheduler,
                                     SearchDirector, SearchSpec,
                                     multi_start_specs)
from repro.core.substrates.batched_grid import BatchedVolunteerGrid
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.core.substrates.pod_mesh import PodMeshEvalBackend

pytestmark = pytest.mark.orchestrator


def _quad_fitness(n=8, seed=3):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = jnp.asarray(A @ A.T + n * np.eye(n, dtype=np.float32))
    x_opt = jnp.asarray(rng.uniform(-0.5, 0.5, n).astype(np.float32))

    @jax.jit
    def f_batch(xs):
        d = xs - x_opt[None, :]
        return 0.5 * jnp.einsum("mi,ij,mj->m", d, H, d)

    return f_batch, n


def _solo_run(spec: SearchSpec, backend, *, pipelined=True):
    """The parity baseline — `SearchSpec.solo_run` is the ONE shared
    construction (tests, dryrun smoke, benchmark, example all use it)."""
    return spec.solo_run(backend, pipelined=pipelined)


def _portfolio(backend, n_searches=4, *, n_hosts=512, m=32, iters=3,
               configs=None, policy="fixed", fleet_seed=3, **director_kw):
    f_batch, n = _quad_fitness()
    fleet = GridConfig(n_hosts=n_hosts, failure_prob=0.1,
                       malicious_prob=0.02, seed=fleet_seed)
    sched = FleetScheduler(backend, fleet)
    anm = AnmConfig(m_regression=m, m_line_search=m, max_iterations=iters)
    specs = multi_start_specs(sched, np.ones(n), -10 * np.ones(n),
                              10 * np.ones(n), 0.5 * np.ones(n), anm,
                              n_searches, seed=0, jitter=0.3,
                              configs=configs)
    director = SearchDirector(sched, specs, policy, **director_kw)
    return director.run(), sched


# -- the parity contract ------------------------------------------------------

def test_coalesced_searches_match_solo_runs_bit_identically():
    """Heterogeneous portfolio (two different m's), coalesced over one
    backend: every search's committed iterates AND final engine stats
    must equal the same spec run alone."""
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    hetero = [AnmConfig(m_regression=32, m_line_search=32, max_iterations=3),
              AnmConfig(m_regression=48, m_line_search=48, max_iterations=2)]
    res, _ = _portfolio(backend, 4, configs=hetero)
    assert len(res.outcomes) == 4
    for o in res.outcomes:
        assert o.status == "done"
        solo = _solo_run(o.spec, backend)
        assert identical_trajectories(o.engine, solo)
        assert o.engine.stats == solo.stats
    # the coalescer really ran: shared buckets served per-search blocks
    assert res.coalesce_stats.dispatches < res.coalesce_stats.lane_blocks
    assert res.coalesce_stats.lanes > 0


def test_multi_search_parity_on_pod_backend():
    """The same contract through the shard_map backend (degenerate mesh on
    a single-device CPU — the real 16x16 runs in the dryrun smoke)."""
    f_batch, _ = _quad_fitness()
    backend = PodMeshEvalBackend(f_batch)
    res, _ = _portfolio(backend, 3, n_hosts=384, m=24, iters=2)
    for o in res.outcomes:
        solo = _solo_run(o.spec, backend)
        assert identical_trajectories(o.engine, solo)
        assert o.engine.stats == solo.stats


def test_uncoalesced_scheduler_still_matches_solo():
    """coalesce=False (the serial-equivalent dispatch mode the benchmarks
    compare against) must preserve the identical trajectories too."""
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    fleet = GridConfig(n_hosts=256, failure_prob=0.1, malicious_prob=0.02,
                       seed=3)
    sched = FleetScheduler(backend, fleet, coalesce=False)
    anm = AnmConfig(m_regression=24, m_line_search=24, max_iterations=2)
    specs = multi_start_specs(sched, np.ones(8), -10 * np.ones(8),
                              10 * np.ones(8), 0.5 * np.ones(8), anm, 2)
    res = SearchDirector(sched, specs).run()
    assert res.coalesce_stats is None
    for o in res.outcomes:
        assert identical_trajectories(o.engine, _solo_run(o.spec, backend))


def test_uncoalesced_deep_pipelines_survive_the_shared_staging_ring():
    """Many uncoalesced searches pipelining deep stack more same-shape
    in-flight buckets than one grid's depth clamp accounts for; the
    scheduler's shared ring guard must drain the oldest early instead of
    letting the backend raise — with trajectories still solo-identical.
    (Regression: this exact shape crashed with 'uncollected submission
    still aliases staging slot' before the guard existed.)"""
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    fleet = GridConfig(n_hosts=768, failure_prob=0.1, malicious_prob=0.02,
                       seed=3)
    sched = FleetScheduler(backend, fleet, coalesce=False,
                           pipeline_depth=6)
    anm = AnmConfig(m_regression=96, m_line_search=96, max_iterations=2)
    specs = multi_start_specs(sched, np.ones(8), -10 * np.ones(8),
                              10 * np.ones(8), 0.5 * np.ones(8), anm, 6,
                              jitter=0.3)
    res = SearchDirector(sched, specs).run()
    assert sched.ring_guard.ring_drains > 0    # the guard really engaged
    for o in res.outcomes:
        assert identical_trajectories(
            o.engine, _solo_run(o.spec, backend, pipelined=True))


# -- coalescing mechanics -----------------------------------------------------

def test_coalescing_amortizes_dispatches_in_long_phases():
    """Long phases (rare phase-boundary collects) are the regime the
    coalescer exists for: most rounds must fold every live search's block
    into ONE dispatch."""
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    res, _ = _portfolio(backend, 4, n_hosts=512, m=96, iters=2)
    st = res.coalesce_stats
    # 4 searches' blocks per round; boundaries force some extra dispatches
    assert st.dispatches < 0.5 * st.lane_blocks
    for o in res.outcomes:
        assert identical_trajectories(
            o.engine, _solo_run(o.spec, InProcessEvalBackend(f_batch)))


def test_lane_tags_demux_shared_bucket():
    """Two searches' blocks in one shared bucket: collect must hand each
    search exactly the values its own solo bucket would have produced,
    and the handle's lane tags must map lanes to search ids."""
    f_batch, n = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    co = CoalescingSubmitter(backend)
    sub_a, sub_b = co.lane_submitter(0), co.lane_submitter(1)
    rng = np.random.default_rng(0)
    pts_a = rng.uniform(-1, 1, (5, n))
    pts_b = rng.uniform(-1, 1, (9, n))
    u_b = np.full(9, np.nan)
    u_b[[1, 4]] = [0.3, 0.7]           # corruption lanes stay per-lane
    lane_a = sub_a.submit(pts_a)
    lane_b = sub_b.submit(pts_b, u_b)
    co.flush()
    assert co.stats.dispatches == 1 and co.stats.lane_blocks == 2
    handle = lane_a.round_.handle
    np.testing.assert_array_equal(handle.tags[:5], 0)
    np.testing.assert_array_equal(handle.tags[5:14], 1)
    assert lane_a.kp == handle.kp
    ys_a = sub_a.collect(lane_a)
    ys_b = sub_b.collect(lane_b)
    np.testing.assert_array_equal(ys_a, backend(pts_a))
    np.testing.assert_array_equal(ys_b, backend(pts_b, u_b))


def test_collect_before_flush_forces_the_round_out():
    """A search that must decide a phase transition mid-round cannot wait
    for the others: collecting an undispatched lane flushes the open
    round immediately, and later submits open a new round."""
    f_batch, n = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    co = CoalescingSubmitter(backend)
    rng = np.random.default_rng(1)
    pts = rng.uniform(-1, 1, (6, n))
    lane = co.lane_submitter(0).submit(pts)
    ys = co.collect(lane)              # round still open -> forced flush
    np.testing.assert_array_equal(ys, backend(pts))
    assert co.stats.forced_flushes == 1 and co.stats.dispatches == 1
    pts2 = rng.uniform(-1, 1, (3, n))
    lane2 = co.lane_submitter(1).submit(pts2)
    co.flush()
    np.testing.assert_array_equal(co.collect(lane2), backend(pts2))
    assert co.stats.dispatches == 2


def test_warmed_backend_stays_zero_compile_through_multi_search():
    """The coalesced ladder (sum of per-search warm bounds) is compiled by
    the director's warm-up; the run itself must not add a single trace."""
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    res, _ = _portfolio(backend, 3, n_hosts=384, m=24, iters=2)
    warmed = backend.compile_count
    assert warmed > 0
    res2, _ = _portfolio(backend, 3, n_hosts=384, m=24, iters=2)
    assert backend.compile_count == warmed
    for a, b in zip(res.outcomes, res2.outcomes):
        assert identical_trajectories(a.engine, b.engine)


def test_identical_trajectories_separates_independently_seeded_engines():
    """The parity predicate must have teeth across a multi-start
    portfolio: engines on the SAME problem with different seeds (or
    different sub-fleets) diverge and must compare unequal, while a true
    re-run compares equal — otherwise every gate in this file could
    vacuously pass."""
    f_batch, n = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    grid_cfg = GridConfig(n_hosts=128, failure_prob=0.05,
                          malicious_prob=0.01, seed=3)
    # 4 iterations: this workload's first committed improvement lands at
    # iteration 4, and only improving commits make seeds distinguishable
    anm = AnmConfig(m_regression=32, m_line_search=32, max_iterations=4)

    def run(engine_seed, grid_seed=3):
        engine = AnmEngine(np.ones(n), -10 * np.ones(n), 10 * np.ones(n),
                           0.5 * np.ones(n), anm, seed=engine_seed)
        BatchedVolunteerGrid(
            None, dataclasses.replace(grid_cfg, seed=grid_seed),
            backend=backend).run(engine)
        return engine

    base, rerun = run(7), run(7)
    assert identical_trajectories(base, rerun)
    assert not identical_trajectories(base, run(8))       # engine seed
    assert not identical_trajectories(base, run(7, 4))    # sub-fleet seed


# -- fleet partitioning -------------------------------------------------------

def test_partition_and_subfleet_are_deterministic():
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    fleet = GridConfig(n_hosts=1024, seed=11)
    sched = FleetScheduler(backend, fleet, min_hosts=32)
    assert sched.partition(4) == 256
    assert sched.partition(128) == 32          # floored, never starved
    subs = [sched.subfleet(i, 4) for i in range(4)]
    assert all(s.n_hosts == 256 for s in subs)
    assert len({s.seed for s in subs}) == 4    # distinct sub-fleets
    # deterministic: the same slot always yields the same sub-fleet
    assert sched.subfleet(2, 4) == subs[2]


# -- portfolio policies -------------------------------------------------------

def test_portfolio_kill_retires_dominated_search_as_solo_prefix():
    """A search started far from the optimum is killed once it trails the
    incumbent past probation — and its committed history must be exactly
    the first iterations of its solo run (stopping early is the ONLY
    thing a kill may do)."""
    f_batch, n = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    fleet = GridConfig(n_hosts=512, failure_prob=0.05, malicious_prob=0.01,
                       seed=5)
    sched = FleetScheduler(backend, fleet)
    anm = AnmConfig(m_regression=32, m_line_search=32, max_iterations=6)
    specs = multi_start_specs(sched, np.zeros(n), -10 * np.ones(n),
                              10 * np.ones(n), 0.5 * np.ones(n), anm, 3,
                              jitter=0.1)
    # doom one search: start it in a far corner with a tiny step so it
    # cannot catch the incumbent within its probation
    bad = dataclasses.replace(specs[1], x0=9.5 * np.ones(n),
                              step=0.05 * np.ones(n))
    specs = [specs[0], bad, specs[2]]
    # margin of 2.0 (on the |best|+1 scale): the near-start survivors
    # differ by far less, the far-corner search by orders of magnitude
    res = SearchDirector(sched, specs, "portfolio", kill_margin=2.0,
                         probation_iterations=2).run()
    by_name = {o.spec.name: o for o in res.outcomes}
    killed = by_name[bad.name]
    assert killed.status == "killed"
    assert killed.engine.iteration < anm.max_iterations
    solo = _solo_run(bad, backend)
    assert len(solo.history) >= len(killed.engine.history) > 0
    for got, want in zip(killed.engine.history, solo.history):
        np.testing.assert_array_equal(got.center, want.center)
        assert got.best_fitness == want.best_fitness
    # the survivors ran to completion and stayed solo-identical
    for name in (specs[0].name, specs[2].name):
        o = by_name[name]
        assert o.status == "done"
        assert identical_trajectories(o.engine, _solo_run(o.spec, backend))
    assert res.best.spec.name != bad.name


def test_restart_policy_spawns_deterministic_solo_reproducible_restarts():
    f_batch, _ = _quad_fitness()
    backend = InProcessEvalBackend(f_batch)
    res, sched = _portfolio(backend, 2, n_hosts=256, m=24, iters=2,
                            policy="restart", max_restarts=2, seed=13)
    assert len(res.outcomes) == 4              # 2 originals + 2 restarts
    restarts = [o for o in res.outcomes if "~r" in o.spec.name]
    assert len(restarts) == 2
    for o in res.outcomes:
        assert o.status == "done"
        # a restart's spec is fully recorded, so it is solo-reproducible
        # like any other search — the parity contract has no exceptions
        assert identical_trajectories(o.engine, _solo_run(o.spec, backend))
    # fresh seeds, not reruns of the dead search
    names = {o.spec.engine_seed for o in res.outcomes}
    assert len(names) == 4
    assert sched.stats.admitted == 4
