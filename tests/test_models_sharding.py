"""Sharding-spec construction on the (modeled) production 16×16 mesh.

Spec assignment is pure shape arithmetic — ``param_specs`` /
``input_specs`` / ``enforce_divisible`` only read ``mesh.shape`` and
``mesh.axis_names`` — so these tests model the forced 512-device mesh
with ``jax.sharding.AbstractMesh`` and run on the single real CPU device.

The pinned contract (DESIGN.md §11): for EVERY registered smoke config,
every surviving spec entry divides its mesh axes evenly, and every
non-dividing assignment is downgraded to replication EXPLICITLY —
reported by ``enforce_divisible``, never silently padded.  The two LM
workload archs additionally pin their exact fallback sets, so a rule
change that silently re-shards (or stops sharding) a smoke tensor fails
loudly here.
"""
import functools

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_NAMES, ShapeConfig, get_smoke_config
from repro.models import transformer as T
from repro.models.sharding import enforce_divisible, input_specs, param_specs

MESH = AbstractMesh((("data", 16), ("model", 16)))


def _axis_size(entry) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= MESH.shape[a]
    return size


def _leaves_with_specs(cfg, specs):
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.key(0))
    return zip(jax.tree.leaves(shapes),
               jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
class TestEverySmokeConfig:
    def test_param_specs_divide_or_fall_back(self, arch):
        cfg = get_smoke_config(arch)
        specs, fallbacks = enforce_divisible(cfg, MESH)
        # 1) every surviving entry divides evenly
        for leaf, spec in _leaves_with_specs(cfg, specs):
            for dim, entry in enumerate(spec):
                if entry is not None:
                    assert leaf.shape[dim] % _axis_size(entry) == 0, (
                        f"{arch}: {spec} does not divide {leaf.shape}")
        # 2) every downgrade is explicit and true: the reported dim
        # really does not divide the axis it was assigned
        for path, dim, entry, dim_size in fallbacks:
            assert dim_size % _axis_size(entry) != 0, (
                f"{arch}: {path} reported as fallback but divides")

    def test_input_specs_divide_or_fall_back(self, arch):
        cfg = get_smoke_config(arch)
        for b, s in ((2, 32), (16, 32), (64, 128)):
            shape = ShapeConfig("t", seq_len=s, global_batch=b,
                                kind="train")
            sds, specs = input_specs(cfg, shape, MESH)
            for name, spec in specs.items():
                for dim, entry in enumerate(spec):
                    if entry is not None:
                        assert (sds[name].shape[dim] % _axis_size(entry)
                                == 0), (f"{arch} {name}: {spec} vs "
                                        f"{sds[name].shape}")

    def test_small_batch_replicates(self, arch):
        # a 2-row batch cannot split 16 ways: the rule must fall back to
        # replication, not emit a non-dividing spec
        cfg = get_smoke_config(arch)
        shape = ShapeConfig("t", seq_len=32, global_batch=2, kind="train")
        _, specs = input_specs(cfg, shape, MESH)
        key = "embeds" if cfg.frontend == "audio_stub" else "tokens"
        assert specs[key][0] is None

    def test_enforce_divisible_idempotent(self, arch):
        cfg = get_smoke_config(arch)
        once, _ = enforce_divisible(cfg, MESH)
        twice, again = enforce_divisible(cfg, MESH, specs=once)
        assert again == []
        assert jax.tree.all(jax.tree.map(
            lambda a, b: a == b, once, twice,
            is_leaf=lambda x: isinstance(x, P)))


class TestWorkloadArchPins:
    """The two LM-workload smoke configs pin their exact fallback sets."""

    def test_rwkv6_fallbacks(self):
        _, fallbacks = enforce_divisible(get_smoke_config("rwkv6-7b"),
                                         MESH)
        # 4 rwkv heads (and the 224-wide ffn gate) cannot split model=16
        names = sorted({p.split("/")[-1] for p, *_ in fallbacks})
        assert names == ["ln_out", "u", "w0", "w_g", "w_k", "w_lora_b",
                         "w_o", "w_r", "w_v"]
        assert all(dim_size in (4, 224) for *_, dim_size in fallbacks)

    def test_danube_fallbacks(self):
        _, fallbacks = enforce_divisible(
            get_smoke_config("h2o-danube-3-4b"), MESH)
        # 4 q heads / 2 kv heads cannot split model=16; everything else
        # (embeddings, ffn, lm head) divides
        assert sorted(p.split("/")[-1] for p, *_ in fallbacks) == \
            ["wo", "wq"]
        assert all(dim_size == 4 for *_, dim_size in fallbacks)

    def test_untouched_specs_still_shard(self):
        # the enforcement must not over-replicate: leaves that DO divide
        # keep their model-axis assignment (the storage-scaling claim of
        # the pod LM backend depends on at least the embedding sharding)
        cfg = get_smoke_config("rwkv6-7b")
        specs, _ = enforce_divisible(cfg, MESH)
        flat = {
            "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path): spec
            for path, spec in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}
        sharded = [p for p, s in flat.items()
                   if any(e is not None for e in s)]
        assert any(p.endswith("tok") for p in sharded)
        assert any(p.endswith("w") for p in sharded)      # lm head


class TestBackendSpecComposition:
    def test_basis_specs_mirror_param_specs(self):
        # the pod LM backend prepends a replicated lane axis to every
        # param spec; the pair must stay tree-aligned and divisible
        cfg = get_smoke_config("rwkv6-7b")
        specs, _ = enforce_divisible(cfg, MESH)
        bspecs = jax.tree.map(lambda s: P(*((None,) + tuple(s))), specs,
                              is_leaf=lambda x: isinstance(x, P))
        for spec, bspec in zip(
                jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
                jax.tree.leaves(bspecs,
                                is_leaf=lambda x: isinstance(x, P))):
            assert bspec[0] is None
            assert tuple(bspec[1:]) == tuple(spec)
