"""Ref-vs-Pallas parity for the routed model hot paths — INSIDE the
bucket ladder.

``kernels/compat.route_pallas`` decides at trace time whether a model
forward's attention / wkv6 legs run through the Pallas kernels or the
pure-jnp ref oracles.  ``tests/test_kernels.py`` sweeps the kernels
against the oracles op-by-op; these tests pin the other half of the
DESIGN.md §11 contract: the SAME parity must hold when the routed ops
are traced inside ``LmLossEvalBackend``'s jitted bucket ladder (per-lane
``lax.map``, malicious-lane corruption, pad masking around them), which
is where they actually run in production.  The CPU ref fallback is the
tier-1 default route, so every other LM-backend test exercises it; here
we force interpret-mode Pallas (``route_pallas`` override) and compare.
"""
import numpy as np
import pytest

import repro.kernels.compat as compat
from repro.core.substrates.lm_loss import LmLossEvalBackend, make_lm_workload

#: one arch per routed kernel leg: rwkv6 exercises the wkv6 chunked scan,
#: the dense sliding-window danube config exercises flash attention
ARCHS = {"rwkv6-7b": "wkv6", "h2o-danube-3-4b": "flash_attention"}


def _ladder_eval(workload, pts):
    be = LmLossEvalBackend(workload)
    mal = np.full(len(pts), np.nan)
    return be.collect(be.submit(pts, mal, list(range(len(pts)))))


@pytest.fixture
def force_pallas(monkeypatch):
    # trace-time override: every routed leg takes the Pallas kernel,
    # which on CPU runs in interpret mode (ops.py's interpret default)
    monkeypatch.setattr(compat, "route_pallas",
                        lambda override=None: True)


class TestRouting:
    def test_cpu_default_is_ref(self):
        assert compat.route_pallas() is False       # CPU container
        assert compat.route_pallas(override=True) is True
        assert compat.route_pallas(override=False) is False

    def test_smoke_configs_route_kernels(self):
        # the workload definitions opt in: a smoke config reaching the
        # backend has use_kernels set, so the routed legs are really in
        # the traced ladder (not silently dense)
        for arch in ARCHS:
            assert make_lm_workload(arch, k=2, batch_size=1,
                                    seq_len=8).cfg.use_kernels

    def test_routed_off_matches_dense(self):
        # use_kernels=False is the pinned-numbers dense path; the ref
        # route must not change which computation runs when it's off
        wl_off = make_lm_workload("rwkv6-7b", k=3, batch_size=1,
                                  seq_len=16, seed=2, use_kernels=False)
        wl_ref = make_lm_workload("rwkv6-7b", k=3, batch_size=1,
                                  seq_len=16, seed=2, use_kernels=True)
        pts = np.random.default_rng(0).uniform(-0.4, 0.4, (2, 3))
        ys_off = _ladder_eval(wl_off, pts)
        ys_ref = _ladder_eval(wl_ref, pts)
        np.testing.assert_allclose(ys_ref, ys_off, rtol=2e-2)


@pytest.mark.parametrize("arch", sorted(ARCHS), ids=lambda a: ARCHS[a])
class TestInLadderParity:
    def test_ref_vs_pallas_in_ladder(self, arch, force_pallas):
        wl = make_lm_workload(arch, k=3, batch_size=1, seq_len=16, seed=1)
        pts = np.random.default_rng(7).uniform(-0.4, 0.4, (3, 3))
        # ref route first, built OUTSIDE the override...
        with pytest.MonkeyPatch.context() as mp:
            mp.setattr(compat, "route_pallas",
                       lambda override=None: False)
            ys_ref = _ladder_eval(wl, pts)
        # ...then the interpret-Pallas route through the identical ladder
        ys_pal = _ladder_eval(wl, pts)
        assert np.all(np.isfinite(ys_pal))
        # wkv6 ref and kernel agree bitwise at this scale; flash
        # attention reassociates the softmax (blocked online max/sum), so
        # the loss moves in the last few ulps — same tolerance family as
        # the op-level sweeps in test_kernels.py
        np.testing.assert_allclose(ys_pal, ys_ref, rtol=1e-3, atol=1e-3)

    def test_pallas_route_malicious_and_pad_framing(self, arch,
                                                    force_pallas):
        # the bucket framing (corruption + NaN pad lanes) must compose
        # with the kernel route too — 2 real lanes ride a bucket of 8
        wl = make_lm_workload(arch, k=3, batch_size=1, seq_len=16, seed=1)
        be = LmLossEvalBackend(wl)
        pts = np.tile(np.asarray([0.1, -0.2, 0.3]), (2, 1))
        honest = be.collect(be.submit(pts, np.full(2, np.nan), [0, 1]))
        lied = be.collect(be.submit(pts, np.asarray([np.nan, 0.4]),
                                    [0, 1]))
        assert honest[0] == lied[0]
        assert lied[1] != honest[1] and np.isfinite(lied[1])
