"""Chaos-hardened work service tests (DESIGN.md §12).

The robustness claim under test: N concurrent TCP clients, each behind a
seeded fault injector (drops, duplicates, delays, resets, torn writes),
commit BIT-IDENTICAL iterates and identical engine stats to a fault-free
serial loopback baseline — including across a simulated kill + restore
mid-chaos.  The supporting layers get their own pins: the sequenced
intake's reorder buffer, (host, cs) idempotency (no double votes, no
leaked leases), the duplicate-report-after-lapse accounting fix, the
malformed-frame fuzz survival contract, and sqlite eval-cache recovery
when the cache ran AHEAD of the replay log at the kill.
"""
import json
import os
import socket
import struct
import threading

import numpy as np
import pytest

from repro.core.engine import identical_trajectories
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.core.substrates.eval_cache import EvalCache, SqliteCacheStore
from repro.server import protocol
from repro.server.chaos import PRESETS, ChaosStats, FaultPlan
from repro.server.server import SequencedIntake, WorkServer
from repro.server.sim import ServerSubstrate, SimulatedCrash, smoke_problem
from repro.server.transport import TcpConnection, TcpTransport

pytestmark = pytest.mark.chaos


# -- shared small workload -----------------------------------------------------

@pytest.fixture(scope="module")
def problem():
    return smoke_problem(n_stars=120, n_hosts=40, m=10, iterations=2)


@pytest.fixture(scope="module")
def backend(problem):
    _, _, f_batch = problem
    return InProcessEvalBackend(f_batch)


@pytest.fixture(scope="module")
def baseline(problem, backend):
    spec, fleet, _ = problem
    return ServerSubstrate(spec, fleet, backend).run()


def _run(problem, backend, **kw):
    spec, fleet, _ = problem
    return ServerSubstrate(spec, fleet, backend, **kw).run(
        resume=kw.pop("resume", False) if "resume" in kw else False)


# -- the sequenced intake ------------------------------------------------------

def test_sequenced_intake_replays_canonical_order():
    handled = []
    intake = SequencedIntake(lambda m: handled.append(m["intake_seq"]) or
                             {"kind": "ack"})
    order = [3, 0, 4, 1, 2]
    threads = [threading.Thread(
        target=intake.submit, args=({"intake_seq": s},)) for s in order]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30.0)
    assert handled == [0, 1, 2, 3, 4]
    assert intake.next_seq == 5
    assert intake.parked > 0


def test_sequenced_intake_unstamped_is_stamp_neutral():
    """A status probe (no stamp) mid-stream must be handled immediately
    and never consume a stamp — otherwise one monitoring poll would park
    the entire stamped stream forever."""
    handled = []
    intake = SequencedIntake(lambda m: handled.append(
        m.get("intake_seq", "probe")) or {"kind": "ack"})
    intake.submit({"intake_seq": 0})
    intake.submit({"kind": "status"})           # unstamped
    intake.submit({"intake_seq": 1})
    assert handled == [0, "probe", 1]
    assert intake.next_seq == 2


def test_sequenced_intake_late_duplicate_out_of_band():
    handled = []
    intake = SequencedIntake(lambda m: handled.append(m["intake_seq"]) or
                             {"kind": "ack"})
    intake.submit({"intake_seq": 0})
    intake.submit({"intake_seq": 1})
    intake.submit({"intake_seq": 0})            # a retry racing its ack
    assert handled == [0, 1, 0]
    assert intake.next_seq == 2
    assert intake.out_of_band == 1


# -- fault plans ---------------------------------------------------------------

def test_fault_plan_roundtrip_and_determinism():
    for name, plan in PRESETS.items():
        assert plan.name == name
        assert FaultPlan.from_doc(plan.to_doc()) == plan
    p = PRESETS["drop_dup"]
    assert p.draws(3, 7, 1) == p.draws(3, 7, 1)
    assert p.draws(3, 7, 1) != p.draws(3, 7, 2)
    assert p.draws(3, 7, 1) != p.draws(4, 7, 1)


# -- the tentpole gates: chaos parity ------------------------------------------

def test_concurrent_clean_parity(problem, backend, baseline):
    eb = baseline.engines[0]
    res = _run(problem, backend, transport="loopback", concurrent=8)
    assert identical_trajectories(eb, res.engines[0])
    assert eb.stats == res.engines[0].stats
    assert res.intake["parked"] > 0             # reordering actually happened


@pytest.mark.parametrize("preset,expect", [
    ("drop_dup", ("drops_request", "drops_reply", "duplicates")),
    ("reorder_delay", ("delays", "duplicates")),
    ("reset_torn", ("resets", "torn_writes", "drops_reply")),
])
def test_chaos_tcp_parity(problem, backend, baseline, preset, expect):
    """≥8 concurrent TCP clients under a seeded fault schedule commit the
    serial fault-free baseline's exact trajectory — and the schedule must
    have actually injected every fault class it advertises."""
    eb = baseline.engines[0]
    res = _run(problem, backend, transport="tcp", concurrent=8,
               chaos=preset)
    assert identical_trajectories(eb, res.engines[0])
    assert eb.stats == res.engines[0].stats
    for field in expect:
        assert res.chaos[field] > 0, f"{preset} never injected {field}"
    assert res.chaos["plan"] == PRESETS[preset].to_doc()


def test_chaos_crash_resume_parity(problem, backend, baseline, tmp_path):
    """Kill the run mid-chaos (message budget), restore from snapshot +
    replay log, finish under the SAME fault plan: still bit-identical."""
    eb = baseline.engines[0]
    kw = dict(transport="tcp", concurrent=8, chaos="reset_torn",
              ckpt_dir=str(tmp_path), snapshot_every=150)
    spec, fleet, _ = problem
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(spec, fleet, backend,
                        max_messages=baseline.pool.messages // 2,
                        **kw).run()
    res = ServerSubstrate(spec, fleet, backend, **kw).run(resume=True)
    assert res.resumed
    assert identical_trajectories(eb, res.engines[0])
    assert eb.stats == res.engines[0].stats


# -- idempotency pins (the unit-level contracts behind the parity gate) --------

def _mini_server(problem):
    spec, fleet, _ = problem
    return WorkServer([spec], lease_timeout=8.0 * fleet.base_eval_time,
                      idle_retry=fleet.idle_retry)


def test_duplicate_request_work_leaks_no_second_lease(problem):
    srv = _mini_server(problem)
    srv.handle(protocol.register(0, 0.0, cs=0))
    rep1 = srv.handle(protocol.request_work(0, 1.0, cs=1))
    assert rep1["kind"] == "work"
    rep2 = srv.handle(protocol.request_work(0, 1.0, cs=1))  # duplicated frame
    assert rep2 == rep1                         # cached reply, same wu
    assert srv.counters.leases_issued == 1
    assert len(srv.leases) == 1
    assert srv.counters.duplicates_suppressed == 1


def test_retried_report_casts_one_vote(problem):
    srv = _mini_server(problem)
    srv.handle(protocol.register(0, 0.0, cs=0))
    rep = srv.handle(protocol.request_work(0, 1.0, cs=1))
    msg = protocol.report_result(0, rep["search"], rep["wu"], 1.5, 2.0,
                                 cs=2)
    before = srv.counters.messages
    ack1 = srv.handle(msg)
    ack2 = srv.handle(dict(msg))                # the retry after a lost reply
    assert ack2 == ack1
    assert srv.counters.messages == before + 1  # applied exactly once
    assert srv.registry.hosts[0].returned == 1
    assert srv.counters.duplicates_suppressed == 1


def test_stale_duplicate_is_refused_with_echo(problem):
    srv = _mini_server(problem)
    srv.handle(protocol.register(0, 0.0, cs=0))
    srv.handle(protocol.request_work(0, 1.0, cs=1))
    rep = srv.handle(protocol.request_work(0, 6.0, cs=0))  # below the window
    assert rep["kind"] == "error"
    assert rep["cs"] == 0 and rep["host_id"] == 0   # reply-matching keys
    assert srv.counters.stale_duplicates == 1


def test_duplicate_report_after_lapse_not_counted_twice(problem):
    """Satellite fix: a host re-reporting work whose lease records are
    already gone (first report raced a lapse, or the ack was lost beyond
    the cs window) is a benign retransmit — it must be classified as
    ``duplicate_reports``, not protocol misuse, and must NEVER inflate
    the registry's ``returned`` reliability numerator."""
    srv = _mini_server(problem)
    srv.handle(protocol.register(0, 0.0, cs=0))
    rep = srv.handle(protocol.request_work(0, 1.0, cs=1))
    report = protocol.report_result(0, rep["search"], rep["wu"], 1.5, 2.0)
    srv.handle({**report, "cs": 2})
    assert srv.registry.hosts[0].returned == 1
    # the same result again under a NEW cs: the (host, cs) window has
    # moved on, the lease tables no longer know the wu — only the
    # settled-work memory can recognize it
    srv.handle({**report, "cs": 3, "now": 3.0})
    assert srv.counters.duplicate_reports == 1
    assert srv.counters.unknown_results == 0
    assert srv.registry.hosts[0].returned == 1  # counted at most once


def test_unknown_result_still_flagged(problem):
    """The lapse fix must not swallow real protocol misuse: a result for
    work this server never leased to anyone stays ``unknown_results``."""
    srv = _mini_server(problem)
    srv.handle(protocol.register(0, 0.0, cs=0))
    srv.handle(protocol.report_result(0, 0, 999999, 1.5, 1.0, cs=1))
    assert srv.counters.unknown_results == 1
    assert srv.counters.duplicate_reports == 0


# -- malformed-frame fuzz (satellite a) ----------------------------------------

def _recv_reply(sock):
    buf = b""
    while len(buf) < 4:
        chunk = sock.recv(4 - len(buf))
        if not chunk:
            return None                         # clean disconnect
        buf += chunk
    (n,) = struct.unpack(">I", buf)
    payload = b""
    while len(payload) < n:
        chunk = sock.recv(n - len(payload))
        if not chunk:
            return None
        payload += chunk
    return protocol.decode_message(payload)


def test_malformed_frame_fuzz_survival(problem):
    """Seeded garbage into the TCP server: every frame must yield either
    an ``error`` reply or a clean disconnect — never a hang, never a
    crash — and the server must keep serving well-formed traffic after."""
    srv = _mini_server(problem)
    transport = TcpTransport().start(srv.handle)
    rng = np.random.default_rng(0xF022)
    try:
        # well-framed garbage bodies: random codec byte + random bytes —
        # the handler must answer every one with an error reply
        sock = socket.create_connection((transport.host, transport.port),
                                        timeout=30.0)
        for _ in range(32):
            body = bytes(rng.integers(0, 256, int(rng.integers(1, 64)),
                                      dtype=np.uint8))
            sock.sendall(struct.pack(">I", len(body)) + body)
            rep = _recv_reply(sock)
            assert rep is not None and rep["kind"] == "error"
        # valid JSON codec, garbage JSON — still an error reply
        sock.sendall(struct.pack(">I", 9) + bytes([protocol.CODEC_JSON])
                     + b"not json")
        assert _recv_reply(sock)["kind"] == "error"
        # valid JSON, wrong protocol version — error reply
        body = bytes([protocol.CODEC_JSON]) + json.dumps(
            {"kind": "status", "v": 999}).encode()
        sock.sendall(struct.pack(">I", len(body)) + body)
        assert _recv_reply(sock)["kind"] == "error"
        sock.close()
        # an oversized length prefix is unframeable: the stream cannot be
        # resynced, so the contract is a clean disconnect
        sock = socket.create_connection((transport.host, transport.port),
                                        timeout=30.0)
        sock.sendall(struct.pack(">I", protocol.MAX_FRAME + 1) + b"junk")
        assert _recv_reply(sock) is None
        sock.close()
        # a truncated frame followed by close: server-side decoder just
        # discards the fragment
        sock = socket.create_connection((transport.host, transport.port),
                                        timeout=30.0)
        sock.sendall(struct.pack(">I", 1000) + b"\x01partial")
        sock.close()
        # after all of that, a well-formed request succeeds
        conn = TcpConnection(transport.host, transport.port)
        rep = conn.call(protocol.status())
        assert rep["kind"] == "status"
        rep = conn.call(protocol.register(7, 0.0, cs=0))
        assert rep["kind"] == "registered"
        conn.close()
    finally:
        transport.stop()


# -- sqlite eval-cache crash coverage (satellite c) ----------------------------

def test_sqlite_cache_ahead_of_log_restores_warm_and_identical(
        problem, backend, baseline, tmp_path):
    """Kill between a cache commit and the replay-log flush: the cache is
    AHEAD of the log.  Recovery must still be bit-identical (bit-exact
    serving is value-neutral) and warm (the survivor cache serves)."""
    eb = baseline.engines[0]
    spec, fleet, _ = problem
    db = str(tmp_path / "cache.sqlite")
    ckpt = str(tmp_path / "ckpt")
    kw = dict(ckpt_dir=ckpt, snapshot_every=150)
    with pytest.raises(SimulatedCrash):
        ServerSubstrate(
            spec, fleet, backend,
            cache=EvalCache(SqliteCacheStore(db, flush_every=1),
                            fingerprint="chaos_sqlite"),
            max_messages=baseline.pool.messages // 2, **kw).run()
    # simulate the log losing its unflushed suffix while the per-insert-
    # committed sqlite cache kept everything: chop the last replay lines
    log = os.path.join(ckpt, "replay.jsonl")
    with open(log) as f:
        lines = f.readlines()
    assert len(lines) > 8
    with open(log, "w") as f:
        f.writelines(lines[:-5])
    res = ServerSubstrate(
        spec, fleet, backend,
        cache=EvalCache(SqliteCacheStore(db), fingerprint="chaos_sqlite"),
        **kw).run(resume=True)
    assert res.cache["hits"] > 0                # warmed from the survivor
    assert identical_trajectories(eb, res.engines[0])
    assert eb.stats == res.engines[0].stats


# -- chaos over a cached run ---------------------------------------------------

def test_chaos_stats_shared_across_connections():
    stats = ChaosStats()
    assert stats.sent == 0
    p = PRESETS["degraded"]
    assert p.drop_request == 0.10 and p.duplicate == 0.05
