"""FGDO on a simulated volunteer grid — the paper's full system (§V–§VI).

A 256-host heterogeneous, faulty, partly-malicious grid fits the
8-parameter synthetic SDSS stream model asynchronously: work generated on
demand, phases advance on the first m results, the best line-search point
is quorum-validated before being committed.

    PYTHONPATH=src python examples/volunteer_grid.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import paper_anm
from repro.core.anm import AnmConfig
from repro.core.fgdo import FgdoAnmServer
from repro.core.grid import GridConfig, VolunteerGrid
from repro.data import sdss


def main():
    pc = paper_anm.smoke()
    stripe = sdss.make_stripe("stripe79", n_stars=6_000, seed=79)
    _, f_single = sdss.make_fitness(stripe)
    rng = np.random.default_rng(1)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.2, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    f0 = float(f_single(jnp.asarray(x0)))
    print(f"start fitness {f0:.5f}; truth "
          f"{float(f_single(jnp.asarray(stripe.truth))):.5f}")

    server = FgdoAnmServer(
        x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
        AnmConfig(m_regression=128, m_line_search=128, max_iterations=8),
        seed=3, validation_quorum=pc.validation_quorum)
    grid = VolunteerGrid(
        lambda p: float(f_single(jnp.asarray(p, jnp.float32))),
        GridConfig(n_hosts=256, base_eval_time=3600.0, speed_sigma=1.0,
                   failure_prob=0.1, malicious_prob=0.03, seed=5))
    gstats = grid.run(server)

    print(f"converged to {server.best_fitness:.5f} in {server.iteration} "
          f"iterations / {gstats.sim_time / 3600:.1f} simulated hours")
    print(f"grid: {gstats.completed} results ({gstats.failed} lost, "
          f"{gstats.corrupted} corrupted), {server.stats.stale} stale "
          f"discarded, {server.stats.validations_failed} malicious bests "
          f"rejected by quorum")
    for rec in server.history:
        print(f"  iter {rec.iteration}: best={rec.best_fitness:.5f} "
              f"alpha={rec.best_alpha:.2f}")


if __name__ == "__main__":
    main()
