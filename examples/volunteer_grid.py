"""FGDO on a simulated volunteer grid — the paper's full system (§V–§VI).

A 256-host heterogeneous, faulty, partly-malicious grid fits the
8-parameter synthetic SDSS stream model asynchronously: work generated on
demand, phases advance on the first m results, the best line-search point
is quorum-validated before being committed.

Both grid substrates drive the SAME AnmEngine state machine (DESIGN.md §1):
the per-event simulator through the BOINC-style FgdoAnmServer adapter, and
the vectorized batched grid directly — the second act of this script reruns
the problem at 4096 hosts with one jitted f_batch call per tick.

The batched acts take the PR-3 async path's knobs on the command line, so
the example exercises the pipelined tick loop and both evaluation backends
without edits:

    PYTHONPATH=src python examples/volunteer_grid.py
    PYTHONPATH=src python examples/volunteer_grid.py --no-pipelined
    PYTHONPATH=src python examples/volunteer_grid.py \
        --substrate pod_mesh --pipeline-depth 6
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import paper_anm
from repro.core.anm import AnmConfig
from repro.core.engine import AnmEngine, identical_trajectories
from repro.core.fgdo import FgdoAnmServer
from repro.core.grid import GridConfig, VolunteerGrid
from repro.core.substrates.batched_grid import BatchedVolunteerGrid
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.core.substrates.pod_mesh import PodMeshEvalBackend
from repro.data import sdss


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pipelined", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="pipelined tick loop (DESIGN.md §7) for the "
                         "batched acts; --no-pipelined collects every "
                         "bucket synchronously")
    ap.add_argument("--pipeline-depth", type=int, default=4,
                    help="max in-flight tick buckets when pipelined")
    ap.add_argument("--substrate", default="in_process",
                    choices=["in_process", "pod_mesh"],
                    help="evaluation backend for act 2 (act 3 runs the "
                         "OTHER backend for the parity comparison)")
    args = ap.parse_args()
    pc = paper_anm.smoke()
    stripe = sdss.make_stripe("stripe79", n_stars=6_000, seed=79)
    _, f_single = sdss.make_fitness(stripe)
    rng = np.random.default_rng(1)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.2, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    f0 = float(f_single(jnp.asarray(x0)))
    print(f"start fitness {f0:.5f}; truth "
          f"{float(f_single(jnp.asarray(stripe.truth))):.5f}")

    server = FgdoAnmServer(
        x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
        AnmConfig(m_regression=128, m_line_search=128, max_iterations=8),
        seed=3, validation_quorum=pc.validation_quorum)
    grid = VolunteerGrid(
        lambda p: float(f_single(jnp.asarray(p, jnp.float32))),
        GridConfig(n_hosts=256, base_eval_time=3600.0, speed_sigma=1.0,
                   failure_prob=0.1, malicious_prob=0.03, seed=5))
    gstats = grid.run(server)

    print(f"converged to {server.best_fitness:.5f} in {server.iteration} "
          f"iterations / {gstats.sim_time / 3600:.1f} simulated hours")
    print(f"grid: {gstats.completed} results ({gstats.failed} lost, "
          f"{gstats.corrupted} corrupted), {server.stats.stale} stale "
          f"discarded, {server.stats.validations_failed} malicious bests "
          f"rejected by quorum")
    for rec in server.history:
        print(f"  iter {rec.iteration}: best={rec.best_fitness:.5f} "
              f"alpha={rec.best_alpha:.2f}")

    # -- act 2: the same engine on the vectorized 4096-host substrate --------
    f_batch, _ = sdss.make_fitness(stripe)
    backends = {"in_process": lambda: InProcessEvalBackend(f_batch),
                "pod_mesh": lambda: PodMeshEvalBackend(f_batch)}
    backend2 = backends[args.substrate]()
    engine = AnmEngine(x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
                       AnmConfig(m_regression=128, m_line_search=128,
                                 max_iterations=8),
                       seed=3, validation_quorum=pc.validation_quorum)
    t0 = time.perf_counter()
    bstats = BatchedVolunteerGrid(
        None, GridConfig(n_hosts=4096, base_eval_time=3600.0,
                         speed_sigma=1.0, failure_prob=0.1,
                         malicious_prob=0.03, seed=5),
        backend=backend2, pipelined=args.pipelined,
        pipeline_depth=args.pipeline_depth).run(engine)
    wall = time.perf_counter() - t0
    print(f"batched grid (4096 hosts, {args.substrate} backend, "
          f"{'pipelined' if args.pipelined else 'sync'}): "
          f"{engine.best_fitness:.5f} in "
          f"{engine.iteration} iterations / {bstats.sim_time / 3600:.1f} "
          f"simulated hours — {bstats.batch_calls} fitness batches "
          f"(mean {bstats.batched_evals / max(bstats.batch_calls, 1):.0f} "
          f"points each), {wall:.1f}s wall")
    print(f"  ticks (DESIGN.md §7): device-blocked "
          f"{bstats.device_blocked_s:.2f}s vs host {bstats.host_s:.2f}s, "
          f"pipeline depth {bstats.max_in_flight}, "
          f"{bstats.spec_blocks} speculative blocks "
          f"({bstats.spec_discarded} discarded)")

    # -- act 3: the same grid through the OTHER backend ----------------------
    # (DESIGN.md §6 — on this CPU the pod mesh degenerates to the available
    # devices; run under repro.launch.dryrun --substrate pod_mesh for the
    # real 16x16 partitioning.  Same seed => bit-identical iterates, on
    # either backend, pipelined or not.)
    other = "pod_mesh" if args.substrate == "in_process" else "in_process"
    backend3 = backends[other]()
    engine2 = AnmEngine(x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
                        AnmConfig(m_regression=128, m_line_search=128,
                                  max_iterations=8),
                        seed=3, validation_quorum=pc.validation_quorum)
    BatchedVolunteerGrid(
        None, GridConfig(n_hosts=4096, base_eval_time=3600.0,
                         speed_sigma=1.0, failure_prob=0.1,
                         malicious_prob=0.03, seed=5),
        backend=backend3, pipelined=args.pipelined,
        pipeline_depth=args.pipeline_depth).run(engine2)
    identical = identical_trajectories(engine, engine2)
    print(f"{other} backend: {engine2.best_fitness:.5f} — iterates "
          f"{'bit-identical to' if identical else 'DIVERGED from'} "
          f"the {args.substrate} backend")


if __name__ == "__main__":
    main()
