"""The fault-tolerant FGDO service layer, end to end (DESIGN.md §9, §12).

Four acts over one seeded 8-parameter SDSS-stream search:

  1. serve it: a loopback work server (real framed protocol messages,
     host registry, deadline leases) drives a simulated 128-host volunteer
     fleet to completion, then reports the registry's view of the fleet;
  2. crash it: the same search with checkpointing AND the persistent
     eval cache on, killed mid-run (simulated crash after N messages),
     restored from snapshot + replay log + the surviving cache store,
     and run to completion — the restored run must commit bit-identical
     iterates and identical engine stats, and comes back WARM: the
     re-leased in-flight points it already paid for are served from the
     cache instead of re-evaluated (DESIGN.md §10);
  3. go over TCP: the identical search through real sockets on
     127.0.0.1, which must match the loopback trajectory exactly;
  4. break the network: 8 truly concurrent TCP client threads behind
     the sequenced intake, with a seeded ``FaultPlan`` dropping,
     duplicating, delaying, resetting, and tearing frames mid-write —
     retries + (host_id, client_seq) idempotency absorb every fault
     and the trajectory STILL matches act 1 bit-for-bit (DESIGN.md
     §12).

    PYTHONPATH=src python examples/fgdo_service.py
    PYTHONPATH=src python examples/fgdo_service.py --act 4
"""
import argparse
import tempfile
import time

from repro.core.engine import identical_trajectories
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.core.substrates.eval_cache import EvalCache, JsonlCacheStore
from repro.server import protocol
from repro.server.chaos import FaultPlan
from repro.server.checkpoint import eval_cache_path
from repro.server.sim import ServerSubstrate, SimulatedCrash, smoke_problem
from repro.server.transport import LoopbackTransport


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--act", type=int, default=0, choices=[0, 1, 2, 3, 4],
                    help="run one act (0 = all)")
    args = ap.parse_args()

    # 10% malicious hosts so the robustness story is visible (the smoke
    # default of 2% happens to draw zero liars at this fleet seed)
    spec, fleet, f_batch = smoke_problem(n_stars=400, n_hosts=128, m=24,
                                         iterations=3, malicious=0.1)
    backend = InProcessEvalBackend(f_batch)

    print("== act 1: a volunteer fleet served over the wire protocol ==")
    t0 = time.time()
    base = ServerSubstrate(spec, fleet, backend).run()
    eng = base.engines[0]
    print(f"  {eng.iteration} iterations, best {eng.best_fitness:.6f} "
          f"in {time.time() - t0:.1f}s wall")
    p = base.pool
    print(f"  {p.messages} messages: {p.work_received} leases, "
          f"{p.results_reported} results ({p.failed} lost to vanishing "
          f"hosts, {p.corrupted} corrupted), {p.no_work} no-work backoffs")
    print(f"  {p.evals} fitness evals in {p.eval_batches} lazy batches; "
          f"{eng.stats.candidates_rejected} lying candidates rejected by "
          f"quorum")
    reg = base.server.registry.summary()
    print(f"  registry: {reg['hosts']} hosts {reg['states']}, "
          f"{reg['returned']}/{reg['issued']} returned "
          f"({reg['stale_returns']} stale), "
          f"{reg['excluded_by_return_rate']} gated as black holes")
    c = base.server.counters
    print(f"  leases: {c.leases_issued} issued, {c.leases_lapsed} lapsed, "
          f"{c.leases_abandoned} abandoned, {c.late_returns} late returns")

    if args.act in (0, 2):
        print("== act 2: kill the server mid-search, restore WARM, "
              "compare ==")
        ckpt = tempfile.mkdtemp(prefix="fgdo_service_")
        crash_at = p.messages // 3
        fp = "fgdo_service"
        try:
            ServerSubstrate(
                spec, fleet, backend, ckpt_dir=ckpt, snapshot_every=200,
                max_messages=crash_at,
                cache=EvalCache(JsonlCacheStore(eval_cache_path(ckpt)),
                                fingerprint=fp)).run()
            raise RuntimeError("expected the simulated crash")
        except SimulatedCrash:
            print(f"  server 'crashed' after {crash_at} messages "
                  f"(snapshot + replay log + cache store on disk)")
        # a fresh cache instance, warmed purely from the surviving store
        cache = EvalCache(JsonlCacheStore(eval_cache_path(ckpt)),
                          fingerprint=fp)
        res = ServerSubstrate(spec, fleet, backend, ckpt_dir=ckpt,
                              snapshot_every=200,
                              cache=cache).run(resume=True)
        same = (identical_trajectories(eng, res.engines[0])
                and eng.stats == res.engines[0].stats)
        print(f"  restored: replayed {res.replayed} logged messages, "
              f"re-leased {res.pool.resumed_leases} in-flight workunits")
        cc = res.cache
        print(f"  eval cache: {cc['hits']} hits / {cc['misses']} misses "
              f"(hit rate {cc['hit_rate']:.2f}), {cc['lanes_saved']} "
              f"evaluations never re-run, store {cc['store_size']} entries")
        print(f"  restored run bit-identical to uninterrupted: {same}")
        assert same, "kill/restore contract violated"
        assert cc["hits"] > 0, "restored server should have come back warm"

    if args.act in (0, 3):
        print("== act 3: the same search over TCP sockets ==")
        t0 = time.time()
        tcp = ServerSubstrate(spec, fleet, backend, transport="tcp").run()
        same = (identical_trajectories(eng, tcp.engines[0])
                and eng.stats == tcp.engines[0].stats)
        print(f"  {tcp.pool.messages} frames over 127.0.0.1 in "
              f"{time.time() - t0:.1f}s; bit-identical to loopback: {same}")
        assert same, "TCP trajectory diverged from loopback"

    if args.act in (0, 4):
        print("== act 4: 8 concurrent clients through a hostile network ==")
        # a composite schedule: every fault category the transport can
        # inject, all at once, on a recorded seed
        plan = FaultPlan(seed=4242, drop_request=0.06, drop_reply=0.04,
                         duplicate=0.08, delay=0.15, delay_ms=1.5,
                         torn_write=0.03, reset=0.03)
        t0 = time.time()
        res = ServerSubstrate(spec, fleet, backend, transport="tcp",
                              concurrent=8, chaos=plan).run()
        same = (identical_trajectories(eng, res.engines[0])
                and eng.stats == res.engines[0].stats)
        ch, ik = res.chaos, res.intake
        print(f"  faults injected: {ch['drops_request']}+"
              f"{ch['drops_reply']} drops, {ch['duplicates']} dups, "
              f"{ch['delays']} delays, {ch['resets']} resets, "
              f"{ch['torn_writes']} torn writes -> {ch['retries']} "
              f"retries in {time.time() - t0:.1f}s")
        print(f"  intake: {ik['next_seq']} stamps admitted in canonical "
              f"order, {ik['parked']} early arrivals parked, "
              f"{ik['out_of_band']} late duplicates absorbed")
        c = res.server.counters
        print(f"  idempotency: {c.duplicates_suppressed} replies served "
              f"from cache, {c.stale_duplicates} stale dups refused, "
              f"{c.duplicate_reports} lapsed-lease re-reports ignored")
        print(f"  trajectory bit-identical to the clean serial run: {same}")
        assert same, "chaos run diverged from the fault-free baseline"

    # a peek through the protocol's monitoring message, for flavor
    srv = base.server
    status = LoopbackTransport().start(srv.handle).connect().call(
        protocol.status())
    s = status["searches"][0]
    print(f"status frame: search {s['name']!r} {s['status']} at iteration "
          f"{s['iteration']}, best {s['best']:.6f}")


if __name__ == "__main__":
    main()
