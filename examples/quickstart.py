"""Quickstart: the asynchronous Newton method in ~30 lines.

Fits a 2-D Rosenbrock-like bowl with the paper's three ingredients:
box-sampled regression (gradient+Hessian in ONE parallel batch), the
damped Newton direction, and the randomized line search.  ``anm_minimize``
is a thin synchronous driver over the same AnmEngine state machine that the
asynchronous volunteer-grid substrates use (see examples/volunteer_grid.py
and DESIGN.md §1) — including quorum validation of every committed point.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anm import AnmConfig, anm_minimize


def rosenbrock_batch(xs):                    # (m, 2) -> (m,)
    x, y = xs[:, 0], xs[:, 1]
    return (1 - x) ** 2 + 5.0 * (y - x * x) ** 2


def main():
    state = anm_minimize(
        jax.jit(rosenbrock_batch),
        x0=np.array([-1.2, 1.0]),
        lo=np.array([-3.0, -3.0]), hi=np.array([3.0, 3.0]),
        step=np.array([0.25, 0.25]),
        cfg=AnmConfig(m_regression=64, m_line_search=64, max_iterations=25,
                      alpha_max=2.0),
        key=jax.random.key(0))
    print(f"optimum found at {np.round(np.asarray(state.center), 4)} "
          f"(truth: [1, 1]), fitness {state.best_fitness:.2e}")
    for rec in state.history[:6]:
        print(f"  iter {rec.iteration}: best={rec.best_fitness:.5f} "
              f"avg_line={rec.avg_line_fitness:.5f}")
    assert state.best_fitness < 1e-3


if __name__ == "__main__":
    main()
