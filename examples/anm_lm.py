"""The model stack as the fitness function (DESIGN.md §11).

Every evaluation here is a REAL forward + cross-entropy of a ``models/``
network on a fixed synthetic batch, its parameters perturbed along a
k-dimensional orthonormal subspace basis (``core/subspace.py`` — the same
chart the in-process subspace-Newton optimizer uses).  The asynchronous
Newton engine searches the coefficient box; the volunteer fleet, the
orchestrator and the work server never know the objective changed from an
8-parameter quadratic to a language model.

Three acts:
  1. solo: one ANM search over the rwkv6 smoke config's loss landscape
     through the pipelined batched grid — zero compiles once warmed;
  2. portfolio: a coalesced multi-start portfolio PER smoke config
     (rwkv6 and the dense h2o-danube), every orchestrated search
     bit-identical to its solo run, best arch reported;
  3. crash: the same workload through the checkpointed work server,
     killed mid-search (simulated crash after N messages) and restored
     from snapshot + replay log — bit-identical to uninterrupted.

    PYTHONPATH=src python examples/anm_lm.py
    PYTHONPATH=src python examples/anm_lm.py --act 2 --arch h2o-danube-3-4b
"""
import argparse
import tempfile
import time

from repro.core.engine import identical_trajectories
from repro.core.orchestrator import (FleetScheduler, SearchDirector,
                                     multi_start_specs)
from repro.core.substrates.batched_grid import BatchedVolunteerGrid
from repro.core.substrates.eval_backend import bucket_size
from repro.core.substrates.lm_loss import LmLossEvalBackend
from repro.server.sim import (ServerSubstrate, SimulatedCrash, lm_problem,
                              result_doc)

PORTFOLIO_ARCHS = ("rwkv6-7b", "h2o-danube-3-4b")


def act1_solo(args):
    print(f"== act 1: ANM over the {args.arch} loss landscape ==")
    spec, fleet, wl = lm_problem(arch=args.arch, k=args.k, m=args.m,
                                 iterations=args.iterations,
                                 n_hosts=args.hosts)
    t0 = time.time()
    max_bucket = bucket_size(BatchedVolunteerGrid.warm_max_bucket(args.m))
    backend = LmLossEvalBackend(wl, n_dims=args.k, max_bucket=max_bucket)
    print(f"  workload: {wl.proj.n_params} params, k={wl.k} subspace, "
          f"warmed ladder in {time.time() - t0:.1f}s "
          f"({backend.compile_count} compiles)")
    c0 = backend.compile_count
    t0 = time.time()
    engine = spec.build_engine()
    stats = BatchedVolunteerGrid(None, fleet, backend=backend,
                                 pipelined=True).run(engine)
    loss0 = engine.history[0].best_fitness
    print(f"  {engine.iteration} iterations, loss "
          f"{loss0:.6f} -> {engine.best_fitness:.6f} in "
          f"{time.time() - t0:.1f}s wall ({stats.batch_calls} buckets, "
          f"{backend.compile_count - c0} compiles mid-run)")
    return backend, spec, fleet, wl


def act2_portfolio(args):
    print("== act 2: a coalesced portfolio per smoke config ==")
    best = {}
    for arch in PORTFOLIO_ARCHS:
        spec, fleet, wl = lm_problem(arch=arch, k=args.k, m=args.m,
                                     iterations=args.iterations,
                                     n_hosts=args.hosts)
        backend = LmLossEvalBackend(wl)
        sched = FleetScheduler(backend, fleet)
        specs = multi_start_specs(sched, spec.x0, spec.lo, spec.hi,
                                  spec.step, spec.anm, args.searches,
                                  seed=7, jitter=0.3)
        t0 = time.time()
        res = SearchDirector(sched, specs).run()
        wall = time.time() - t0
        parity = all(identical_trajectories(o.engine,
                                            o.spec.solo_run(backend))
                     for o in res.outcomes)
        co = res.coalesce_stats
        print(f"  {arch}: {args.searches} searches, "
              f"{co.dispatches} dispatches for {co.lane_blocks} blocks, "
              f"best {res.best.engine.best_fitness:.6f} in {wall:.1f}s; "
              f"solo parity {'ok' if parity else 'FAIL'}")
        best[arch] = res.best.engine.best_fitness
    winner = min(best, key=best.get)
    print(f"  best landscape: {winner} at {best[winner]:.6f}")


def act3_crash(args):
    print("== act 3: kill the work server mid-search, restore ==")
    spec, fleet, wl = lm_problem(arch=args.arch, k=args.k, m=args.m,
                                 iterations=args.iterations,
                                 n_hosts=args.hosts)
    backend = LmLossEvalBackend(wl)
    base = result_doc(ServerSubstrate(spec, fleet, backend).run())
    print(f"  uninterrupted: {base['iteration']} iterations, best "
          f"{base['best_fitness']:.6f}, {base['pool']['messages']} "
          f"protocol messages")
    kill_after = max(50, int(0.4 * base["pool"]["messages"]))
    with tempfile.TemporaryDirectory(prefix="anm_lm_") as ckpt:
        try:
            ServerSubstrate(spec, fleet, backend, ckpt_dir=ckpt,
                            snapshot_every=25,
                            max_messages=kill_after).run()
            print("  FAIL: finished before the crash point")
            return
        except SimulatedCrash as e:
            print(f"  {e}")
        res = result_doc(ServerSubstrate(spec, fleet, backend,
                                         ckpt_dir=ckpt).run(resume=True))
    match = (res["history"] == base["history"]
             and res["engine_stats"] == base["engine_stats"])
    print(f"  restored: replayed {res['replayed']} log records, re-leased "
          f"{res['pool']['resumed_leases']} in-flight workunits, "
          f"finished at {res['best_fitness']:.6f}")
    print(f"  bit-identical to uninterrupted: {'ok' if match else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--act", type=int, default=0, choices=[0, 1, 2, 3],
                    help="run one act (0 = all)")
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--k", type=int, default=6)
    ap.add_argument("--m", type=int, default=12)
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--hosts", type=int, default=48)
    ap.add_argument("--searches", type=int, default=2)
    args = ap.parse_args()

    if args.act in (0, 1):
        act1_solo(args)
    if args.act in (0, 2):
        act2_portfolio(args)
    if args.act in (0, 3):
        act3_crash(args)


if __name__ == "__main__":
    main()
