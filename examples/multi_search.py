"""Multi-search orchestration over one shared fleet (DESIGN.md §8).

The paper's ANM is a local optimizer that FGDO runs as one of MANY
concurrent searches over a single volunteer grid.  This driver does that
for the synthetic SDSS stream problem: a heterogeneous portfolio of ANM
searches (perturbed starts, two per-phase m's) shares one fleet and one
warmed evaluation backend, with every search's tick blocks coalesced into
shared search-id-tagged buckets — one device dispatch per scheduling
round, however many searches are live.

Three acts:
  1. a fixed 6-search portfolio, coalesced — then each search re-run ALONE
     to show the bit-identical parity contract and the wall-clock win;
  2. the best-of-portfolio policy killing dominated searches early;
  3. the restart policy recycling freed capacity into perturbed restarts
     of the incumbent.

    PYTHONPATH=src python examples/multi_search.py
    PYTHONPATH=src python examples/multi_search.py --searches 8 --policy restart
"""
import argparse
import time

import numpy as np

from repro.core.anm import AnmConfig
from repro.core.engine import identical_trajectories
from repro.core.grid import GridConfig
from repro.core.orchestrator import (FleetScheduler, SearchDirector,
                                     multi_start_specs)
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.core.substrates.eval_cache import EvalCache
from repro.data import sdss


def outcome_table(res):
    for o in res.outcomes:
        print(f"  {o.spec.name:>12}  {o.status:>6}  "
              f"iter {o.engine.iteration:>2}  "
              f"best {o.engine.best_fitness:.5f}  "
              f"(m={o.spec.anm.m_regression}, "
              f"{o.spec.grid.n_hosts} hosts)")
    best = res.best
    print(f"  incumbent: {best.spec.name} at {best.engine.best_fitness:.5f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--searches", type=int, default=6)
    ap.add_argument("--hosts", type=int, default=768,
                    help="TOTAL shared fleet, partitioned across searches")
    ap.add_argument("--m", type=int, default=96)
    ap.add_argument("--iterations", type=int, default=4)
    ap.add_argument("--policy", default="all",
                    choices=["all", "fixed", "portfolio", "restart"])
    args = ap.parse_args()

    # a LIGHT stripe on purpose: coalescing amortizes dispatch round-trips
    # and bucket padding, so its win lives in the latency-bound regime
    # (many small ticks, cheap per-row fitness) — with a heavyweight
    # fitness the device compute dominates either way
    stripe = sdss.make_stripe("stripe79", n_stars=500, n_quad=512, seed=79)
    f_batch, f_single = sdss.make_fitness(stripe)
    rng = np.random.default_rng(1)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.25, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    fleet = GridConfig(n_hosts=args.hosts, base_eval_time=3600.0,
                       failure_prob=0.1, malicious_prob=0.03, seed=5)
    backend = InProcessEvalBackend(f_batch)
    hetero = [AnmConfig(m_regression=args.m, m_line_search=args.m,
                        max_iterations=args.iterations),
              AnmConfig(m_regression=args.m // 2, m_line_search=args.m // 2,
                        max_iterations=args.iterations)]

    def fresh(policy="fixed", **kw):
        sched = FleetScheduler(backend, fleet)
        specs = multi_start_specs(sched, x0, sdss.LO, sdss.HI,
                                  sdss.DEFAULT_STEP, hetero[0],
                                  args.searches, seed=11, jitter=0.35,
                                  configs=hetero)
        return sched, specs, SearchDirector(sched, specs, policy, **kw)

    # -- act 1: fixed portfolio, coalesced vs the same searches alone --------
    if args.policy in ("all", "fixed"):
        sched, specs, director = fresh()
        # compile everything BEFORE the timed windows — the bucket ladder
        # plus (via a throwaway 1-iteration mini-portfolio) the engine's
        # phase-finish jits at both heterogeneous m's; otherwise the first
        # run absorbs every trace and the wall-clock comparison below
        # measures XLA, not the orchestrator
        sched.warm(len(x0), specs)
        warm_sched, warm_specs, _ = fresh()
        import dataclasses
        warm_specs = [dataclasses.replace(
            s, anm=dataclasses.replace(s.anm, max_iterations=1))
            for s in warm_specs]
        SearchDirector(warm_sched, warm_specs).run()
        t0 = time.perf_counter()
        res = director.run()
        wall_co = time.perf_counter() - t0
        co = res.coalesce_stats
        print(f"coalesced {args.searches}-search portfolio: "
              f"{wall_co:.2f}s wall, {res.rounds} rounds, "
              f"{co.dispatches} device dispatches for {co.lane_blocks} "
              f"per-search blocks "
              f"({co.lane_blocks / max(co.dispatches, 1):.1f}x amortized), "
              f"padded lanes {co.padded_lanes} vs {co.solo_padded_lanes} solo")
        outcome_table(res)
        t0 = time.perf_counter()
        parity = True
        for o in res.outcomes:
            solo = o.spec.solo_run(backend)
            parity &= identical_trajectories(o.engine, solo)
        wall_ser = time.perf_counter() - t0
        print(f"serial re-runs: {wall_ser:.2f}s wall "
              f"({wall_ser / max(wall_co, 1e-9):.2f}x the coalesced run) — "
              f"trajectories "
              f"{'bit-identical' if parity else 'DIVERGED (BUG)'}")

        # -- the persistent eval cache (DESIGN.md §10): replay it warm ---
        cache = EvalCache(fingerprint="multi_search_example")
        SearchDirector(FleetScheduler(backend, fleet, cache=cache),
                       specs).run()                 # cold run populates
        t0 = time.perf_counter()
        res_warm = SearchDirector(
            FleetScheduler(backend, fleet, cache=cache), specs).run()
        wall_warm = time.perf_counter() - t0
        same = all(identical_trajectories(a.engine, b.engine)
                   and a.engine.stats == b.engine.stats
                   for a, b in zip(res.outcomes, res_warm.outcomes))
        cc = cache.status()
        print(f"warm cache replay: {wall_warm:.2f}s wall "
              f"({wall_co / max(wall_warm, 1e-9):.1f}x the cold coalesced "
              f"run), {cc['hits']} hits / {cc['misses']} misses "
              f"(hit rate {cc['hit_rate']:.2f}), "
              f"{res_warm.coalesce_stats.lanes_deduped} lanes deduped, "
              f"store {cc['store_size']} entries; "
              f"bit-identical: {same}\n")

    # -- act 2: best-of-portfolio with early kill ----------------------------
    if args.policy in ("all", "portfolio"):
        _, _, director = fresh("portfolio", kill_margin=0.02,
                               probation_iterations=2)
        res = director.run()
        killed = [o for o in res.outcomes if o.status == "killed"]
        print(f"portfolio policy: {len(killed)} dominated searches killed "
              f"early (capacity freed after probation)")
        outcome_table(res)
        print()

    # -- act 3: restarts from perturbed incumbents ---------------------------
    if args.policy in ("all", "restart"):
        _, _, director = fresh("restart", max_restarts=args.searches // 2,
                               restart_sigma=0.3, seed=17)
        res = director.run()
        restarts = [o for o in res.outcomes if "~r" in o.spec.name]
        print(f"restart policy: {len(restarts)} fresh searches started "
              f"from perturbed incumbents on freed capacity")
        outcome_table(res)
        truth = float(f_single(np.asarray(stripe.truth, np.float32)))
        print(f"  (fitness at the generating truth: {truth:.5f})")


if __name__ == "__main__":
    main()
