"""Serve a small model with batched requests + continuous batching.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-7b]
"""
import argparse
import sys

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    args = ap.parse_args()
    return serve.main(["--arch", args.arch, "--batch", "4", "--requests", "8",
                       "--prompt-len", "8", "--gen-len", "16"])


if __name__ == "__main__":
    sys.exit(main())
