"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the full substrate — synthetic pipeline, AdamW, checkpointing — plus
the paper's randomized parallel line search as a training feature.

    PYTHONPATH=src python examples/train_lm.py              # full (slow on CPU)
    PYTHONPATH=src python examples/train_lm.py --fast       # reduced demo
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="tiny model / fewer steps (CI-speed demo)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.fast:
        argv = ["--preset", "tiny", "--steps", str(args.steps or 60),
                "--batch", "4", "--seq", "64", "--line-search", "4",
                "--ckpt-dir", "/tmp/repro_train_lm_fast", "--ckpt-every", "20"]
    else:
        argv = ["--preset", "lm-100m", "--steps", str(args.steps or 200),
                "--batch", "4", "--seq", "256", "--lr", "1e-3",
                "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50"]
    return train.main(argv)


if __name__ == "__main__":
    sys.exit(main())
