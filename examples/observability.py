"""The live observability plane, end to end (DESIGN.md §13).

Three acts over one seeded SDSS-stream search served to a simulated
volunteer fleet:

  1. watch without touching: the same search run unobserved and then with
     the metrics hub + a live ``subscribe_stats`` subscriber attached —
     the committed iterates must be bit-identical (monitoring is
     stamp-free, unlogged, and mutation-free by construction);
  2. break the fleet: a quarter of the hosts go silent mid-run; the
     anomaly detector sees the alive→suspect cohort flip in the stats
     stream and quarantines it out of the registry's reliable set —
     exactly once per transition, with the verdict schedule recorded;
  3. replay the defense: a fresh run applies the RECORDED schedule
     (detectors off) and must reproduce act 2's trajectory bit-for-bit —
     the §13 determinism story: anomaly verdicts are data, not races.

    PYTHONPATH=src python examples/observability.py

For a live terminal view of the same stream, run
``python -m repro.launch.obs_dashboard --demo``.
"""
import time

from repro.core.engine import identical_trajectories
from repro.core.substrates.eval_backend import InProcessEvalBackend
from repro.server.sim import ServerSubstrate, smoke_problem


def same(a, b):
    ea, eb = a.engines[0], b.engines[0]
    return identical_trajectories(ea, eb) and ea.stats == eb.stats


def main():
    spec, fleet, f_batch = smoke_problem(n_stars=200, n_hosts=96, m=16,
                                         iterations=3)
    backend = InProcessEvalBackend(f_batch)

    print("== act 1: observe without perturbing ==")
    t0 = time.time()
    base = ServerSubstrate(spec, fleet, backend).run()
    observed = ServerSubstrate(spec, fleet, backend, obs=True,
                               subscribe=True, stats_interval=10.0).run()
    sub = observed.subscriber
    print(f"  unobserved + observed runs in {time.time() - t0:.1f}s wall")
    print(f"  {observed.obs['snapshots']} snapshots sampled at virtual-"
          f"time boundaries; live subscriber received {sub['snapshots']} "
          f"(seqs {sub['first_seq']}..{sub['last_seq']}, "
          f"stamped_ok={sub['stamped_ok']})")
    assert same(base, observed), "observation perturbed the trajectory"
    assert sub["snapshots"] >= 2 and sub["stamped_ok"]
    print("  bit-identical to the unobserved run: True")

    print("== act 2: a quarter of the fleet goes dark; the defense "
          "pages it out ==")
    silence = dict(silence_at=150.0, silence_frac=0.25)
    dark = ServerSubstrate(spec, fleet, backend, **silence).run()
    defended = ServerSubstrate(spec, fleet, backend, defense=True,
                               stats_interval=10.0, **silence).run()
    d = defended.defense
    print(f"  anomalies: {d['events']} events {d['by_action']}, "
          f"{d['quarantined_now']} hosts quarantined now")
    print(f"  reliable set: {dark.server.registry.summary()['reliable_set']}"
          f" undefended -> "
          f"{defended.server.registry.summary()['reliable_set']} defended")
    assert d["quarantined_now"] > 0, "silenced cohort was never paged"

    print("== act 3: replay the recorded verdict schedule ==")
    replayed = ServerSubstrate(spec, fleet, backend,
                               defense_schedule=d["schedule"],
                               stats_interval=10.0, **silence).run()
    print(f"  replay applied {replayed.defense['events']} recorded events "
          f"with detectors off")
    ok = same(defended, replayed)
    print(f"  replayed trajectory bit-identical to the live defense: {ok}")
    assert ok, "defense replay diverged — §13 determinism violated"


if __name__ == "__main__":
    main()
