"""Checkpoint/restart substrate: npz bundles + manifest, atomic writes,
retention, optional async save, and resharding on restore (elastic scaling).

Layout:
    <dir>/step_000123/arrays.npz      # one entry per pytree leaf (path-keyed)
    <dir>/step_000123/MANIFEST.json   # step, leaf paths/dtypes/shapes, extras
    <dir>/LATEST                      # atomic pointer file

Restoring onto a different mesh is supported by passing target shardings:
leaves are device_put with the new NamedSharding — this is how a 256-chip
checkpoint restarts on 512 chips (elastic scale-up) and vice versa.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

# npz cannot serialize ml_dtypes (bfloat16, fp8) — store bitwise views
_RAW_VIEW = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}
_RAW_BACK = {"bfloat16": ml_dtypes.bfloat16,
             "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
             "float8_e5m2": ml_dtypes.float8_e5m2}


def _encode(a: np.ndarray) -> np.ndarray:
    view = _RAW_VIEW.get(a.dtype.name)
    return a.view(view) if view is not None else a


def _decode(arr: np.ndarray, want_dtype) -> np.ndarray:
    name = np.dtype(want_dtype).name if not hasattr(want_dtype, "name") else want_dtype.name
    if name in _RAW_BACK and arr.dtype == _RAW_VIEW[name]:
        return arr.view(_RAW_BACK[name])       # bitwise-exact restore
    if str(arr.dtype) != str(want_dtype):
        return arr.astype(want_dtype)
    return arr


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, extras: Optional[Dict] = None,
         keep: int = 3, async_save: bool = False):
    """Write a checkpoint bundle. Atomic via tmp-dir + rename."""
    flat = _flatten_with_paths(tree)
    host = {k: _encode(np.asarray(v)) for k, v in flat.items()}

    def _write():
        name = f"step_{step:08d}"
        tmp = os.path.join(ckpt_dir, f".tmp_{name}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "extras": extras or {},
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        final = os.path.join(ckpt_dir, name)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
        _retain(ckpt_dir, keep)

    os.makedirs(ckpt_dir, exist_ok=True)
    if async_save:
        th = threading.Thread(target=_write, daemon=True)
        th.start()
        return th
    _write()
    return None


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding — leaves are placed onto it (resharding / elastic restore).
    Returns (tree, step, extras)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))

    flat_template = _flatten_with_paths(template)
    flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}
    missing = set(flat_template) - set(arrays.files)
    extra = set(arrays.files) - set(flat_template)
    if missing or extra:
        raise ValueError(f"checkpoint/template mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")

    leaves, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for path_leaf, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_leaf)
        val = _decode(arrays[key], leaf.dtype)
        if key in flat_shard and flat_shard[key] is not None:
            val = jax.device_put(val, flat_shard[key])
        else:
            val = jax.numpy.asarray(val)
        out.append(val)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest["step"], manifest.get("extras", {})
