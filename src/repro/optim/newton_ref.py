"""Numerical-Hessian Newton baseline (paper §II, eq. 1–3).

The 4n²−n finite-difference evaluations per iteration are the cost ANM's
regression replaces; this reference exists to validate the ANM direction
against the classical one and to quantify the evaluation-count gap.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import numpy as np

from repro.core import regression as reg
import jax.numpy as jnp


@dataclasses.dataclass
class NewtonResult:
    x: np.ndarray
    fitness: float
    iterations: int
    evals: int
    history: List[float]


def numerical_gradient(f, x, s, count):
    n = len(x)
    g = np.zeros(n)
    for i in range(n):
        e = np.zeros(n); e[i] = s[i]
        g[i] = (f(x + e) - f(x - e)) / (2 * s[i])
        count[0] += 2
    return g


def numerical_hessian(f, x, s, count):
    """Paper eq. (2): H_ij = [f(+i+j) - f(+i-j) - f(-i+j) + f(-i-j)] / 4 s_i s_j."""
    n = len(x)
    H = np.zeros((n, n))
    fx = f(x); count[0] += 1
    for i in range(n):
        ei = np.zeros(n); ei[i] = s[i]
        fpi = f(x + ei); fmi = f(x - ei)
        count[0] += 2
        H[i, i] = (fpi - 2 * fx + fmi) / (s[i] ** 2)
        for j in range(i + 1, n):
            ej = np.zeros(n); ej[j] = s[j]
            H[i, j] = (f(x + ei + ej) - f(x + ei - ej)
                       - f(x - ei + ej) + f(x - ei - ej)) / (4 * s[i] * s[j])
            H[j, i] = H[i, j]
            count[0] += 4
    return H


def newton_minimize(f: Callable[[np.ndarray], float], x0, lo, hi, step,
                    max_iterations: int = 50, m_line: int = 64,
                    alpha_max: float = 2.0, damping: float = 1e-6,
                    seed: int = 0, tol: float = 1e-10) -> NewtonResult:
    rng = np.random.default_rng(seed)
    x = np.asarray(x0, np.float64).copy()
    lo = np.asarray(lo, np.float64); hi = np.asarray(hi, np.float64)
    s = np.asarray(step, np.float64).copy()
    count = [0]
    fx = f(x); count[0] += 1
    history = [fx]
    for it in range(max_iterations):
        g = numerical_gradient(f, x, s, count)
        H = numerical_hessian(f, x, s, count)
        d = np.asarray(reg.newton_direction(jnp.asarray(g, jnp.float32),
                                            jnp.asarray(H, jnp.float32), damping),
                       np.float64)
        alphas = rng.uniform(0.0, alpha_max, m_line)
        best_f, best_x = fx, x
        for a in alphas:
            xn = np.clip(x + a * d, lo, hi)
            fn = f(xn); count[0] += 1
            if fn < best_f:
                best_f, best_x = fn, xn
        if best_f < fx - tol:
            x, fx = best_x, best_f
        else:
            s *= 0.5
        history.append(fx)
        if np.max(s) < 1e-12:
            break
    return NewtonResult(x=x, fitness=float(fx), iterations=it + 1,
                        evals=count[0], history=history)
