"""Conjugate gradient descent baseline (paper §II).

Polak–Ribière nonlinear CG with the paper's central-difference gradient
(eq. 1, 2n evaluations per iteration) and a sequential backtracking line
search.  Function evaluations are counted — the paper's comparison metric —
and the line search is *inherently sequential* (its scalability ceiling,
which ANM's randomized line search removes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import numpy as np


@dataclasses.dataclass
class CgdResult:
    x: np.ndarray
    fitness: float
    iterations: int
    evals: int
    history: List[float]


def finite_diff_gradient(f, x, step, count):
    n = len(x)
    g = np.zeros(n)
    for i in range(n):
        e = np.zeros(n)
        e[i] = step[i]
        g[i] = (f(x + e) - f(x - e)) / (2 * step[i])
        count[0] += 2
    return g


def cgd_minimize(f: Callable[[np.ndarray], float], x0, lo, hi, step,
                 max_iterations: int = 500, tol: float = 1e-10,
                 ls_shrink: float = 0.5, ls_max: int = 40) -> CgdResult:
    x = np.asarray(x0, np.float64).copy()
    lo = np.asarray(lo, np.float64)
    hi = np.asarray(hi, np.float64)
    step = np.asarray(step, np.float64)
    count = [0]
    fx = f(x)
    count[0] += 1
    history = [fx]
    g = finite_diff_gradient(f, x, step, count)
    d = -g
    for it in range(max_iterations):
        # backtracking line search along d (sequential — one eval at a time)
        alpha = 1.0
        improved = False
        gd = float(np.dot(g, d))
        if gd > 0:          # not a descent direction: restart with -g
            d = -g
            gd = -float(np.dot(g, g))
        for _ in range(ls_max):
            xn = np.clip(x + alpha * d, lo, hi)
            fn = f(xn)
            count[0] += 1
            if fn < fx + 1e-4 * alpha * gd:
                improved = True
                break
            alpha *= ls_shrink
        if not improved:
            history.append(fx)
            break
        x, f_prev = xn, fx
        fx = fn
        history.append(fx)
        if abs(f_prev - fx) < tol:
            break
        g_new = finite_diff_gradient(f, x, step, count)
        beta = max(0.0, float(np.dot(g_new, g_new - g) / max(np.dot(g, g), 1e-30)))
        d = -g_new + beta * d
        g = g_new
    return CgdResult(x=x, fitness=float(fx), iterations=it + 1,
                     evals=count[0], history=history)
