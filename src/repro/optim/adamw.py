"""Minimal AdamW with optional ZeRO-1-style sharded moments.

API (optax-like but self-contained):
    opt = AdamW(lr=3e-4)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # optional schedule: step -> lr multiplier
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None

    def init(self, params) -> Any:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.grad_clip > 0:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        lr = self.lr * (self.schedule(step) if self.schedule is not None else 1.0)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu = self.b1 * mu + (1 - self.b1) * g32
            nu = self.b2 * nu + (1 - self.b2) * jnp.square(g32)
            mhat = mu / b1c
            nhat = nu / b2c
            delta = mhat / (jnp.sqrt(nhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu, "step": step}


def opt_state_specs(param_specs_tree):
    """Optimizer-state PartitionSpec tree mirroring param specs (moments are
    sharded exactly like their parameters)."""
    from jax.sharding import PartitionSpec as P
    return {
        "mu": param_specs_tree,
        "nu": param_specs_tree,
        "step": P(),
    }
