"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scale quantization applied before the data-parallel gradient
all-reduce, with local error-feedback accumulators so the bias is corrected
over steps (Seide et al. / EF-SGD style).  Cuts DP all-reduce bytes 2x (bf16)
to 4x (f32).  Composes with any optimizer: wrap its grads before update.

Under pjit the quantize/dequantize pair around the psum is what GSPMD sees;
the all-reduce then moves int8.  (The dry-run hillclimb measures the
collective-byte reduction.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error_state):
    """Returns (compressed-then-decompressed grads, new_error_state).

    The returned grads are what the optimizer consumes; the quantization
    residual is carried to the next step (error feedback).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(one, grads, error_state)
    new_grads = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err
