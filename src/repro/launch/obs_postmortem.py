"""Post-mortem timeline reconstruction from the §14 retention store.

A SIGKILLed observed run leaves behind its snapshot/trace store
(``obs_store.jsonl`` / ``.sqlite`` in the checkpoint dir) and the §9
replay log.  This CLI reopens both **read-only** — no epoch marker is
appended, nothing is mutated — and reconstructs the dead server's
timeline:

  * per-epoch extent (which run wrote what: the killed run's records are
    separable from any restored run's by the epoch markers);
  * per-search phase/status transitions with virtual-time stamps;
  * fleet cohort churn (alive/suspect/dead counts over time);
  * every anomaly verdict the defense recorded (quarantines, pages,
    stall kills) at its snapshot seq;
  * per-workunit critical paths: the slowest traced spans end-to-end
    (issued→[lapsed]→reported), with host/search/phase tags;
  * turnaround percentiles over all completed spans, split by outcome;
  * the replay log's extent (records, last applied message) — the §9
    ground truth of where the dead server actually stopped.

    PYTHONPATH=src python -m repro.launch.obs_postmortem --ckpt-dir DIR
    PYTHONPATH=src python -m repro.launch.obs_postmortem --store PATH \\
        --json --out report.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Sequence

from repro.obs.retention import (OBS_STORE_DB, OBS_STORE_NAME,
                                 open_snapshot_store)
from repro.server.checkpoint import LOG_NAME


def find_store(ckpt_dir: str) -> str:
    """The §10 convention: JSONL preferred, sqlite fallback."""
    for name in (OBS_STORE_NAME, OBS_STORE_DB):
        p = os.path.join(ckpt_dir, name)
        if os.path.exists(p):
            return p
    raise FileNotFoundError(
        f"no retention store ({OBS_STORE_NAME} or {OBS_STORE_DB}) "
        f"in {ckpt_dir}")


def _percentile(sorted_vals: List[float], q: float) -> Optional[float]:
    if not sorted_vals:
        return None
    i = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return float(sorted_vals[int(i)])


def _phase_timeline(snaps: List[dict]) -> List[dict]:
    """Per-search (phase, status) transitions across the snapshot run."""
    out: List[dict] = []
    last: dict = {}
    for s in snaps:
        for e in s.get("groups", {}).get("server", {}).get("searches", []):
            sid = int(e["search_id"])
            cur = (e.get("phase"), e.get("status"))
            if last.get(sid) != cur:
                last[sid] = cur
                out.append({"seq": int(s["seq"]), "now": float(s["now"]),
                            "search": sid, "phase": e.get("phase"),
                            "status": e.get("status"),
                            "iteration": e.get("iteration"),
                            "best": e.get("best")})
    return out


def _cohort_timeline(snaps: List[dict]) -> List[dict]:
    """Fleet state-count transitions (alive/suspect/dead/warming)."""
    out: List[dict] = []
    last = None
    for s in snaps:
        reg = s.get("groups", {}).get("registry")
        if reg is None:
            continue
        st = dict(reg.get("states", {}))
        cur = (tuple(sorted(st.items())), int(reg.get("quarantined", 0)))
        if cur != last:
            last = cur
            out.append({"seq": int(s["seq"]), "now": float(s["now"]),
                        "states": st,
                        "quarantined": reg.get("quarantined", 0),
                        "reliable_set": reg.get("reliable_set"),
                        "churn": reg.get("churn")})
    return out


def _span_report(spans: List[dict], top: int = 10) -> dict:
    done = [sp for sp in spans if sp.get("turnaround") is not None]
    ts = sorted(float(sp["turnaround"]) for sp in done)
    by_outcome: dict = {}
    for sp in done:
        by_outcome[sp.get("outcome", "?")] = \
            by_outcome.get(sp.get("outcome", "?"), 0) + 1
    crit = sorted(done, key=lambda sp: -float(sp["turnaround"]))[:top]
    return {
        "spans": len(done),
        "late": sum(1 for sp in done if sp.get("late")),
        "by_outcome": by_outcome,
        "turnaround": {
            "p50": _percentile(ts, 0.50), "p90": _percentile(ts, 0.90),
            "p99": _percentile(ts, 0.99),
            "max": ts[-1] if ts else None,
        },
        "critical_paths": [{
            "search": sp.get("search"), "wu": sp.get("wu"),
            "host": sp.get("host"), "phase": sp.get("phase"),
            "issued_at": sp.get("issued_at"),
            "lapsed_at": sp.get("lapsed_at"),
            "reported_at": sp.get("reported_at"),
            "turnaround": sp.get("turnaround"),
            "outcome": sp.get("outcome"), "late": sp.get("late"),
        } for sp in crit],
    }


def _replay_log_extent(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    records = 0
    last = None
    with open(path) as f:
        for line in f:
            if not line.endswith("\n"):
                break                      # SIGKILL-torn tail
            try:
                last = json.loads(line)
            except ValueError:
                break
            records += 1
    if last is None:
        return {"records": 0}
    msg = last.get("msg", {})
    return {"records": records, "last_seq": last.get("seq"),
            "last_kind": msg.get("kind"), "last_now": msg.get("now"),
            "last_host": msg.get("host_id")}


def reconstruct(store_path: str, replay_log: Optional[str] = None,
                epoch: Optional[int] = None, top: int = 10) -> dict:
    """The timeline doc — pure data, shared by terminal and JSON modes."""
    store = open_snapshot_store(store_path, read_only=True)
    epochs_doc = []
    for ep in store.epochs():
        snaps = store.snapshots(epoch=ep)
        seqs = [int(s["seq"]) for s in snaps]
        nows = [float(s["now"]) for s in snaps]
        epochs_doc.append({
            "epoch": ep, "snapshots": len(snaps),
            "seq_range": [min(seqs), max(seqs)] if seqs else None,
            "now_range": [min(nows), max(nows)] if nows else None,
            "spans": len(store.records("span", epoch=ep)),
            "anomalies": len(store.records("anomaly", epoch=ep)),
        })
    snaps = store.snapshots(epoch=epoch)
    spans = [r["doc"] for r in store.records("span", epoch=epoch)]
    anomalies = [dict(r["doc"], epoch=r["epoch"])
                 for r in store.records("anomaly", epoch=epoch)]
    doc = {
        "store": store.summary(),
        "epoch_filter": epoch,
        "epochs": epochs_doc,
        "phases": _phase_timeline(snaps),
        "cohorts": _cohort_timeline(snaps),
        "anomalies": anomalies,
        **_span_report(spans, top=top),
    }
    if replay_log is not None:
        doc["replay_log"] = _replay_log_extent(replay_log)
    return doc


def render(doc: dict, out=sys.stdout) -> None:
    p = lambda s: print(s, file=out)   # noqa: E731
    st = doc["store"]
    p(f"== post-mortem: {st['path']}")
    p(f"   {st['records']} records, epochs {st['epochs']} "
      f"(by type: {st['by_type']})")
    for ep in doc["epochs"]:
        sr, nr = ep["seq_range"], ep["now_range"]
        p(f"   epoch {ep['epoch']}: {ep['snapshots']} snapshots"
          + (f" seq {sr[0]}..{sr[1]} t {nr[0]:.0f}..{nr[1]:.0f}"
             if sr else "")
          + f", {ep['spans']} spans, {ep['anomalies']} anomalies")
    rl = doc.get("replay_log")
    if rl is not None:
        p(f"-- replay log: {rl.get('records')} applied records"
          + ("" if rl.get("last_kind") is None else
             f", last {rl['last_kind']!r} at t={rl.get('last_now')}"))
    p(f"-- phase transitions ({len(doc['phases'])}):")
    for t in doc["phases"]:
        best = t.get("best")
        p(f"   seq {t['seq']:>4} t={t['now']:>8.1f} search {t['search']}: "
          f"phase={t['phase']} status={t['status']} "
          f"iter={t['iteration']} best="
          + ("?" if best is None else f"{best:.6f}"))
    p(f"-- cohort churn ({len(doc['cohorts'])} transitions):")
    for c in doc["cohorts"]:
        p(f"   seq {c['seq']:>4} t={c['now']:>8.1f} states={c['states']} "
          f"quarantined={c['quarantined']} reliable={c['reliable_set']}")
    p(f"-- anomaly verdicts ({len(doc['anomalies'])}):")
    for a in doc["anomalies"]:
        p(f"   seq {a['seq']:>4} t={a['now']:>8.1f} [{a['action']}] "
          f"{a['kind']} hosts={a['hosts']} detail={a.get('detail')}")
    tr = doc["turnaround"]
    p(f"-- workunit spans: {doc['spans']} completed "
      f"({doc['late']} late; by outcome {doc['by_outcome']})")
    if tr["p50"] is not None:
        p(f"   turnaround p50={tr['p50']:.1f} p90={tr['p90']:.1f} "
          f"p99={tr['p99']:.1f} max={tr['max']:.1f} (virtual s)")
    p("-- critical paths (slowest spans):")
    for sp in doc["critical_paths"]:
        lap = ("" if sp.get("lapsed_at") is None
               else f" lapsed@{sp['lapsed_at']:.0f}")
        p(f"   s{sp['search']}/wu{sp['wu']} host {sp['host']} "
          f"phase {sp['phase']}: {sp['turnaround']:.1f}s "
          f"[{sp['outcome']}{' late' if sp.get('late') else ''}]{lap}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir holding the retention store "
                         "(+ replay log, used when present)")
    ap.add_argument("--store", default=None,
                    help="explicit retention store path (overrides the "
                         "--ckpt-dir convention)")
    ap.add_argument("--replay-log", default=None,
                    help="explicit replay log path")
    ap.add_argument("--epoch", type=int, default=None,
                    help="restrict the timeline to one epoch "
                         "(default: all)")
    ap.add_argument("--top", type=int, default=10,
                    help="critical paths listed")
    ap.add_argument("--json", action="store_true",
                    help="emit the timeline doc as JSON")
    ap.add_argument("--out", default=None, help="write the report here")
    args = ap.parse_args(argv)

    if args.store is None and args.ckpt_dir is None:
        ap.error("need --store or --ckpt-dir")
    store_path = args.store or find_store(args.ckpt_dir)
    replay_log = args.replay_log
    if replay_log is None and args.ckpt_dir is not None:
        replay_log = os.path.join(args.ckpt_dir, LOG_NAME)
    doc = reconstruct(store_path, replay_log=replay_log, epoch=args.epoch,
                      top=args.top)
    if args.out:
        with open(args.out, "w") as f:
            if args.json:
                json.dump(doc, f, indent=2)
            else:
                render(doc, out=f)
        print(f"[postmortem] wrote {args.out}")
    elif args.json:
        json.dump(doc, sys.stdout, indent=2)
        print()
    else:
        render(doc)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
