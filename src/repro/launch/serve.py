"""Batched serving driver: prompt ingestion + autoregressive decode.

Prompts are consumed through the same serve_step used by the decode dry-run
(the cache fills token by token; a fused prefill kernel is the production
path, see DESIGN.md), then tokens are sampled with temperature/top-k.
Continuous batching: finished sequences are replaced by queued requests
without stopping the decode loop.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import init_cache, init_params, make_serve_step


def sample_logits(key, logits, temperature: float = 1.0, top_k: int = 40):
    logits = logits.astype(jnp.float32) / max(temperature, 1e-4)
    if top_k > 0 and top_k < logits.shape[-1]:
        vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < vals[..., -1:], -jnp.inf, logits)
    return jax.random.categorical(key, logits)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b", choices=ARCH_NAMES,
                    help="smoke-reduced config of this arch is served")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if cfg.is_encoder:
        print("encoder-only arch has no decode path", file=sys.stderr)
        return 1
    key = jax.random.key(args.seed)
    params = init_params(cfg, key)
    serve_step = jax.jit(make_serve_step(cfg))

    rng = np.random.default_rng(args.seed)
    queue: List[np.ndarray] = [
        rng.integers(1, cfg.vocab_size, args.prompt_len).astype(np.int32)
        for _ in range(args.requests)]
    print(f"[serve] {cfg.name}: {args.requests} requests, batch={args.batch}")

    cache = init_cache(cfg, args.batch, args.max_seq)
    active = [queue.pop(0) if queue else None for _ in range(args.batch)]
    pos = [0] * args.batch
    outputs = {i: [] for i in range(args.requests)}
    req_ids = list(range(min(args.batch, args.requests)))
    next_req = len(req_ids)
    done = 0
    t = 0
    t0 = time.time()
    steps = 0
    cur_tok = np.zeros((args.batch, 1), np.int32)
    for b in range(args.batch):
        if active[b] is not None:
            cur_tok[b, 0] = active[b][0]
            pos[b] = 1

    while done < args.requests and t < args.max_seq - 1:
        key, skey = jax.random.split(key)
        logits, cache = serve_step(params, cache, jnp.asarray(cur_tok),
                                   jnp.int32(t))
        steps += 1
        nxt = np.asarray(sample_logits(skey, logits[:, 0]))
        t += 1
        for b in range(args.batch):
            if active[b] is None:
                continue
            rid = req_ids[b]
            if pos[b] < len(active[b]):
                cur_tok[b, 0] = active[b][pos[b]]           # still prefill
                pos[b] += 1
            else:
                tok = int(nxt[b])
                outputs[rid].append(tok)
                cur_tok[b, 0] = tok
                if len(outputs[rid]) >= args.gen_len:
                    done += 1
                    if queue:                               # continuous batching
                        active[b] = queue.pop(0)
                        req_ids[b] = next_req
                        next_req += 1
                        pos[b] = 1
                        cur_tok[b, 0] = active[b][0]
                    else:
                        active[b] = None

    dt = time.time() - t0
    for rid in range(args.requests):
        print(f"[serve] req{rid}: {len(outputs[rid])} tokens "
              f"-> {outputs[rid][:8]}...")
    print(f"[serve] {steps} decode steps, {steps * args.batch / dt:.1f} tok/s "
          f"(batched), {dt:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
