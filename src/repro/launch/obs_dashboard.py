"""Terminal/JSON dashboard over the ``subscribe_stats`` stream (§13).

A read-only monitoring client: it connects to a running work server (TCP
host:port), long-polls the metrics ring with a cursor, and renders each
stamped snapshot — fleet states, reliable set, service pressure, per-
search phase/iteration/best, message rate with a sparkline.  Because the
stream is served by the same unstamped/unlogged path as ``status``,
watching a run CANNOT perturb it: the committed iterates are bit-identical
with or without a dashboard attached (the obs_server dryrun smoke gates
exactly this).

    # against a live server
    PYTHONPATH=src python -m repro.launch.obs_dashboard --host H --port P

    # self-contained demo: serves a seeded smoke fleet in-process and
    # watches it live through a real framed connection
    PYTHONPATH=src python -m repro.launch.obs_dashboard --demo

``--json`` emits one JSON line per snapshot instead of the terminal view
(the machine-readable mode CI and scripts consume).
"""
from __future__ import annotations

import argparse
import collections
import json
import sys
import threading
import time
from typing import Optional, Sequence

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values: Sequence[float], width: int = 24) -> str:
    """Unicode mini-chart of the last ``width`` values."""
    vs = [float(v) for v in values][-width:]
    if not vs:
        return ""
    lo, hi = min(vs), max(vs)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))]
                   for v in vs)


def render(snap: dict, rate_history: Sequence[float] = (),
           dropped: int = 0) -> str:
    """One snapshot as a compact terminal block (pure function: testable
    without a terminal or a server).  ``dropped`` is the ring-gap count
    the stats reply carried for this batch — rendered loudly rather than
    letting seqs silently skip (§14 satellite)."""
    g = snap.get("groups", {})
    srv = g.get("server", {})
    reg = g.get("registry", {})
    lines = [f"-- obs snapshot seq={snap['seq']} t={snap['now']:.1f} "
             f"(stream v{snap['stream_v']})"]
    if dropped:
        lines.append(f"   !! gap: {dropped} snapshots fell off the ring "
                     f"before this one")
    rate = srv.get("messages_per_s")
    rate_s = "" if rate is None else f" ({rate:.1f} msg/s)"
    lines.append(
        f"   server: {srv.get('messages', '?')} messages{rate_s} "
        f"{sparkline(rate_history)}")
    lines.append(
        f"   pressure: {srv.get('lease_depth', '?')} leases, "
        f"{srv.get('lapsed_depth', '?')} lapsed"
        + ("" if "intake" not in g else
           f", intake parked {g['intake'].get('parked')}"))
    if reg:
        st = reg.get("states", {})
        lines.append(
            f"   fleet: {reg.get('hosts', '?')} hosts "
            f"(alive {st.get('alive', 0)} / suspect {st.get('suspect', 0)} "
            f"/ dead {st.get('dead', 0)}), warming {reg.get('warming', 0)}, "
            f"reliable {reg.get('reliable_set', '?')}, "
            f"quarantined {reg.get('quarantined', 0)}")
        ch = reg.get("churn", {})
        lines.append(
            f"   churn: →suspect {ch.get('to_suspect', 0)}, "
            f"→dead {ch.get('to_dead', 0)}, revived {ch.get('revived', 0)}")
    if "cache" in g and g["cache"]:
        c = g["cache"]
        lines.append(f"   cache: {c.get('hits', 0)} hits / "
                     f"{c.get('misses', 0)} misses "
                     f"(rate {c.get('hit_rate', 0.0):.2f})")
    for s in srv.get("searches", []):
        best = s.get("best")
        best_s = "?" if best is None else f"{best:.6f}"
        lines.append(f"   search {s.get('search_id')}: {s.get('status')} "
                     f"phase={s.get('phase')} iter={s.get('iteration')} "
                     f"best={best_s}")
    return "\n".join(lines)


def watch(connect, *, as_json: bool = False, poll_s: float = 0.25,
          max_snapshots: Optional[int] = None,
          stop: Optional[threading.Event] = None,
          out=sys.stdout) -> int:
    """Poll ``subscribe_stats`` on the connection ``connect()`` returns and
    render every snapshot until the stream goes quiet (server shut down),
    ``max_snapshots`` arrive, or ``stop`` is set.  Returns the number of
    snapshots rendered."""
    from repro.obs import StatsSubscriber
    from repro.server.protocol import ProtocolError

    conn = connect()
    sub = StatsSubscriber(conn)
    rates: collections.deque = collections.deque(maxlen=64)
    shown = 0
    try:
        while stop is None or not stop.is_set():
            try:
                snaps = sub.poll()
            except (ProtocolError, OSError) as e:
                print(f"[obs] stream ended: {e}", file=out)
                break
            gap = sub.last_dropped
            if gap and as_json:
                # a distinct record kind, so snapshot consumers that key
                # on ``seq`` can skip it while gap-aware ones alert
                print(json.dumps({"kind": "gap", "dropped": int(gap)}),
                      file=out, flush=True)
            for i, snap in enumerate(snaps):
                r = snap.get("groups", {}).get("server", {}) \
                    .get("messages_per_s")
                if isinstance(r, (int, float)):
                    rates.append(float(r))
                if as_json:
                    print(json.dumps(snap), file=out, flush=True)
                else:
                    print(render(snap, rates, dropped=gap if i == 0 else 0),
                          file=out, flush=True)
                shown += 1
                if max_snapshots is not None and shown >= max_snapshots:
                    return shown
            if not snaps:
                time.sleep(poll_s)
    finally:
        try:
            conn.close()
        except Exception:
            pass
    return shown


def _demo(args) -> int:
    """Serve a seeded smoke fleet in-process (loopback transport, metrics
    hub attached) and watch it live — the zero-setup way to see the
    stream."""
    from repro.core.substrates.eval_backend import InProcessEvalBackend
    from repro.obs import MetricsHub
    from repro.server.server import WorkServer
    from repro.server.sim import SimClientPool, smoke_problem
    from repro.server.transport import LoopbackTransport

    spec, fleet, f_batch = smoke_problem(n_stars=120, n_hosts=64, m=12,
                                         iterations=3)
    server = WorkServer([spec], lease_timeout=8.0 * fleet.base_eval_time,
                        idle_retry=fleet.idle_retry)
    hub = MetricsHub(interval=args.interval)
    server.attach_hub(hub)
    lock = threading.Lock()          # dashboard polls race the fleet

    def handler(msg):
        with lock:
            return server.handle(msg)

    transport = LoopbackTransport().start(handler)
    pool = SimClientPool(fleet, InProcessEvalBackend(f_batch))
    done = threading.Event()

    def drive():
        try:
            pool.run(transport.connect())
        finally:
            done.set()

    driver = threading.Thread(target=drive, daemon=True, name="obs-demo")
    driver.start()
    shown = watch(transport.connect, as_json=args.json, poll_s=0.05,
                  max_snapshots=args.max_snapshots, stop=done)
    # let the fleet finish before teardown — a JAX call interrupted by
    # interpreter exit aborts uncleanly
    driver.join(timeout=600.0)
    eng = server.engines[0]
    print(f"[obs] demo done: {shown} snapshots, {pool.stats.messages} "
          f"messages, best {eng.best_fitness:.6f}")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=None,
                    help="TCP port of a running work server")
    ap.add_argument("--demo", action="store_true",
                    help="serve + watch a seeded in-process smoke fleet")
    ap.add_argument("--json", action="store_true",
                    help="one JSON line per snapshot (machine-readable)")
    ap.add_argument("--interval", type=float, default=10.0,
                    help="demo: virtual seconds between snapshots")
    ap.add_argument("--poll-s", type=float, default=0.25,
                    help="wall-clock long-poll spacing")
    ap.add_argument("--max-snapshots", type=int, default=None,
                    help="stop after this many snapshots")
    args = ap.parse_args(argv)

    if args.demo:
        return _demo(args)
    if args.port is None:
        ap.error("need --port (or --demo)")

    def connect():
        from repro.server.transport import TcpConnection
        return TcpConnection(args.host, args.port)

    watch(connect, as_json=args.json, poll_s=args.poll_s,
          max_snapshots=args.max_snapshots)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
