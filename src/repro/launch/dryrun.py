import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build ShapeDtypeStruct stand-ins for params / optimizer
state / inputs / caches (NO device allocation), jit the step with explicit
in/out shardings, ``.lower().compile()`` against the production mesh, and
record memory_analysis / cost_analysis / per-kind collective bytes into
artifacts/dryrun/<arch>__<shape>__<mesh>.json for the roofline report.

``--substrate pod_mesh`` instead runs the batched-grid substrate smoke:
the same ANM workload through the in-process backend (synchronous and
PIPELINED tick loops) and the pipelined shard_map pod-mesh backend on the
forced 512-device host platform, requiring bit-identical committed
iterates across all three (DESIGN.md §6–§7) — so the production
partitioning AND the async submit/collect path are exercised on CPU
before any TPU time is spent.

``--substrate multi_search`` runs the multi-search orchestrator smoke
(DESIGN.md §8): a heterogeneous portfolio of concurrent ANM searches
coalesced over ONE shared backend — in-process and shard_map'd over the
production mesh — where every orchestrated search must commit
bit-identical iterates to the same spec run alone on the same backend.

``--substrate server`` runs the service-layer kill/restore smoke
(DESIGN.md §9): a seeded search through the work server + simulated
client fleet, SIGKILLed mid-search and restored from its snapshot +
replay log — the restored run must commit bit-identical final iterates
and identical final engine stats vs the same spec run uninterrupted, on
BOTH the loopback and the TCP transport, and the in-process and pod-mesh
evaluation paths must agree.

The substrate names, descriptions and runners live in ONE registry
(``repro/launch/substrates.py``) — argparse ``choices`` derive from it
(an unknown name fails at parse time) and ``--list-substrates`` prints
it; ``benchmarks/scalability.py`` validates its own substrate filter
against the same dict.

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh pod|multipod|both] [--skip-existing]
    python -m repro.launch.dryrun --substrate pod_mesh
    python -m repro.launch.dryrun --substrate multi_search
    python -m repro.launch.dryrun --substrate server
    python -m repro.launch.dryrun --list-substrates
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.substrates import SUBSTRATES, list_substrates
from repro.models import (
    ShardCtx, cache_specs, init_cache, init_params, input_specs,
    make_prefill_step, make_serve_step, make_train_step, mesh_axes, param_specs,
)
from repro.optim.adamw import AdamW, opt_state_specs
from repro.roofline.analysis import (
    collective_bytes_from_hlo, model_flops, roofline_terms,
)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")


def _named(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mla_absorb: bool = False, extra_tags=None, cfg_override=None,
               donate: bool = False, fsdp: bool = False):
    """Returns (lowered, compiled, report dict)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp, tp = mesh_axes(mesh)
    ctx = ShardCtx(mesh=mesh, dp=dp, tp=tp)
    n_chips = mesh.size

    pspecs = param_specs(cfg, mesh, fsdp=fsdp)
    params_sds = jax.eval_shape(functools.partial(init_params, cfg),
                                jax.random.key(0))
    psh = _named(pspecs, mesh)
    batch_sds, batch_pspecs = input_specs(cfg, shape, mesh)
    bsh = _named(batch_pspecs, mesh)
    t0 = time.time()

    # unroll=True: every layer appears in the HLO, so cost_analysis FLOPs and
    # parsed collective bytes are whole-program (XLA counts a while body once)
    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        osh = _named(opt_state_specs(pspecs), mesh)
        step_fn = make_train_step(cfg, opt, ctx, unroll=True)
        jitted = jax.jit(step_fn,
                         in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, NamedSharding(mesh, P())),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(cfg, ctx, unroll=True)
        jitted = jax.jit(step_fn, in_shardings=(psh, bsh))
        lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        cache_sds = init_cache(cfg, shape.global_batch, shape.seq_len,
                               as_shape=True)
        csh = _named(cache_specs(cfg, shape, mesh), mesh)
        step_fn = make_serve_step(cfg, ctx, absorb=mla_absorb, unroll=True)
        jitted = jax.jit(step_fn,
                         in_shardings=(psh, csh, bsh["tokens"], bsh["t"]),
                         out_shardings=(None, csh),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(params_sds, cache_sds,
                               batch_sds["tokens"], batch_sds["t"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_report = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_report = {"error": str(e)}

    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    terms = roofline_terms(flops, bytes_accessed, coll.total_bytes, n_chips)
    mf = model_flops(cfg, shape, shape.kind)

    report = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "tags": extra_tags or {},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_report,
        "hlo_flops": flops,
        "hlo_bytes_accessed": bytes_accessed,
        "collective_bytes": coll.total_bytes,
        "collective_bytes_by_kind": coll.bytes_by_kind,
        "collective_count_by_kind": coll.count_by_kind,
        "top_collectives": coll.top_ops,
        "model_flops": mf,
        # hlo flops are per-device; scale up for the whole-program ratio
        "useful_flops_ratio": (mf / (flops * n_chips)) if flops else None,
        "n_params": get_config(arch).n_params(),
        "n_active_params": get_config(arch).n_active_params(),
        **terms,
    }
    return lowered, compiled, report


def run_cell(arch, shape_name, multi_pod, out_dir, skip_existing=False,
             mla_absorb=False, suffix="", cfg_override=None, donate=False,
             fsdp=False):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape_name}__{mesh_tag}{suffix}"
    path = os.path.join(out_dir, name + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip] {name}")
        return True
    cfg = get_config(arch)
    ok, reason = cell_is_runnable(cfg, SHAPES[shape_name])
    if not ok:
        report = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                  "skipped": True, "reason": reason}
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[SKIP-RULE] {name}: {reason}")
        return True
    try:
        _, compiled, report = lower_cell(arch, shape_name, multi_pod,
                                         mla_absorb=mla_absorb,
                                         cfg_override=cfg_override,
                                         donate=donate, fsdp=fsdp)
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[ok] {name}: compile={report['compile_s']}s "
              f"flops={report['hlo_flops']:.3e} coll={report['collective_bytes']:.3e} "
              f"dom={report['dominant']} frac={report['roofline_fraction']:.3f}")
        del compiled
        return True
    except Exception:
        err = traceback.format_exc()
        with open(path + ".err", "w") as f:
            f.write(err)
        print(f"[FAIL] {name}:\n{err}")
        return False


def run_substrate_smoke(out_dir: str, m: int = 32, iterations: int = 2,
                        n_stars: int = 500, n_hosts: int = 512) -> bool:
    """Pod-mesh + pipelined substrate smoke (``--substrate pod_mesh``).

    Runs the SAME batched-grid workload three ways — in-process backend
    with the synchronous tick loop (the reference), in-process PIPELINED,
    and ``PodMeshEvalBackend`` pipelined with every bucket shard_mapped
    over the production (data=16, model=16) mesh of forced host devices —
    and requires identical committed centers, fitness history and
    iteration counts across all three (DESIGN.md §6–§7).  Writes
    artifacts/dryrun/substrate_pod_mesh.json; returns pass/fail.
    """
    import numpy as np
    from repro.core.anm import AnmConfig
    from repro.core.engine import AnmEngine, identical_trajectories
    from repro.core.grid import GridConfig
    from repro.core.substrates.batched_grid import BatchedVolunteerGrid
    from repro.core.substrates.pod_mesh import PodMeshEvalBackend
    from repro.data import sdss

    mesh = make_production_mesh()
    stripe = sdss.make_stripe("podmesh_smoke", n_stars=n_stars, seed=17)
    f_batch, _ = sdss.make_fitness(stripe)
    rng = np.random.default_rng(3)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.2, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    anm_cfg = AnmConfig(m_regression=m, m_line_search=m,
                        max_iterations=iterations)
    grid_cfg = GridConfig(n_hosts=n_hosts, failure_prob=0.05,
                          malicious_prob=0.01, seed=9)

    def run_with(backend, pipelined):
        engine = AnmEngine(x0, sdss.LO, sdss.HI, sdss.DEFAULT_STEP,
                           anm_cfg, seed=7)
        t0 = time.time()
        stats = BatchedVolunteerGrid(f_batch, grid_cfg, backend=backend,
                                     pipelined=pipelined).run(engine)
        return engine, stats, time.time() - t0

    # one backend per evaluation target, shared across loop modes and
    # warmed over the whole bucket ladder at construction, so NO timed
    # window below pays a compile — otherwise the first (sync) run would
    # absorb the ladder and bias the sync-vs-pipelined comparison
    from repro.core.substrates.eval_backend import (InProcessEvalBackend,
                                                    bucket_size)
    max_bucket = bucket_size(BatchedVolunteerGrid.warm_max_bucket(m))
    in_backend = InProcessEvalBackend(f_batch, n_dims=8,
                                      max_bucket=max_bucket)
    e_in, s_in, t_in = run_with(in_backend, False)   # in-process, sync
    e_pin, s_pin, t_pin = run_with(in_backend, True)  # in-process, pipelined
    pod = PodMeshEvalBackend(f_batch, mesh=mesh, n_dims=8,
                             max_bucket=max_bucket)
    e_pod, s_pod, t_pod = run_with(pod, True)         # pod mesh, pipelined

    centers_equal = (
        len(e_in.history) == len(e_pod.history) and
        all(np.array_equal(a.center, b.center)
            for a, b in zip(e_in.history, e_pod.history)))
    fitness_equal = [r.best_fitness for r in e_in.history] == \
        [r.best_fitness for r in e_pod.history]
    pipelined_ok = identical_trajectories(e_in, e_pin)
    pod_ok = identical_trajectories(e_in, e_pod)
    ok = pipelined_ok and pod_ok
    report = {
        "mesh": "16x16", "data_shards": pod.n_shards,
        "min_bucket": pod.min_bucket, "n_hosts": n_hosts, "m": m,
        "iterations": {"in_process": e_in.iteration,
                       "in_process_pipelined": e_pin.iteration,
                       "pod_mesh": e_pod.iteration},
        "final": {"in_process": e_in.best_fitness,
                  "in_process_pipelined": e_pin.best_fitness,
                  "pod_mesh": e_pod.best_fitness},
        "batch_calls": {"in_process": s_in.batch_calls,
                        "in_process_pipelined": s_pin.batch_calls,
                        "pod_mesh": s_pod.batch_calls},
        "wall_s": {"in_process": round(t_in, 3),
                   "in_process_pipelined": round(t_pin, 3),
                   "pod_mesh": round(t_pod, 3)},
        "pipeline": {"spec_blocks": s_pin.spec_blocks,
                     "spec_discarded": s_pin.spec_discarded,
                     "max_in_flight": s_pin.max_in_flight,
                     "pod_max_in_flight": s_pod.max_in_flight},
        "centers_equal": centers_equal, "fitness_equal": fitness_equal,
        "pipelined_parity_ok": pipelined_ok, "pod_parity_ok": pod_ok,
        "parity_ok": ok,
    }
    path = os.path.join(out_dir, "substrate_pod_mesh.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[{'ok' if ok else 'FAIL'}] substrate pod_mesh: "
          f"{pod.n_shards} data shards, iters "
          f"{e_in.iteration}/{e_pin.iteration}/{e_pod.iteration}, final "
          f"{e_in.best_fitness:.6f}/{e_pin.best_fitness:.6f}/"
          f"{e_pod.best_fitness:.6f}, wall {t_in:.2f}s/{t_pin:.2f}s/"
          f"{t_pod:.2f}s (sync/pipelined/pod-pipelined) -> {path}")
    return ok


def run_multi_search_smoke(out_dir: str, n_searches: int = 4, m: int = 24,
                           iterations: int = 2, n_stars: int = 400,
                           fleet_hosts: int = 512) -> bool:
    """Multi-search orchestrator smoke (``--substrate multi_search``).

    A heterogeneous ``n_searches``-way portfolio (two different per-phase
    ``m``'s, perturbed starts, per-slot sub-fleets) runs coalesced over
    one shared backend, twice: through ``InProcessEvalBackend`` and
    through ``PodMeshEvalBackend`` on the production (data=16, model=16)
    mesh of forced host devices.  For EVERY search and BOTH backends, the
    orchestrated engine must commit bit-identical iterates and identical
    final stats to the same spec run alone on the same backend — the
    coalescing-safety contract of DESIGN.md §8.  Writes
    artifacts/dryrun/substrate_multi_search.json; returns pass/fail.
    """
    import numpy as np
    from repro.core.anm import AnmConfig
    from repro.core.engine import identical_trajectories
    from repro.core.grid import GridConfig
    from repro.core.orchestrator import (FleetScheduler, SearchDirector,
                                         multi_start_specs)
    from repro.core.substrates.eval_backend import InProcessEvalBackend
    from repro.core.substrates.pod_mesh import PodMeshEvalBackend
    from repro.data import sdss

    mesh = make_production_mesh()
    stripe = sdss.make_stripe("multisearch_smoke", n_stars=n_stars, seed=23)
    f_batch, _ = sdss.make_fitness(stripe)
    rng = np.random.default_rng(3)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.2, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    fleet = GridConfig(n_hosts=fleet_hosts, failure_prob=0.05,
                       malicious_prob=0.01, seed=9)
    configs = [AnmConfig(m_regression=m, m_line_search=m,
                         max_iterations=iterations),
               AnmConfig(m_regression=m // 2, m_line_search=m // 2,
                         max_iterations=iterations)]

    def run_portfolio(backend):
        sched = FleetScheduler(backend, fleet)
        specs = multi_start_specs(sched, x0, sdss.LO, sdss.HI,
                                  sdss.DEFAULT_STEP, configs[0], n_searches,
                                  seed=7, jitter=0.3, configs=configs)
        t0 = time.time()
        res = SearchDirector(sched, specs).run()
        wall = time.time() - t0
        parity = []
        for o in res.outcomes:
            solo = o.spec.solo_run(backend)
            parity.append(identical_trajectories(o.engine, solo)
                          and o.engine.stats == solo.stats)
        return res, wall, parity

    backends = {
        "in_process": InProcessEvalBackend(f_batch),
        "pod_mesh": PodMeshEvalBackend(f_batch, mesh=mesh),
    }
    report = {"mesh": "16x16", "n_searches": n_searches,
              "fleet_hosts": fleet_hosts, "backends": {}}
    ok = True
    cross = {}
    for name, backend in backends.items():
        res, wall, parity = run_portfolio(backend)
        co = res.coalesce_stats
        report["backends"][name] = {
            "parity_per_search": parity,
            "iterations": [o.engine.iteration for o in res.outcomes],
            "final": [o.engine.best_fitness for o in res.outcomes],
            "rounds": res.rounds,
            "dispatches": co.dispatches, "lane_blocks": co.lane_blocks,
            "padded_lanes": co.padded_lanes,
            "solo_padded_lanes": co.solo_padded_lanes,
            "wall_s": round(wall, 3),
        }
        cross[name] = res
        ok = ok and all(parity)
    # row-independence also means the portfolio itself must agree across
    # backends, search by search
    backend_pair_ok = all(
        identical_trajectories(a.engine, b.engine)
        for a, b in zip(cross["in_process"].outcomes,
                        cross["pod_mesh"].outcomes))
    ok = ok and backend_pair_ok
    report["cross_backend_ok"] = backend_pair_ok
    report["parity_ok"] = ok
    path = os.path.join(out_dir, "substrate_multi_search.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    rb = report["backends"]
    print(f"[{'ok' if ok else 'FAIL'}] substrate multi_search: "
          f"{n_searches} searches, dispatches "
          f"{rb['in_process']['dispatches']}/{rb['pod_mesh']['dispatches']} "
          f"for {rb['in_process']['lane_blocks']} blocks, wall "
          f"{rb['in_process']['wall_s']}s/{rb['pod_mesh']['wall_s']}s "
          f"(in-process/pod), cross-backend "
          f"{'ok' if backend_pair_ok else 'FAIL'} -> {path}")
    return ok


def run_cached_portfolio_smoke(out_dir: str, n_searches: int = 8,
                               m: int = 24, iterations: int = 2,
                               n_stars: int = 400,
                               fleet_hosts: int = 512) -> bool:
    """Eval-cache smoke (``--substrate cached_portfolio``).

    An ``n_searches``-way coalesced portfolio runs three times per
    backend (``InProcessEvalBackend`` and ``PodMeshEvalBackend`` on the
    production mesh): cache-off, cache-on cold, and cache-on warm (same
    cache, whole portfolio replayed).  The §10 gates:

      * bit-exact parity — both cache-on runs commit bit-identical
        iterates and identical final stats to cache-off, per search;
      * the warm rerun is FULLY served — zero new misses, hits > 0
        (only malicious lanes touch the device again).

    Writes artifacts/dryrun/substrate_cached_portfolio.json.
    """
    import numpy as np
    from repro.core.anm import AnmConfig
    from repro.core.engine import identical_trajectories
    from repro.core.grid import GridConfig
    from repro.core.orchestrator import (FleetScheduler, SearchDirector,
                                         multi_start_specs)
    from repro.core.substrates.eval_backend import InProcessEvalBackend
    from repro.core.substrates.eval_cache import EvalCache
    from repro.core.substrates.pod_mesh import PodMeshEvalBackend
    from repro.data import sdss

    mesh = make_production_mesh()
    stripe = sdss.make_stripe("cached_portfolio_smoke", n_stars=n_stars,
                              seed=23)
    f_batch, _ = sdss.make_fitness(stripe)
    rng = np.random.default_rng(3)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.2, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    fleet = GridConfig(n_hosts=fleet_hosts, failure_prob=0.05,
                       malicious_prob=0.01, seed=9)
    anm = AnmConfig(m_regression=m, m_line_search=m,
                    max_iterations=iterations)

    def portfolio(backend, cache):
        sched = FleetScheduler(backend, fleet, cache=cache)
        specs = multi_start_specs(sched, x0, sdss.LO, sdss.HI,
                                  sdss.DEFAULT_STEP, anm, n_searches,
                                  seed=7, jitter=0.3)
        t0 = time.time()
        res = SearchDirector(sched, specs).run()
        return res, time.time() - t0

    def pairwise_identical(a, b):
        return all(identical_trajectories(x.engine, y.engine)
                   and x.engine.stats == y.engine.stats
                   for x, y in zip(a.outcomes, b.outcomes))

    backends = {
        "in_process": InProcessEvalBackend(f_batch),
        "pod_mesh": PodMeshEvalBackend(f_batch, mesh=mesh),
    }
    report = {"mesh": "16x16", "n_searches": n_searches,
              "fleet_hosts": fleet_hosts, "backends": {}}
    ok = True
    for name, backend in backends.items():
        off, wall_off = portfolio(backend, None)
        cache = EvalCache(fingerprint=f"cached_portfolio/{name}")
        cold, wall_cold = portfolio(backend, cache)
        misses0 = cache.stats.misses
        hits0 = cache.stats.hits
        warm, wall_warm = portfolio(backend, cache)
        cold_parity = pairwise_identical(off, cold)
        warm_parity = pairwise_identical(off, warm)
        warm_served = (cache.stats.misses == misses0
                       and cache.stats.hits > hits0)
        b_ok = cold_parity and warm_parity and warm_served
        report["backends"][name] = {
            "cold_parity": cold_parity, "warm_parity": warm_parity,
            "warm_fully_served": warm_served,
            "cache": cache.status(),
            "lanes_deduped": (warm.coalesce_stats.lanes_deduped
                              if warm.coalesce_stats else 0),
            "wall_s": {"off": round(wall_off, 3),
                       "cold": round(wall_cold, 3),
                       "warm": round(wall_warm, 3)},
        }
        ok = ok and b_ok
    report["parity_ok"] = ok
    path = os.path.join(out_dir, "substrate_cached_portfolio.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    rb = report["backends"]
    ip = rb["in_process"]
    print(f"[{'ok' if ok else 'FAIL'}] substrate cached_portfolio: "
          f"{n_searches} searches, hit_rate "
          f"{ip['cache']['hit_rate']:.2f}, wall off/cold/warm "
          f"{ip['wall_s']['off']}s/{ip['wall_s']['cold']}s/"
          f"{ip['wall_s']['warm']}s (in-process), pod warm_parity "
          f"{rb['pod_mesh']['warm_parity']} -> {path}")
    return ok


def run_lm_subspace_smoke(out_dir: str, arch: str = "rwkv6-7b",
                          k: int = 6, m: int = 12, iterations: int = 2,
                          n_hosts: int = 48) -> bool:
    """LM-loss workload smoke (``--substrate lm_subspace``).

    The model stack IS the fitness function: an ``LmWorkload`` over one
    smoke config (kernels routed through ``kernels/ops.py``), searched in
    its k-dim subspace-coefficient box by the full asynchronous stack.
    Gates (DESIGN.md §11):

      1. sync == pipelined == pod: the batched grid commits bit-identical
         iterates through the in-process backend (both tick loops) and
         through the pod backend — lanes sharded over ``data``, θ0 and
         the basis STORED sharded over ``model`` on the production 16×16
         mesh — with ZERO compiles once warmed;
      2. orchestrator + cache: a coalesced 2-search portfolio over the
         shared backend, evaluated through ``CachingSubmitter``; every
         search bit-identical to its solo run, warm replay fully served;
      3. work server: the same workload through the crash-recoverable
         server (simulated crash mid-run, restore from snapshot + replay
         log) — restored == uninterrupted, and in-process == pod through
         the whole server stack.

    Writes artifacts/dryrun/substrate_lm_subspace.json; returns pass/fail.
    """
    import numpy as np
    from repro.core.engine import identical_trajectories
    from repro.core.orchestrator import (FleetScheduler, SearchDirector,
                                         multi_start_specs)
    from repro.core.substrates.batched_grid import BatchedVolunteerGrid
    from repro.core.substrates.eval_backend import bucket_size
    from repro.core.substrates.eval_cache import EvalCache
    from repro.core.substrates.lm_loss import LmLossEvalBackend
    from repro.server.sim import (ServerSubstrate, SimulatedCrash,
                                  lm_problem, result_doc)

    mesh = make_production_mesh()
    spec, fleet, wl = lm_problem(arch=arch, k=k, n_hosts=n_hosts, m=m,
                                 iterations=iterations)
    max_bucket = bucket_size(BatchedVolunteerGrid.warm_max_bucket(m))
    t0 = time.time()
    in_backend = LmLossEvalBackend(wl, n_dims=k, max_bucket=max_bucket)
    pod = LmLossEvalBackend(wl, mesh=mesh, n_dims=k, max_bucket=max_bucket)
    t_warm = time.time() - t0
    compiles_warm = (in_backend.compile_count, pod.compile_count)

    # -- gate 1: sync == pipelined == pod, zero compiles after warm --------
    def grid_run(backend, pipelined):
        engine = spec.build_engine()
        t0 = time.time()
        stats = BatchedVolunteerGrid(None, spec.grid, backend=backend,
                                     pipelined=pipelined).run(engine)
        return engine, stats, time.time() - t0

    e_sync, s_sync, t_sync = grid_run(in_backend, False)
    e_pipe, s_pipe, t_pipe = grid_run(in_backend, True)
    e_pod, s_pod, t_pod = grid_run(pod, True)
    pipe_ok = identical_trajectories(e_sync, e_pipe)
    pod_ok = identical_trajectories(e_sync, e_pod)
    zero_compiles = (in_backend.compile_count == compiles_warm[0]
                     and pod.compile_count == compiles_warm[1])

    # -- gate 2: coalesced portfolio through CachingSubmitter --------------
    cache = EvalCache(fingerprint=f"lm_subspace/{arch}/{k}")
    def portfolio():
        sched = FleetScheduler(in_backend, fleet, cache=cache)
        specs = multi_start_specs(sched, spec.x0, spec.lo, spec.hi,
                                  spec.step, spec.anm, 2, seed=7,
                                  jitter=0.3)
        return SearchDirector(sched, specs).run()

    t0 = time.time()
    cold = portfolio()
    misses0, hits0 = cache.stats.misses, cache.stats.hits
    warm = portfolio()
    t_port = time.time() - t0
    solo_parity = [identical_trajectories(o.engine,
                                          o.spec.solo_run(in_backend))
                   for o in cold.outcomes]
    warm_parity = all(identical_trajectories(a.engine, b.engine)
                      for a, b in zip(cold.outcomes, warm.outcomes))
    warm_served = (cache.stats.misses == misses0
                   and cache.stats.hits > hits0)
    orch_ok = all(solo_parity) and warm_parity and warm_served

    # -- gate 3: the crash-recoverable work server -------------------------
    import tempfile
    t0 = time.time()
    base_doc = result_doc(ServerSubstrate(spec, fleet, in_backend).run())
    pod_doc = result_doc(ServerSubstrate(spec, fleet, pod).run())
    server_backend_ok = (
        base_doc["history"] == pod_doc["history"]
        and base_doc["engine_stats"] == pod_doc["engine_stats"])
    kill_after = max(50, int(0.4 * base_doc["pool"]["messages"]))
    with tempfile.TemporaryDirectory(prefix="lm_server_") as ckpt:
        try:
            ServerSubstrate(spec, fleet, in_backend, ckpt_dir=ckpt,
                            snapshot_every=25,
                            max_messages=kill_after).run()
            crashed = False            # finished before the crash: fail
        except SimulatedCrash:
            crashed = True
        resumed = ServerSubstrate(spec, fleet, in_backend,
                                  ckpt_dir=ckpt).run(resume=True)
    res_doc = result_doc(resumed)
    restore_ok = (crashed and not res_doc["recovered_done"]
                  and res_doc["history"] == base_doc["history"]
                  and res_doc["engine_stats"] == base_doc["engine_stats"])
    t_server = time.time() - t0

    ok = (pipe_ok and pod_ok and zero_compiles and orch_ok
          and server_backend_ok and restore_ok)
    report = {
        "arch": arch, "k": k, "m": m, "iterations": iterations,
        "mesh": "16x16", "n_params": int(wl.proj.n_params),
        "data_shards": pod.n_shards, "min_bucket": pod.min_bucket,
        "model_spec_fallbacks": len(pod.spec_fallbacks),
        "warm_s": round(t_warm, 3),
        "compiles": {"in_process": in_backend.compile_count,
                     "pod": pod.compile_count,
                     "zero_after_warm": zero_compiles},
        "grid": {
            "iterations": {"sync": e_sync.iteration,
                           "pipelined": e_pipe.iteration,
                           "pod": e_pod.iteration},
            "final": {"sync": e_sync.best_fitness,
                      "pipelined": e_pipe.best_fitness,
                      "pod": e_pod.best_fitness},
            "batch_calls": {"sync": s_sync.batch_calls,
                            "pipelined": s_pipe.batch_calls,
                            "pod": s_pod.batch_calls},
            "wall_s": {"sync": round(t_sync, 3),
                       "pipelined": round(t_pipe, 3),
                       "pod": round(t_pod, 3)},
            "pipelined_parity_ok": pipe_ok, "pod_parity_ok": pod_ok,
        },
        "orchestrator": {
            "solo_parity": solo_parity, "warm_replay_parity": warm_parity,
            "warm_fully_served": warm_served, "cache": cache.status(),
            "wall_s": round(t_port, 3), "parity_ok": orch_ok,
        },
        "server": {
            "iterations": base_doc["iteration"],
            "best": base_doc["best_fitness"],
            "messages": base_doc["pool"]["messages"],
            "backend_parity_ok": server_backend_ok,
            "crashed_mid_run": crashed,
            "replayed": res_doc["replayed"],
            "resumed_leases": res_doc["pool"]["resumed_leases"],
            "restore_parity_ok": restore_ok,
            "wall_s": round(t_server, 3),
        },
        "parity_ok": ok,
    }
    path = os.path.join(out_dir, "substrate_lm_subspace.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[{'ok' if ok else 'FAIL'}] substrate lm_subspace: {arch} "
          f"({wl.proj.n_params} params, k={k}), grid "
          f"{'ok' if pipe_ok and pod_ok else 'FAIL'} "
          f"(wall {t_sync:.1f}s/{t_pipe:.1f}s/{t_pod:.1f}s "
          f"sync/pipelined/pod), compiles "
          f"{'0' if zero_compiles else 'NONZERO'} after warm, "
          f"orchestrator {'ok' if orch_ok else 'FAIL'}, server "
          f"{'ok' if server_backend_ok and restore_ok else 'FAIL'} "
          f"-> {path}")
    return ok


def run_server_smoke(out_dir: str, n_hosts: int = 160, m: int = 24,
                     iterations: int = 4, n_stars: int = 400) -> bool:
    """Service-layer kill/restore smoke (``--substrate server``).

    The seeded smoke search (``repro.server.sim.smoke_problem``) runs four
    ways, every subprocess with a CLEAN single-device CPU environment (the
    dryrun's forced 512-device platform stays in THIS process):

      1. uninterrupted, loopback transport                → the baseline;
      2. uninterrupted, pod-mesh evaluation path          → must equal 1
         (row-independence across evaluation widths, DESIGN.md §6/§8) —
         plus an IN-PROCESS run over the production 16×16 mesh here in
         the parent, exercising the real partitioning;
      3. SIGKILLed mid-search on loopback, restored from snapshot +
         replay log, run to completion                    → must equal 1;
      4. the same kill/restore over the TCP transport     → must equal 1;
      5. the same loopback kill/restore with ``--cache``  → must equal 1,
         AND the restored process must come back WARM: its eval-cache
         store survives the SIGKILL in the checkpoint dir and serves the
         re-leased in-flight points (``cache.hits > 0``, DESIGN.md §10).

    "Equal" is the hard service-layer contract: bit-identical committed
    centers and fitness history AND identical final ``EngineStats``.
    Writes artifacts/dryrun/substrate_server.json; returns pass/fail.
    """
    import shutil
    import signal
    import subprocess
    import sys
    import tempfile

    child_env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    child_env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           ".."))
    child_env["PYTHONPATH"] = src_dir + (
        ":" + child_env["PYTHONPATH"] if child_env.get("PYTHONPATH") else "")
    spec_args = ["--n-hosts", str(n_hosts), "--m", str(m),
                 "--iterations", str(iterations), "--n-stars", str(n_stars)]

    def child(extra, timeout=600):
        cmd = [sys.executable, "-m", "repro.server.sim"] + spec_args + extra
        return subprocess.run(cmd, env=child_env, timeout=timeout,
                              capture_output=True, text=True)

    def load(path):
        with open(path) as f:
            return json.load(f)

    def trajectories_equal(a, b):
        return (a["history"] == b["history"]
                and a["iteration"] == b["iteration"]
                and a["best_fitness"] == b["best_fitness"]
                and a["engine_stats"] == b["engine_stats"])

    tmp = tempfile.mkdtemp(prefix="server_smoke_")
    report = {"n_hosts": n_hosts, "m": m, "iterations": iterations}
    ok = True
    try:
        # 1+2: uninterrupted baselines on both evaluation paths
        base_path = os.path.join(tmp, "base.json")
        r = child(["--out", base_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("baseline child failed")
        base = load(base_path)
        pod_path = os.path.join(tmp, "pod.json")
        r = child(["--backend", "pod_mesh", "--out", pod_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("pod-backend child failed")
        pod = load(pod_path)
        backend_ok = trajectories_equal(base, pod)
        # ... and the REAL 16x16 partitioning, in-parent on the forced
        # 512-device platform (the whole point of the dryrun environment)
        from repro.core.substrates.pod_mesh import PodMeshEvalBackend
        from repro.server.sim import (ServerSubstrate, result_doc,
                                      smoke_problem)
        spec, fleet, f_batch = smoke_problem(
            n_stars=n_stars, n_hosts=n_hosts, m=m, iterations=iterations)
        mesh_backend = PodMeshEvalBackend(f_batch,
                                          mesh=make_production_mesh())
        mesh_doc = result_doc(
            ServerSubstrate(spec, fleet, mesh_backend).run())
        mesh_ok = trajectories_equal(base, mesh_doc)

        # 3+4+5: SIGKILL mid-search, restore, compare — both transports,
        # then loopback again with the persistent eval cache enabled
        kills = {}
        variants = (("loopback", "loopback", []),
                    ("tcp", "tcp", []),
                    ("loopback_cache", "loopback", ["--cache"]))
        for variant, transport, cache_args in variants:
            ckpt = os.path.join(tmp, f"ckpt_{variant}")
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.server.sim", *spec_args,
                 "--transport", transport, "--ckpt-dir", ckpt,
                 "--snapshot-every", "200", "--throttle-s", "0.002",
                 *cache_args],
                env=child_env, stdout=subprocess.PIPE,
                stderr=subprocess.PIPE)
            log_path = os.path.join(ckpt, "replay.jsonl")
            deadline = time.time() + 300
            killed_mid_run = False
            # kill once ~40% of the baseline's message count has been
            # logged: deep enough that the kill lands well past the
            # bootstrap, with most of the run still ahead (the throttle
            # in the child stretches the wall-clock window so the 20 ms
            # poll cannot miss it)
            kill_after = max(200, int(0.4 * base["pool"]["messages"]))
            while time.time() < deadline:
                if proc.poll() is not None:
                    break             # finished before we could kill: fail
                has_snap = os.path.isdir(ckpt) and any(
                    f.startswith("snapshot_") for f in os.listdir(ckpt))
                log_lines = 0
                if os.path.exists(log_path):
                    with open(log_path, "rb") as f:
                        log_lines = f.read().count(b"\n")
                if has_snap and log_lines >= kill_after:
                    proc.send_signal(signal.SIGKILL)
                    proc.wait(timeout=30)
                    killed_mid_run = True
                    break
                time.sleep(0.02)
            if not killed_mid_run:
                proc.kill()
                kills[variant] = {"killed_mid_run": False, "ok": False}
                ok = False
                continue
            out_path = os.path.join(tmp, f"resume_{variant}.json")
            r = child(["--transport", transport, "--ckpt-dir", ckpt,
                       "--resume", "--out", out_path, *cache_args])
            if r.returncode != 0:
                print(r.stdout + r.stderr)
                kills[variant] = {"killed_mid_run": True, "ok": False,
                                  "error": "resume child failed"}
                ok = False
                continue
            res = load(out_path)
            t_ok = (trajectories_equal(base, res)
                    and not res["recovered_done"])
            kills[variant] = {
                "killed_mid_run": True,
                "recovered_done": res["recovered_done"],
                "replayed": res["replayed"],
                "resumed_leases": res["pool"]["resumed_leases"],
                "trajectory_equal": trajectories_equal(base, res),
                "ok": t_ok,
            }
            if cache_args:
                # the §10 warm-restore gate: the store survived the kill
                # and the restored process actually served from it
                warm = (res["cache"] is not None
                        and res["cache"]["hits"] > 0
                        and res["cache"]["store_size"] > 0)
                kills[variant]["cache"] = res["cache"]
                kills[variant]["warm_after_restore"] = warm
                kills[variant]["ok"] = t_ok = t_ok and warm
            ok = ok and t_ok
        report.update({
            "baseline": {"iterations": base["iteration"],
                         "best": base["best_fitness"],
                         "messages": base["pool"]["messages"],
                         "registry": base["registry"]},
            "backend_parity_ok": backend_ok,
            "production_mesh_parity_ok": mesh_ok,
            "kill_restore": kills,
        })
        ok = ok and backend_ok and mesh_ok
    except Exception as e:  # noqa: BLE001 — smoke must report, not die
        report["error"] = str(e)
        ok = False
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    report["parity_ok"] = ok
    path = os.path.join(out_dir, "substrate_server.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    kr = report.get("kill_restore", {})
    print(f"[{'ok' if ok else 'FAIL'}] substrate server: "
          f"backend_parity={report.get('backend_parity_ok')} "
          f"mesh_parity={report.get('production_mesh_parity_ok')} "
          f"loopback_kill={kr.get('loopback', {}).get('ok')} "
          f"tcp_kill={kr.get('tcp', {}).get('ok')} "
          f"cache_kill={kr.get('loopback_cache', {}).get('ok')} "
          f"warm={kr.get('loopback_cache', {}).get('warm_after_restore')} "
          f"-> {path}")
    return ok


def run_chaos_server_smoke(out_dir: str, n_hosts: int = 48, m: int = 12,
                           iterations: int = 3, n_stars: int = 200,
                           n_clients: int = 8) -> bool:
    """Chaos-hardened work-service smoke (``--substrate chaos_server``,
    DESIGN.md §12).

    The seeded smoke search runs once serially on loopback with no faults
    (the baseline), then repeatedly as ``n_clients`` truly concurrent TCP
    clients — clean, and under each of three seeded ``FaultPlan`` presets
    (drops + duplication, reordering delay, resets + torn writes) — every
    time in a clean single-device CPU subprocess.  The hard gate is the
    tentpole contract: bit-identical committed iterates and identical
    final engine stats vs the fault-free serial baseline, with the fault
    counters proving the schedule actually injected.  Two more legs:

      * a SIGKILL mid-chaos (concurrent TCP + reset_torn), restored from
        snapshot + replay log and run to completion → must equal the
        baseline;
      * an in-parent concurrent+chaos run evaluating through the REAL
        16×16 production-mesh backend on the forced 512-device platform —
        fault tolerance and the production partitioning composed.

    Writes artifacts/dryrun/substrate_chaos_server.json; returns pass/fail.
    """
    import shutil
    import signal
    import subprocess
    import sys
    import tempfile

    child_env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    child_env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           ".."))
    child_env["PYTHONPATH"] = src_dir + (
        ":" + child_env["PYTHONPATH"] if child_env.get("PYTHONPATH") else "")
    spec_args = ["--n-hosts", str(n_hosts), "--m", str(m),
                 "--iterations", str(iterations), "--n-stars", str(n_stars)]
    conc_args = ["--transport", "tcp", "--concurrent", str(n_clients)]

    def child(extra, timeout=600):
        cmd = [sys.executable, "-m", "repro.server.sim"] + spec_args + extra
        return subprocess.run(cmd, env=child_env, timeout=timeout,
                              capture_output=True, text=True)

    def load(path):
        with open(path) as f:
            return json.load(f)

    def trajectories_equal(a, b):
        return (a["history"] == b["history"]
                and a["iteration"] == b["iteration"]
                and a["best_fitness"] == b["best_fitness"]
                and a["engine_stats"] == b["engine_stats"])

    tmp = tempfile.mkdtemp(prefix="chaos_smoke_")
    report = {"n_hosts": n_hosts, "m": m, "iterations": iterations,
              "n_clients": n_clients}
    ok = True
    try:
        base_path = os.path.join(tmp, "base.json")
        r = child(["--out", base_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("serial baseline child failed")
        base = load(base_path)

        # clean concurrency first: the intake + release machinery alone
        clean_path = os.path.join(tmp, "concurrent.json")
        r = child([*conc_args, "--out", clean_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("concurrent clean child failed")
        clean = load(clean_path)
        concurrent_ok = (trajectories_equal(base, clean)
                         and clean["intake"]["parked"] > 0)
        report["concurrent_clean"] = {
            "trajectory_equal": trajectories_equal(base, clean),
            "intake": clean["intake"], "ok": concurrent_ok}

        # the three seeded fault schedules
        plans = {}
        for preset in ("drop_dup", "reorder_delay", "reset_torn"):
            p_path = os.path.join(tmp, f"{preset}.json")
            r = child([*conc_args, "--chaos", preset, "--out", p_path])
            if r.returncode != 0:
                print(r.stdout + r.stderr)
                plans[preset] = {"ok": False, "error": "child failed"}
                ok = False
                continue
            doc = load(p_path)
            ch = doc["chaos"]
            injected = (ch["drops_request"] + ch["drops_reply"]
                        + ch["duplicates"] + ch["delays"] + ch["resets"]
                        + ch["torn_writes"])
            p_ok = trajectories_equal(base, doc) and injected > 0
            plans[preset] = {
                "trajectory_equal": trajectories_equal(base, doc),
                "faults_injected": injected,
                "chaos": {k: v for k, v in ch.items() if k != "plan"},
                "ok": p_ok}
            ok = ok and p_ok
        report["fault_plans"] = plans

        # SIGKILL mid-chaos + restore under the same plan
        ckpt = os.path.join(tmp, "ckpt_chaos")
        kill_args = [*conc_args, "--chaos", "reset_torn", "--ckpt-dir",
                     ckpt, "--snapshot-every", "150", "--throttle-s",
                     "0.002"]
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.server.sim", *spec_args,
             *kill_args],
            env=child_env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        log_path = os.path.join(ckpt, "replay.jsonl")
        deadline = time.time() + 300
        killed_mid_run = False
        kill_after = max(150, int(0.4 * base["pool"]["messages"]))
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            has_snap = os.path.isdir(ckpt) and any(
                f.startswith("snapshot_") for f in os.listdir(ckpt))
            log_lines = 0
            if os.path.exists(log_path):
                with open(log_path, "rb") as f:
                    log_lines = f.read().count(b"\n")
            if has_snap and log_lines >= kill_after:
                proc.send_signal(signal.SIGKILL)
                proc.wait(timeout=30)
                killed_mid_run = True
                break
            time.sleep(0.02)
        if not killed_mid_run:
            proc.kill()
            report["kill_restore"] = {"killed_mid_run": False, "ok": False}
            ok = False
        else:
            out_path = os.path.join(tmp, "resume_chaos.json")
            r = child([*kill_args, "--resume", "--out", out_path])
            if r.returncode != 0:
                print(r.stdout + r.stderr)
                report["kill_restore"] = {"killed_mid_run": True,
                                          "ok": False,
                                          "error": "resume child failed"}
                ok = False
            else:
                res = load(out_path)
                k_ok = (trajectories_equal(base, res)
                        and not res["recovered_done"])
                report["kill_restore"] = {
                    "killed_mid_run": True,
                    "recovered_done": res["recovered_done"],
                    "replayed": res["replayed"],
                    "resumed_leases": res["pool"]["resumed_leases"],
                    "trajectory_equal": trajectories_equal(base, res),
                    "ok": k_ok}
                ok = ok and k_ok

        # in-parent: concurrent + chaos over the REAL production mesh on
        # the forced 512-device platform
        from repro.core.substrates.pod_mesh import PodMeshEvalBackend
        from repro.server.sim import (ServerSubstrate, result_doc,
                                      smoke_problem)
        spec, fleet, f_batch = smoke_problem(
            n_stars=n_stars, n_hosts=n_hosts, m=m, iterations=iterations)
        mesh_backend = PodMeshEvalBackend(f_batch,
                                          mesh=make_production_mesh())
        mesh_doc = result_doc(ServerSubstrate(
            spec, fleet, mesh_backend, transport="tcp",
            concurrent=n_clients, chaos="drop_dup").run())
        mesh_ok = trajectories_equal(base, mesh_doc)
        report["production_mesh_chaos"] = {
            "trajectory_equal": mesh_ok,
            "chaos": {k: v for k, v in mesh_doc["chaos"].items()
                      if k != "plan"},
            "ok": mesh_ok}
        ok = ok and concurrent_ok and mesh_ok
    except Exception as e:  # noqa: BLE001 — smoke must report, not die
        report["error"] = str(e)
        ok = False
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    report["parity_ok"] = ok
    path = os.path.join(out_dir, "substrate_chaos_server.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    fp = report.get("fault_plans", {})
    print(f"[{'ok' if ok else 'FAIL'}] substrate chaos_server: "
          f"concurrent={report.get('concurrent_clean', {}).get('ok')} "
          f"drop_dup={fp.get('drop_dup', {}).get('ok')} "
          f"reorder={fp.get('reorder_delay', {}).get('ok')} "
          f"reset_torn={fp.get('reset_torn', {}).get('ok')} "
          f"kill={report.get('kill_restore', {}).get('ok')} "
          f"mesh={report.get('production_mesh_chaos', {}).get('ok')} "
          f"-> {path}")
    return ok


def run_obs_server_smoke(out_dir: str, n_hosts: int = 48, m: int = 12,
                         iterations: int = 3, n_stars: int = 200,
                         n_clients: int = 8) -> bool:
    """Observability-plane smoke (``--substrate obs_server``, DESIGN.md
    §13).

    Every leg shares one injected fleet failure — a quarter of the host
    ids go silent at virtual time 150 — so the anomaly machinery always
    has churn to see, and every parity pair lives in the same world:

      1. the UNOBSERVED serial loopback baseline;
      2. observed live: metrics hub + ``n_clients`` truly concurrent TCP
         clients + a real background ``subscribe_stats`` subscriber
         polling over its own socket during the run → bit-identical to 1,
         and the subscriber must have received ≥ 2 stamped snapshots with
         strictly increasing seqs;
      3. observed under chaos (``drop_dup`` fault plan, concurrent TCP) →
         bit-identical to 1 with faults provably injected (monitoring
         traffic bypasses the injector, so the fault schedule — keyed on
         stamped client messages — is unchanged);
      4. observed + subscribed, SIGKILLed mid-stream on loopback,
         restored from snapshot + replay log with obs re-attached →
         bit-identical to 1 (the hub owns no replayable state);
      5. anomaly defense live: detectors quarantine the silenced cohort
         out of the registry's reliable set (measurably smaller than the
         undefended baseline's), recording the verdict schedule — then a
         REPLAY run applies the recorded schedule with detectors off and
         must reproduce the defended trajectory bit-for-bit.

    Writes artifacts/dryrun/substrate_obs_server.json; returns pass/fail.
    """
    import shutil
    import signal
    import subprocess
    import sys
    import tempfile

    child_env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    child_env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           ".."))
    child_env["PYTHONPATH"] = src_dir + (
        ":" + child_env["PYTHONPATH"] if child_env.get("PYTHONPATH") else "")
    spec_args = ["--n-hosts", str(n_hosts), "--m", str(m),
                 "--iterations", str(iterations), "--n-stars", str(n_stars),
                 "--silence-at", "150", "--silence-frac", "0.25"]
    obs_args = ["--obs", "--stats-interval", "10"]
    conc_args = ["--transport", "tcp", "--concurrent", str(n_clients)]

    def child(extra, timeout=600):
        cmd = [sys.executable, "-m", "repro.server.sim"] + spec_args + extra
        return subprocess.run(cmd, env=child_env, timeout=timeout,
                              capture_output=True, text=True)

    def load(path):
        with open(path) as f:
            return json.load(f)

    def trajectories_equal(a, b):
        return (a["history"] == b["history"]
                and a["iteration"] == b["iteration"]
                and a["best_fitness"] == b["best_fitness"]
                and a["engine_stats"] == b["engine_stats"])

    tmp = tempfile.mkdtemp(prefix="obs_smoke_")
    report = {"n_hosts": n_hosts, "m": m, "iterations": iterations,
              "n_clients": n_clients, "silence_at": 150.0,
              "silence_frac": 0.25}
    ok = True
    try:
        # 1: the unobserved baseline (same silenced world as every leg)
        base_path = os.path.join(tmp, "base.json")
        r = child(["--out", base_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("unobserved baseline child failed")
        base = load(base_path)

        # 2: observed + live TCP subscriber + concurrent clients
        live_path = os.path.join(tmp, "observed.json")
        r = child([*conc_args, *obs_args, "--subscribe", "--out",
                   live_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("observed child failed")
        live = load(live_path)
        sub = live["subscriber"]
        live_ok = (trajectories_equal(base, live)
                   and live["obs"]["snapshots"] >= 2
                   and sub["snapshots"] >= 2 and sub["stamped_ok"]
                   and not sub["errors"])
        report["observed_live"] = {
            "trajectory_equal": trajectories_equal(base, live),
            "hub_snapshots": live["obs"]["snapshots"],
            "subscriber": sub, "ok": live_ok}
        ok = ok and live_ok

        # 3: observed under an injected fault schedule
        chaos_path = os.path.join(tmp, "observed_chaos.json")
        r = child([*conc_args, *obs_args, "--chaos", "drop_dup", "--out",
                   chaos_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("observed chaos child failed")
        cdoc = load(chaos_path)
        ch = cdoc["chaos"]
        injected = (ch["drops_request"] + ch["drops_reply"]
                    + ch["duplicates"] + ch["delays"] + ch["resets"]
                    + ch["torn_writes"])
        chaos_ok = trajectories_equal(base, cdoc) and injected > 0
        report["observed_chaos"] = {
            "trajectory_equal": trajectories_equal(base, cdoc),
            "faults_injected": injected, "ok": chaos_ok}
        ok = ok and chaos_ok

        # 4: SIGKILL mid-stream, restore with obs re-attached
        ckpt = os.path.join(tmp, "ckpt_obs")
        kill_args = [*obs_args, "--subscribe", "--ckpt-dir", ckpt,
                     "--snapshot-every", "150", "--throttle-s", "0.002"]
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.server.sim", *spec_args,
             *kill_args],
            env=child_env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        log_path = os.path.join(ckpt, "replay.jsonl")
        deadline = time.time() + 300
        killed_mid_run = False
        kill_after = max(150, int(0.4 * base["pool"]["messages"]))
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            has_snap = os.path.isdir(ckpt) and any(
                f.startswith("snapshot_") for f in os.listdir(ckpt))
            log_lines = 0
            if os.path.exists(log_path):
                with open(log_path, "rb") as f:
                    log_lines = f.read().count(b"\n")
            if has_snap and log_lines >= kill_after:
                proc.send_signal(signal.SIGKILL)
                proc.wait(timeout=30)
                killed_mid_run = True
                break
            time.sleep(0.02)
        if not killed_mid_run:
            proc.kill()
            report["kill_restore"] = {"killed_mid_run": False, "ok": False}
            ok = False
        else:
            out_path = os.path.join(tmp, "resume_obs.json")
            r = child([*kill_args, "--resume", "--out", out_path])
            if r.returncode != 0:
                print(r.stdout + r.stderr)
                report["kill_restore"] = {"killed_mid_run": True,
                                          "ok": False,
                                          "error": "resume child failed"}
                ok = False
            else:
                res = load(out_path)
                k_ok = (trajectories_equal(base, res)
                        and not res["recovered_done"])
                report["kill_restore"] = {
                    "killed_mid_run": True,
                    "recovered_done": res["recovered_done"],
                    "replayed": res["replayed"],
                    "hub_snapshots": res["obs"]["snapshots"],
                    "trajectory_equal": trajectories_equal(base, res),
                    "ok": k_ok}
                ok = ok and k_ok

        # 5: live defense records its schedule; a replay reproduces it
        sched_path = os.path.join(tmp, "schedule.json")
        def_path = os.path.join(tmp, "defended.json")
        r = child([*obs_args, "--defense", "--defense-out", sched_path,
                   "--out", def_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("defense child failed")
        defended = load(def_path)
        d = defended["defense"]
        shrunk = (defended["registry"]["reliable_set"]
                  < base["registry"]["reliable_set"])
        rep_path = os.path.join(tmp, "replayed.json")
        r = child([*obs_args, "--defense-replay", sched_path, "--out",
                   rep_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("defense replay child failed")
        replayed = load(rep_path)
        defense_ok = (d["quarantined_now"] > 0 and shrunk
                      and trajectories_equal(defended, replayed)
                      and replayed["defense"]["mode"] == "replay"
                      and replayed["defense"]["quarantined_now"]
                      == d["quarantined_now"])
        report["defense"] = {
            "events": d["events"], "by_action": d["by_action"],
            "quarantined_now": d["quarantined_now"],
            "reliable_set_defended": defended["registry"]["reliable_set"],
            "reliable_set_undefended": base["registry"]["reliable_set"],
            "reliable_set_shrunk": shrunk,
            "replay_trajectory_equal": trajectories_equal(defended,
                                                          replayed),
            "ok": defense_ok}
        ok = ok and defense_ok
    except Exception as e:  # noqa: BLE001 — smoke must report, not die
        report["error"] = str(e)
        ok = False
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    report["parity_ok"] = ok
    path = os.path.join(out_dir, "substrate_obs_server.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[{'ok' if ok else 'FAIL'}] substrate obs_server: "
          f"live={report.get('observed_live', {}).get('ok')} "
          f"chaos={report.get('observed_chaos', {}).get('ok')} "
          f"kill={report.get('kill_restore', {}).get('ok')} "
          f"defense={report.get('defense', {}).get('ok')} "
          f"-> {path}")
    return ok


def run_postmortem_smoke(out_dir: str, n_hosts: int = 48, m: int = 12,
                         iterations: int = 3, n_stars: int = 200,
                         n_clients: int = 8) -> bool:
    """Post-mortem-plane smoke (``--substrate postmortem``, DESIGN.md
    §14).  Same silenced smoke world as the obs_server smoke; four legs:

      1. the UNOBSERVED serial loopback baseline;
      2. retention byte-compatibility: two checkpointed runs — retention
         plus full tracing ON vs OFF — must write byte-identical replay
         logs (the §14 recovery-compatibility argument) and both match
         the baseline trajectory;
      3. flight recorder under fire: chaotic concurrent TCP with
         retention + tracing, SIGKILLed mid-run.  The CLI
         (``repro.launch.obs_postmortem``) must reconstruct the dead
         server's timeline from the surviving store (epoch 1: snapshots,
         spans, phase transitions, replay-log extent) WITHOUT writing an
         epoch marker; the restored run then appends under epoch 2 and
         its trajectory is bit-identical to the baseline;
      4. windowed stall defense: ``--stall-window`` kills the stalled
         search through the director seam, the verdict is recorded in
         the anomaly schedule, and a REPLAY run applies the recorded
         kill at the recorded seq — bit-identical to the defended run
         (which, having been truncated by the kill, differs from the
         undefended baseline).

    Writes artifacts/dryrun/substrate_postmortem.json; returns pass/fail.
    """
    import shutil
    import signal
    import subprocess
    import sys
    import tempfile

    child_env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    child_env["JAX_PLATFORMS"] = "cpu"
    src_dir = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                           ".."))
    child_env["PYTHONPATH"] = src_dir + (
        ":" + child_env["PYTHONPATH"] if child_env.get("PYTHONPATH") else "")
    spec_args = ["--n-hosts", str(n_hosts), "--m", str(m),
                 "--iterations", str(iterations), "--n-stars", str(n_stars),
                 "--silence-at", "150", "--silence-frac", "0.25"]
    retain_args = ["--retain", "--trace-rate", "1.0",
                   "--stats-interval", "10"]
    conc_args = ["--transport", "tcp", "--concurrent", str(n_clients)]

    def child(extra, timeout=600, module="repro.server.sim"):
        cmd = [sys.executable, "-m", module] + extra
        return subprocess.run(cmd, env=child_env, timeout=timeout,
                              capture_output=True, text=True)

    def load(path):
        with open(path) as f:
            return json.load(f)

    def trajectories_equal(a, b):
        return (a["history"] == b["history"]
                and a["iteration"] == b["iteration"]
                and a["best_fitness"] == b["best_fitness"]
                and a["engine_stats"] == b["engine_stats"])

    tmp = tempfile.mkdtemp(prefix="postmortem_smoke_")
    report = {"n_hosts": n_hosts, "m": m, "iterations": iterations,
              "n_clients": n_clients, "silence_at": 150.0,
              "silence_frac": 0.25}
    ok = True
    try:
        # 1: the unobserved baseline
        base_path = os.path.join(tmp, "base.json")
        r = child([*spec_args, "--out", base_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("unobserved baseline child failed")
        base = load(base_path)

        # 2: replay logs byte-compatible with retention on/off
        ck_off = os.path.join(tmp, "ck_off")
        ck_on = os.path.join(tmp, "ck_on")
        off_path = os.path.join(tmp, "retain_off.json")
        on_path = os.path.join(tmp, "retain_on.json")
        r = child([*spec_args, "--ckpt-dir", ck_off, "--snapshot-every",
                   "150", "--out", off_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("retention-off child failed")
        r = child([*spec_args, "--ckpt-dir", ck_on, "--snapshot-every",
                   "150", *retain_args, "--out", on_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("retention-on child failed")
        with open(os.path.join(ck_off, "replay.jsonl"), "rb") as f:
            log_off = f.read()
        with open(os.path.join(ck_on, "replay.jsonl"), "rb") as f:
            log_on = f.read()
        on_doc = load(on_path)
        bytes_ok = (log_off == log_on and len(log_off) > 0
                    and trajectories_equal(base, load(off_path))
                    and trajectories_equal(base, on_doc)
                    and on_doc["retention"]["snapshots_stored"] > 0
                    and on_doc["retention"]["spans_stored"] > 0)
        report["replay_log_byte_compat"] = {
            "bytes": len(log_off), "identical": log_off == log_on,
            "retention": on_doc["retention"], "trace": on_doc["trace"],
            "ok": bytes_ok}
        ok = ok and bytes_ok

        # 3: chaotic TCP + retention + tracing, SIGKILL, reconstruct,
        # restore under a new epoch
        ckpt = os.path.join(tmp, "ckpt_pm")
        kill_args = [*spec_args, *conc_args, "--chaos", "drop_dup",
                     *retain_args, "--ckpt-dir", ckpt,
                     "--snapshot-every", "150", "--throttle-s", "0.002"]
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.server.sim", *kill_args],
            env=child_env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        log_path = os.path.join(ckpt, "replay.jsonl")
        deadline = time.time() + 300
        killed_mid_run = False
        kill_after = max(150, int(0.4 * base["pool"]["messages"]))
        while time.time() < deadline:
            if proc.poll() is not None:
                break
            has_snap = os.path.isdir(ckpt) and any(
                f.startswith("snapshot_") for f in os.listdir(ckpt))
            log_lines = 0
            if os.path.exists(log_path):
                with open(log_path, "rb") as f:
                    log_lines = f.read().count(b"\n")
            if has_snap and log_lines >= kill_after:
                proc.send_signal(signal.SIGKILL)
                proc.wait(timeout=30)
                killed_mid_run = True
                break
            time.sleep(0.02)
        if not killed_mid_run:
            proc.kill()
            report["flight_recorder"] = {"killed_mid_run": False,
                                         "ok": False}
            ok = False
        else:
            # the CLI reconstructs the DEAD run's timeline, read-only
            pm_dead = os.path.join(tmp, "pm_dead.json")
            r = child(["--ckpt-dir", ckpt, "--json", "--out", pm_dead],
                      module="repro.launch.obs_postmortem")
            if r.returncode != 0:
                print(r.stdout + r.stderr)
                raise RuntimeError("postmortem CLI failed on dead store")
            dead = load(pm_dead)
            dead_ok = (dead["store"]["epochs"] == [1]
                       and dead["store"]["records"] > 0
                       and dead["spans"] > 0
                       and len(dead["phases"]) > 0
                       and dead["replay_log"]["records"] >= kill_after)
            out_path = os.path.join(tmp, "resume_pm.json")
            r = child([*kill_args, "--resume", "--out", out_path])
            if r.returncode != 0:
                print(r.stdout + r.stderr)
                report["flight_recorder"] = {"killed_mid_run": True,
                                             "dead_report_ok": dead_ok,
                                             "ok": False,
                                             "error": "resume failed"}
                ok = False
            else:
                res = load(out_path)
                pm_post = os.path.join(tmp, "pm_post.json")
                r = child(["--ckpt-dir", ckpt, "--json", "--out", pm_post],
                          module="repro.launch.obs_postmortem")
                post = load(pm_post) if r.returncode == 0 else {}
                # the read-only CLI added no epoch; the restored server
                # appended under epoch 2
                epochs_ok = post.get("store", {}).get("epochs") == [1, 2]
                k_ok = (dead_ok and epochs_ok
                        and trajectories_equal(base, res)
                        and not res["recovered_done"])
                report["flight_recorder"] = {
                    "killed_mid_run": True,
                    "dead_epochs": dead["store"]["epochs"],
                    "dead_snapshots": dead["store"]["by_type"].get("snap"),
                    "dead_spans": dead["spans"],
                    "dead_phase_transitions": len(dead["phases"]),
                    "replay_log_records": dead["replay_log"]["records"],
                    "post_restore_epochs":
                        post.get("store", {}).get("epochs"),
                    "replayed": res["replayed"],
                    "trajectory_equal": trajectories_equal(base, res),
                    "ok": k_ok}
                ok = ok and k_ok

        # 4: stall-window kill recorded live, replayed bit-identically
        sched_path = os.path.join(tmp, "stall_schedule.json")
        def_path = os.path.join(tmp, "stalled.json")
        stall_args = ["--stats-interval", "10", "--stall-window", "3"]
        r = child([*spec_args, *stall_args, "--defense-out", sched_path,
                   "--out", def_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("stall-defense child failed")
        defended = load(def_path)
        d = defended["defense"]
        rep_path = os.path.join(tmp, "stall_replayed.json")
        r = child([*spec_args, "--stats-interval", "10",
                   "--defense-replay", sched_path, "--out", rep_path])
        if r.returncode != 0:
            print(r.stdout + r.stderr)
            raise RuntimeError("stall-replay child failed")
        replayed = load(rep_path)
        stall_ok = (d["searches_killed"] == [0]
                    and d["by_action"].get("kill_search", 0) >= 1
                    and trajectories_equal(defended, replayed)
                    and replayed["defense"]["searches_killed"] == [0]
                    and replayed["defense"]["mode"] == "replay"
                    and defended["iteration"] < base["iteration"])
        report["stall_kill"] = {
            "searches_killed": d["searches_killed"],
            "by_action": d["by_action"],
            "defended_iteration": defended["iteration"],
            "baseline_iteration": base["iteration"],
            "replay_trajectory_equal": trajectories_equal(defended,
                                                          replayed),
            "ok": stall_ok}
        ok = ok and stall_ok
    except Exception as e:  # noqa: BLE001 — smoke must report, not die
        report["error"] = str(e)
        ok = False
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    report["parity_ok"] = ok
    path = os.path.join(out_dir, "substrate_postmortem.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[{'ok' if ok else 'FAIL'}] substrate postmortem: "
          f"bytes={report.get('replay_log_byte_compat', {}).get('ok')} "
          f"recorder={report.get('flight_recorder', {}).get('ok')} "
          f"stall={report.get('stall_kill', {}).get('ok')} "
          f"-> {path}")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true",
                    help="use the absorbed MLA decode path (perf variant)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=["global", "grouped"],
                    help="override MoE dispatch strategy (perf variant)")
    ap.add_argument("--remat-policy", default=None, choices=["full", "dots"],
                    help="override remat policy (perf variant)")
    ap.add_argument("--pin-proj", action="store_true",
                    help="force bf16 TP all-reduces (perf variant)")
    ap.add_argument("--moe-cf", type=float, default=None,
                    help="override MoE capacity factor (perf variant)")
    ap.add_argument("--donate", action="store_true",
                    help="donate params/opt (train) or cache (decode)")
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP/ZeRO-3 param+optimizer storage sharding")
    ap.add_argument("--quant-cache", action="store_true",
                    help="int8 KV/latent cache (perf variant)")
    ap.add_argument("--suffix", default="", help="artifact filename suffix")
    # choices come from the ONE substrate registry (launch/substrates.py):
    # an unknown substrate fails at parse time instead of falling through
    # to the model-cell path
    ap.add_argument("--substrate", default=None,
                    choices=sorted(SUBSTRATES),
                    help="run the substrate smoke instead of model cells")
    ap.add_argument("--list-substrates", action="store_true",
                    help="print the registered substrate smokes and exit")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.list_substrates:
        print(list_substrates())
        raise SystemExit(0)

    out_dir = args.out or os.path.abspath(ARTIFACTS)
    os.makedirs(out_dir, exist_ok=True)

    if args.substrate is not None:
        runner = SUBSTRATES[args.substrate].resolve()
        raise SystemExit(0 if runner(out_dir) else 1)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                cfg_override = None
                if args.moe_dispatch or args.remat_policy or args.pin_proj \
                        or args.moe_cf or args.quant_cache:
                    import dataclasses as _dc
                    cfg_override = get_config(arch)
                    if args.moe_dispatch and cfg_override.moe is not None:
                        cfg_override = _dc.replace(
                            cfg_override,
                            moe=_dc.replace(cfg_override.moe,
                                            dispatch=args.moe_dispatch))
                    if args.remat_policy:
                        cfg_override = _dc.replace(
                            cfg_override, remat_policy=args.remat_policy)
                    if args.pin_proj:
                        cfg_override = _dc.replace(
                            cfg_override, pin_proj_outputs=True)
                    if args.moe_cf and cfg_override.moe is not None:
                        cfg_override = _dc.replace(
                            cfg_override,
                            moe=_dc.replace(cfg_override.moe,
                                            capacity_factor=args.moe_cf))
                    if args.quant_cache:
                        cfg_override = _dc.replace(
                            cfg_override, quantized_cache=True)
                ok = run_cell(arch, shape_name, mp, out_dir,
                              skip_existing=args.skip_existing,
                              mla_absorb=args.mla_absorb, suffix=args.suffix,
                              cfg_override=cfg_override, donate=args.donate,
                              fsdp=args.fsdp)
                failures += 0 if ok else 1
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
