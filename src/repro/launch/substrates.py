"""THE substrate-smoke registry: one dict, every consumer derives from it.

``repro.launch.dryrun --substrate X`` used to hardcode its choices and an
unknown name fell through to the model-cell path late; now argparse
``choices`` come from this dict, ``--list-substrates`` prints it, and
``benchmarks/scalability.py`` validates its own ``--substrate`` filter
against the same keys — so adding a substrate smoke is ONE entry here.

Import-side-effect free on purpose: ``repro.launch.dryrun`` forces a
512-device host platform at import time, so runners are referenced by
dotted path and resolved lazily — a benchmark importing this module for
the names must never accidentally reconfigure its own jax platform.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict


@dataclasses.dataclass(frozen=True)
class SubstrateSmoke:
    name: str
    description: str
    runner: str                       # "module:function", resolved lazily

    def resolve(self) -> Callable:
        mod, fn = self.runner.split(":")
        return getattr(importlib.import_module(mod), fn)


SUBSTRATES: Dict[str, SubstrateSmoke] = {
    "pod_mesh": SubstrateSmoke(
        "pod_mesh",
        "batched grid sync + pipelined + shard_map pod-mesh backend on the "
        "forced 512-device mesh; bit-identical iterates across all three",
        "repro.launch.dryrun:run_substrate_smoke"),
    "multi_search": SubstrateSmoke(
        "multi_search",
        "coalesced multi-search portfolio over one shared backend, "
        "in-process AND pod mesh; every search bit-identical to its solo "
        "run",
        "repro.launch.dryrun:run_multi_search_smoke"),
    "cached_portfolio": SubstrateSmoke(
        "cached_portfolio",
        "persistent eval cache under a coalesced portfolio, in-process "
        "AND pod mesh: cache-on cold and warm runs bit-identical to "
        "cache-off, warm rerun fully served (zero new misses)",
        "repro.launch.dryrun:run_cached_portfolio_smoke"),
    "lm_subspace": SubstrateSmoke(
        "lm_subspace",
        "LM-loss workload: the models/ stack as the fitness function, "
        "parameters perturbed along a shared subspace basis; sync + "
        "pipelined + model/data-sharded pod backend bit-identical, same "
        "backend under the coalescing orchestrator and the work server",
        "repro.launch.dryrun:run_lm_subspace_smoke"),
    "server": SubstrateSmoke(
        "server",
        "fault-tolerant work server: seeded search over loopback and TCP "
        "transports, SIGKILLed mid-search and restored from snapshot + "
        "replay log; restored run bit-identical to uninterrupted",
        "repro.launch.dryrun:run_server_smoke"),
    "chaos_server": SubstrateSmoke(
        "chaos_server",
        "chaos-hardened work service: concurrent TCP clients behind the "
        "sequenced intake under seeded fault plans (drops, duplicates, "
        "delays, resets, torn writes) incl. SIGKILL mid-chaos restore "
        "and the production-mesh backend; every run bit-identical to the "
        "fault-free serial baseline",
        "repro.launch.dryrun:run_chaos_server_smoke"),
    "obs_server": SubstrateSmoke(
        "obs_server",
        "live observability plane: metrics hub + subscribe_stats stream "
        "over concurrent TCP (live subscriber), under chaos, and through "
        "a SIGKILL restore — all bit-identical to the unobserved "
        "baseline; injected fleet silence paged out by the anomaly "
        "defense, replayed bit-identically from its recorded schedule",
        "repro.launch.dryrun:run_obs_server_smoke"),
    "postmortem": SubstrateSmoke(
        "postmortem",
        "flight recorder: durable snapshot/trace retention under chaotic "
        "concurrent TCP, SIGKILLed mid-run; the post-mortem CLI "
        "reconstructs the dead server's timeline read-only, the restored "
        "run appends under a new epoch bit-identically, replay logs stay "
        "byte-compatible with retention on/off, and a recorded stall-kill "
        "schedule replays bit-identically through the director seam",
        "repro.launch.dryrun:run_postmortem_smoke"),
}


def list_substrates() -> str:
    width = max(len(n) for n in SUBSTRATES)
    return "\n".join(f"{s.name:<{width}}  {s.description}"
                     for s in SUBSTRATES.values())
