"""End-to-end training driver.

Runs reduced/"100M" configs on CPU (the same code paths pjit onto pods):
synthetic data pipeline, AdamW (optionally int8-compressed grads with error
feedback), checkpoint/restart (bitwise resume), and the paper's randomized
parallel line search as a first-class training option.

Examples:
    python -m repro.launch.train --preset lm-100m --steps 200
    python -m repro.launch.train --arch rwkv6-7b --steps 20          # smoke cfg
    python -m repro.launch.train --preset tiny --optimizer subspace-newton
    python -m repro.launch.train --preset tiny --steps 50 --crash-at 25 \
        --ckpt-dir /tmp/ck && python -m repro.launch.train --preset tiny \
        --steps 50 --ckpt-dir /tmp/ck --resume     # fault-tolerant restart
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import ARCH_NAMES, get_smoke_config
from repro.configs.base import ModelConfig
from repro.core.parallel_line_search import LineSearchConfig, randomized_line_search
from repro.core import subspace_newton as subn
from repro.data.pipeline import DataConfig, SyntheticLM, SyntheticMasked
from repro.models import (NULL_CTX, count_params, init_params, make_loss_fn,
                          make_train_step)
from repro.optim.adamw import AdamW
from repro.optim.compression import compress_grads, init_error_state

PRESETS = {
    "tiny": ModelConfig(name="tiny-lm", family="dense", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=512,
                        head_dim=16, remat=False),
    "lm-100m": ModelConfig(name="lm-100m", family="dense", n_layers=10,
                           d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
                           vocab_size=32000, head_dim=64, remat=False),
}


def build_config(args) -> ModelConfig:
    if args.preset:
        return PRESETS[args.preset]
    return get_smoke_config(args.arch)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES)
    ap.add_argument("--preset", default=None, choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "subspace-newton"])
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 + error-feedback gradient compression")
    ap.add_argument("--line-search", type=int, default=0,
                    help="p>0: randomized parallel line search every step")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a node failure at this step (exit 42)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-file", default=None)
    args = ap.parse_args(argv)

    if not args.preset and not args.arch:
        args.preset = "tiny"
    cfg = build_config(args)
    key = jax.random.key(args.seed)
    params = init_params(cfg, key)
    print(f"[train] config={cfg.name} params={count_params(params):,}")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    if cfg.frontend == "audio_stub":
        data = SyntheticMasked(dcfg, cfg.d_model)
    else:
        data = SyntheticLM(dcfg)

    opt = AdamW(lr=args.lr, weight_decay=0.01)
    opt_state = opt.init(params)
    err_state = init_error_state(params) if args.compress_grads else None
    loss_fn = make_loss_fn(cfg)
    start_step = 0

    if args.resume and args.ckpt_dir:
        tree = {"params": params, "opt": opt_state}
        if err_state is not None:
            tree["err"] = err_state
        tree, start_step, extras = ckpt.restore(args.ckpt_dir, tree)
        params, opt_state = tree["params"], tree["opt"]
        err_state = tree.get("err", err_state)
        print(f"[train] resumed from step {start_step}")

    if args.optimizer == "subspace-newton":
        sn_cfg = subn.SubspaceNewtonConfig(k=6, sample_scale=0.02)
        sn_state = subn.init_state(params)

        def sn_step(params, sn_state, batch, key):
            return subn.subspace_newton_step(
                lambda p: loss_fn(p, batch)[0], params, sn_state, sn_cfg, key)
        sn_step = jax.jit(sn_step)

    base_step = make_train_step(cfg, opt)

    def full_step(params, opt_state, err_state, batch, key):
        if args.compress_grads:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads, err_state = compress_grads(grads, err_state)
            params_new, opt_state = opt.update(grads, opt_state, params)
            metrics = dict(metrics, loss=loss)
        else:
            params_new, opt_state, metrics = base_step(params, opt_state, batch)
        if args.line_search > 0:
            update = jax.tree.map(lambda n, o: n.astype(jnp.float32)
                                  - o.astype(jnp.float32), params_new, params)
            params_new, alpha, ls_loss = randomized_line_search(
                lambda p: loss_fn(p, batch)[0], params, update, key,
                LineSearchConfig(p=args.line_search))
            metrics = dict(metrics, ls_alpha=alpha, ls_loss=ls_loss)
        return params_new, opt_state, err_state, metrics

    jit_step = jax.jit(full_step)

    logf = open(args.log_file, "a") if args.log_file else None
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        skey = jax.random.fold_in(jax.random.key(args.seed + 7), step)
        if args.optimizer == "subspace-newton":
            params, sn_state, info = sn_step(params, sn_state, batch, skey)
            metrics = {"loss": info["loss_after"], "alpha": info["alpha"]}
        else:
            params, opt_state, err_state, metrics = jit_step(
                params, opt_state, err_state, batch, skey)
        if args.crash_at and step + 1 == args.crash_at:
            # checkpoint written for every completed multiple of ckpt_every
            print(f"[train] simulated crash at step {step + 1}", flush=True)
            sys.exit(42)
        if (step + 1) % args.ckpt_every == 0 and args.ckpt_dir:
            tree = {"params": params, "opt": opt_state}
            if err_state is not None:
                tree["err"] = err_state
            ckpt.save(args.ckpt_dir, step + 1, tree,
                      extras={"config": cfg.name})
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            line = {"step": step + 1,
                    "loss": round(float(metrics["loss"]), 5),
                    "elapsed_s": round(time.time() - t0, 1)}
            if "ls_alpha" in metrics:
                line["ls_alpha"] = round(float(metrics["ls_alpha"]), 3)
            print(f"[train] {json.dumps(line)}", flush=True)
            if logf:
                logf.write(json.dumps(line) + "\n")
                logf.flush()
    if args.ckpt_dir:
        tree = {"params": params, "opt": opt_state}
        if err_state is not None:
            tree["err"] = err_state
        ckpt.save(args.ckpt_dir, args.steps, tree, extras={"config": cfg.name})
    print(f"[train] done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
