"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips, with the
"pod" axis acting as an outer data-parallel axis across the DCN/ICI boundary.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "launch via repro.launch.dryrun (it sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh():
    """Degenerate 1-device mesh for CPU tests/examples (axes preserved)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
