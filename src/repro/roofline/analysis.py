"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs            / (chips × PEAK_FLOPS)
    memory     = HLO_bytes_accessed   / (chips × HBM_BW)
    collective = collective_bytes     / (chips × ICI_BW)

Measured semantics: ``compiled.cost_analysis()`` on the SPMD-partitioned
module reports PER-DEVICE flops/bytes (verified: hlo_flops ≈ model_flops /
chips × remat factor), and the parsed HLO is the per-device program, so the
"/ chips" in the formulas above is already applied — we divide the per-device
quantities by ONE chip's peak numbers.

collective_bytes is parsed from the post-SPMD optimized HLO: we sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, counting all-reduce twice (ring RS+AG).

Caveat recorded in EXPERIMENTS.md: XLA:CPU fuses far less than XLA:TPU, so
``bytes accessed`` over-reports TPU HBM traffic by a large constant factor.
The memory term is therefore an upper bound; it is consistent ACROSS cells
and iterations, which is what the perf loop optimizes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple HLO shape string like
    '(bf16[16,128]{1,0}, f32[4]{0})' or 'bf16[16,128]{1,0}'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]
    top_ops: List[Tuple[str, int]]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes_from_hlo(hlo_text: str, top_k: int = 8) -> CollectiveStats:
    bytes_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    ops: List[Tuple[str, int]] = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-defining lines look like: '%name = SHAPE op-name(...)'
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[\w\[\],{}\s/]*\)?)\s+([\w\-]+)", ls)
        if not m:
            continue
        opname = m.group(2)
        kind = next((k for k in _COLLECTIVES if opname == k or
                     opname.startswith(k + ".") or opname == k + "-start"), None)
        if kind is None:
            continue
        b = _shape_bytes(m.group(1))
        if kind == "all-reduce":
            b *= 2                      # ring: reduce-scatter + all-gather
        bytes_by_kind[kind] += b
        count_by_kind[kind] += 1
        ops.append((ls[:120], b))
    ops.sort(key=lambda t: -t[1])
    return CollectiveStats(bytes_by_kind, count_by_kind, ops[:top_k])


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, n_chips: int) -> Dict[str, float]:
    """Inputs are per-device (see module docstring); n_chips recorded only."""
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    bound = max(compute, memory, collective)
    terms["step_time_lower_bound_s"] = bound
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward
    (N = active params, D = tokens processed)."""
    n_active = cfg.n_active_params()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
