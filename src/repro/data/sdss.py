"""Synthetic SDSS-like star catalogs + the 8-parameter stream MLE (paper §VI).

The paper fits a Sagittarius tidal-stream model plus a Milky Way background
to 92k–112k stars from SDSS stripes.  We reproduce the *shape* of that
optimization problem in JAX: an 8-parameter mixture likelihood over a 3-D
star catalog —

    params = [eps, cx, cy, cz, theta, phi, sigma, q]
      eps            — logit of the stream mixing fraction
      (cx, cy, cz)   — a point on the stream axis
      (theta, phi)   — stream axis orientation
      sigma          — stream (Gaussian tube) width, log-scale
      q              — background halo flattening

    pdf = (1-w)·bg(x; q)/Z_bg + w·stream(x; c, axis, sigma)/Z_stream

Normalization constants are Monte-Carlo quadratures over the survey wedge
with a quadrature set fixed per dataset, so the likelihood is smooth and
deterministic.  Two datasets ("stripe79", "stripe86") mirror the paper's two
test stripes: different truths, sizes, and seeds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

N_PARAMS = 8
# search-space bounds (paper: user-specified b_min/b_max)
LO = np.array([-6.0, -4.0, -4.0, -4.0, 0.0, -3.2, -3.0, 0.3], np.float32)
HI = np.array([2.0, 4.0, 4.0, 4.0, 3.2, 3.2, 1.0, 1.6], np.float32)
DEFAULT_STEP = 0.1 * (HI - LO)

WEDGE_LO = np.array([-5.0, -5.0, -5.0], np.float32)
WEDGE_HI = np.array([5.0, 5.0, 5.0], np.float32)


@dataclasses.dataclass(frozen=True)
class Stripe:
    name: str
    stars: np.ndarray          # (n_stars, 3)
    quad: np.ndarray           # (n_quad, 3) fixed quadrature points
    truth: np.ndarray          # (8,) generating parameters


def _axis(theta, phi):
    st, ct = jnp.sin(theta), jnp.cos(theta)
    sp, cp = jnp.sin(phi), jnp.cos(phi)
    return jnp.stack([st * cp, st * sp, ct])


def _bg_density(x, q):
    """Flattened-halo power-law background (Hernquist-like)."""
    r2 = x[..., 0] ** 2 + x[..., 1] ** 2 + (x[..., 2] / q) ** 2
    return (r2 + 0.25) ** -1.5


def _stream_density(x, center, axis, sigma):
    """Gaussian tube around the line {center + t·axis}."""
    rel = x - center
    along = jnp.einsum("...k,k->...", rel, axis)
    perp2 = jnp.sum(rel * rel, axis=-1) - along ** 2
    return jnp.exp(-0.5 * perp2 / (sigma ** 2))


def log_likelihood(params: jax.Array, stars: jax.Array, quad: jax.Array) -> jax.Array:
    """Mean negative log-likelihood (LOWER is better — a fitness)."""
    eps, cx, cy, cz, theta, phi, lsig, q = (params[i] for i in range(8))
    w = jax.nn.sigmoid(eps)
    sigma = jnp.exp(lsig)
    center = jnp.stack([cx, cy, cz])
    axis = _axis(theta, phi)
    vol = float(np.prod(WEDGE_HI - WEDGE_LO))

    z_bg = jnp.mean(_bg_density(quad, q)) * vol
    z_st = jnp.mean(_stream_density(quad, center, axis, sigma)) * vol

    p_bg = _bg_density(stars, q) / jnp.maximum(z_bg, 1e-12)
    p_st = _stream_density(stars, center, axis, sigma) / jnp.maximum(z_st, 1e-12)
    pdf = (1.0 - w) * p_bg + w * p_st
    return -jnp.mean(jnp.log(jnp.maximum(pdf, 1e-30)))


def make_stripe(name: str, n_stars: int = 100_000, n_quad: int = 4096,
                seed: int = 0) -> Stripe:
    rng = np.random.default_rng(seed)
    # ground truth (perturbed per stripe)
    truth = np.array([
        rng.uniform(-1.5, -0.5),                     # eps (w ~ 0.2-0.4)
        *rng.uniform(-1.0, 1.0, 3),                  # stream center
        rng.uniform(0.8, 2.2), rng.uniform(-1.5, 1.5),  # theta, phi
        np.log(rng.uniform(0.3, 0.6)),               # log sigma
        rng.uniform(0.6, 1.1),                       # q
    ], np.float32)
    w = 1.0 / (1.0 + np.exp(-truth[0]))
    center, sigma, q = truth[1:4], float(np.exp(truth[6])), float(truth[7])
    th, ph = truth[4], truth[5]
    axis = np.array([np.sin(th) * np.cos(ph), np.sin(th) * np.sin(ph), np.cos(th)])

    n_st = int(n_stars * w)
    n_bg = n_stars - n_st
    # stream stars: along the axis, Gaussian tube around it
    t = rng.uniform(-4, 4, n_st)
    e1 = np.cross(axis, [0.0, 0.0, 1.0])
    if np.linalg.norm(e1) < 1e-6:
        e1 = np.cross(axis, [0.0, 1.0, 0.0])
    e1 /= np.linalg.norm(e1)
    e2 = np.cross(axis, e1)
    rad = rng.normal(0, sigma, (n_st, 2))
    st = center + t[:, None] * axis + rad[:, :1] * e1 + rad[:, 1:] * e2
    # background stars: rejection-sample the flattened halo in the wedge
    bg = []
    while sum(len(b) for b in bg) < n_bg:
        cand = rng.uniform(WEDGE_LO, WEDGE_HI, (4 * n_bg + 1024, 3))
        r2 = cand[:, 0] ** 2 + cand[:, 1] ** 2 + (cand[:, 2] / q) ** 2
        dens = (r2 + 0.25) ** -1.5
        keep = rng.random(len(cand)) < dens / dens.max()
        bg.append(cand[keep])
    bg = np.concatenate(bg)[:n_bg]
    stars = np.concatenate([st, bg]).astype(np.float32)
    stars = np.clip(stars, WEDGE_LO, WEDGE_HI)
    rng.shuffle(stars)
    quad = rng.uniform(WEDGE_LO, WEDGE_HI, (n_quad, 3)).astype(np.float32)
    return Stripe(name=name, stars=stars, quad=quad, truth=truth)


def stripe79(n_stars: int = 100_000) -> Stripe:
    return make_stripe("stripe79", n_stars, seed=79)


def stripe86(n_stars: int = 112_000) -> Stripe:
    return make_stripe("stripe86", n_stars, seed=86)


def make_fitness(stripe: Stripe):
    """Returns (f_batch (m,8)->(m,), f_single (8,)->float) jitted fitness fns."""
    stars = jnp.asarray(stripe.stars)
    quad = jnp.asarray(stripe.quad)

    @jax.jit
    def f_single(p):
        return log_likelihood(p, stars, quad)

    @jax.jit
    def f_batch(ps):
        return jax.vmap(lambda p: log_likelihood(p, stars, quad))(ps)

    return f_batch, f_single
