"""Deterministic synthetic token pipeline.

Produces reproducible (tokens, labels) batches without external data: a
mixture of Zipf-distributed unigrams and short Markov "phrases" so the loss
actually decreases during the example training runs.  Supports per-host
sharding (each data-parallel host pulls only its slice) and stateless
resume: batch i is a pure function of (seed, i), so a restarted job
continues the stream exactly (checkpoint stores only the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    # synthetic structure
    zipf_a: float = 1.3
    phrase_len: int = 8
    n_phrases: int = 512


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed phrase table (shared structure to learn)
        self.phrases = root.integers(0, v, (cfg.n_phrases, cfg.phrase_len))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.unigram = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for `step`, local slice for this host. Pure in (seed, step)."""
        cfg = self.cfg
        local = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 64 + cfg.host_id)
        toks = rng.choice(cfg.vocab_size, size=(local, cfg.seq_len + 1),
                          p=self.unigram)
        # splice phrases at random offsets (learnable bigram structure)
        n_splice = max(1, cfg.seq_len // (2 * cfg.phrase_len))
        for b in range(local):
            idx = rng.integers(0, cfg.n_phrases, n_splice)
            off = rng.integers(0, cfg.seq_len - cfg.phrase_len, n_splice)
            for i, o in zip(idx, off):
                toks[b, o:o + cfg.phrase_len] = self.phrases[i]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class SyntheticMasked:
    """Masked-frame batches for encoder-only (hubert-style) training."""

    def __init__(self, cfg: DataConfig, d_model: int, mask_rate: float = 0.3):
        self.cfg = cfg
        self.d_model = d_model
        self.mask_rate = mask_rate
        root = np.random.default_rng(cfg.seed)
        self.codebook = root.normal(size=(cfg.vocab_size, d_model)).astype(np.float32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 999_983 + step) * 64 + cfg.host_id)
        labels = rng.integers(0, cfg.vocab_size, (local, cfg.seq_len))
        embeds = self.codebook[labels] + \
            rng.normal(0, 0.5, (local, cfg.seq_len, self.d_model)).astype(np.float32)
        mask = rng.random((local, cfg.seq_len)) < self.mask_rate
        return {"embeds": embeds.astype(np.float32),
                "labels": labels.astype(np.int32), "mask": mask}
