"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — llama-arch dense, GQA(kv=8)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    head_dim=128,
    head_pad_to=64,  # TP16 alignment (inert masked heads; see DESIGN.md)
    rope_theta=100_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-smoke", family="dense", n_layers=2, d_model=56,
        n_heads=4, n_kv_heads=2, d_ff=144, vocab_size=512, head_dim=16,
        remat=False,
    )
