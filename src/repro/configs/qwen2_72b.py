"""Qwen2-72B [arXiv:2407.10671; hf] — dense, GQA(kv=8), QKV bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=176, vocab_size=512, head_dim=16,
        qkv_bias=True, remat=False,
    )
