"""Model / shape configuration dataclasses shared by the whole framework.

Every assigned architecture is expressed as a ``ModelConfig``; the per-arch
modules in this package instantiate the exact published numbers plus a
``smoke()`` reduction of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    experts_per_token: int = 0      # top-k
    n_shared_experts: int = 0
    expert_d_ff: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    # layers [moe_layer_start, n_layers) with stride moe_layer_stride are MoE
    moe_layer_start: int = 0
    moe_layer_stride: int = 1
    router_jitter: float = 0.0
    # "global": one sort over all tokens (max load balance; combine crosses
    # the model axis with a (tokens·k, d) f32 payload — measured 58x more
    # collective bytes + 14x more HLO flops on deepseek-v2-lite train,
    # EXPERIMENTS.md §Perf cell 1).
    # "grouped": per-batch-row dispatch (GShard groups) — the default.
    dispatch: str = "grouped"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = dense q projection (V2-Lite)
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Covers both RWKV6 and Mamba2 blocks."""
    state_size: int = 64            # mamba2 ssm_state / rwkv head_dim
    expand: int = 2                 # mamba2 d_inner = expand * d_model
    conv_width: int = 4             # mamba2 depthwise conv
    head_dim: int = 64              # mamba2 P / rwkv6 head size
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                    # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                       # dense-FFN hidden dim
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # TP alignment: pad query heads up to this count with inert heads (zero
    # output AND zero gradient via an output mask) so the head axis shards
    # evenly over model=16.  0 = no padding.  Published arch is unchanged —
    # see DESIGN.md §5 and test_models_smoke.
    head_pad_to: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False           # chameleon-style query/key RMSNorm
    parallel_block: bool = False    # cohere-style parallel attn+FFN residual
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 = full attention
    norm_eps: float = 1e-5
    use_layernorm: bool = False     # True -> LayerNorm (cohere/hubert), else RMSNorm
    causal: bool = True
    is_encoder: bool = False        # encoder-only (hubert): no decode path
    frontend: str = "none"          # none | audio_stub | vision_stub
    # block layout for ssm / hybrid archs: entries in
    # {"attn", "rwkv6", "mamba2", "shared_attn"}; empty -> all "attn"
    block_pattern: Tuple[str, ...] = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # shared_attn: one weight-shared transformer block used by all
    # "shared_attn" slots (zamba2)
    shared_attn_every: int = 0
    dtype: str = "bfloat16"
    remat: bool = True              # activation checkpointing for train_step
    # "full": recompute whole blocks in backward (min memory, max recompute)
    # "dots": save matmul outputs (jax dots_with_no_batch_dims_saveable)
    remat_policy: str = "full"
    # Pin TP projection outputs (attn wo / mlp w_out) to their replicated
    # sharding while still bf16, forcing the cross-model all-reduce to move
    # bf16 instead of the f32 the downstream norm consumes (halves TP
    # collective bytes; see EXPERIMENTS.md §Perf).
    pin_proj_outputs: bool = False
    # int8 KV/latent cache with per-position scales (halves decode cache
    # bytes + storage; see EXPERIMENTS.md §Perf cell 2).
    quantized_cache: bool = False
    # Route the loss/train forward's attention and wkv6 hot paths through
    # kernels/ops.py (Pallas on TPU, pure-jnp ref fallback on CPU — see
    # compat.route_pallas / DESIGN.md §11).  Only the contiguous-position
    # prefill leg routes; decode and cache-threading paths are unchanged.
    # Off by default: the routed softmax/scan orderings differ from the
    # dense einsum path in the last ulp, and published-arch smoke tests
    # pin the dense numbers.
    use_kernels: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_heads(self) -> int:
        return self.head_pad_to or self.n_heads

    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        return ("attn",) * self.n_layers

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                   # embed
        if not self.tie_embeddings:
            total += v * d                              # unembed
        hd = self.resolved_head_dim
        shared_counted = False
        for idx, kind in enumerate(self.blocks()):
            if kind == "shared_attn" and shared_counted:
                continue  # weight-shared block: count once
            if kind in ("attn", "shared_attn"):
                if self.mla is not None:
                    m = self.mla
                    q_in = m.q_lora_rank or d
                    total += (d * m.q_lora_rank if m.q_lora_rank else 0)
                    total += q_in * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * hd       # q
                    total += 2 * d * self.n_kv_heads * hd  # k, v
                    total += self.n_heads * hd * d       # o
                if self._layer_is_moe(idx):
                    m = self.moe
                    total += d * m.n_experts             # router
                    total += (m.n_experts + m.n_shared_experts) * 3 * d * m.expert_d_ff
                else:
                    total += 3 * d * self.d_ff           # swiglu
                if kind == "shared_attn":
                    shared_counted = True
            elif kind == "rwkv6":
                total += 4 * d * d + d * self.d_ff * 2   # r,k,v,g(+mix); channel-mix
            elif kind == "mamba2":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                total += d * (2 * d_in + 2 * s.n_groups * s.state_size) + d_in * d
        return total

    def _layer_is_moe(self, idx: int) -> bool:
        m = self.moe
        if m is None:
            return False
        return idx >= m.moe_layer_start and (idx - m.moe_layer_start) % m.moe_layer_stride == 0

    def n_active_params(self) -> int:
        """Active params per token (MoE counts only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        m = self.moe
        full = self.n_params()
        all_expert = m.n_experts * 3 * d * m.expert_d_ff
        n_moe = sum(1 for i in range(self.n_layers) if self._layer_is_moe(i))
        active_expert = m.experts_per_token * 3 * d * m.expert_d_ff
        return full - n_moe * (all_expert - active_expert)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


# The four assigned input shapes (shared across the 10 LM archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules for the 40-cell matrix (documented in DESIGN.md §4)."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window > 0
            or all(b in ("rwkv6", "mamba2") for b in cfg.blocks())
        )
        if not sub_quadratic:
            return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""
