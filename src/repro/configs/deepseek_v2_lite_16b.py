"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf] — MLA + fine-grained MoE.

HF V2-Lite values: 27 layers, d_model=2048, 16 heads, MLA kv_lora_rank=512
(no q-lora in Lite), rope/nope head dims 64/128, v_head_dim=128.
MoE: 64 routed experts top-6 + 2 shared experts, expert_d_ff=1408; the first
layer keeps a dense FFN (d_ff=10944).

Note: the assignment header says "MoE 64e top-6" while its tail says
"160 routed"; 160 belongs to full V2 — we follow the header + HF V2-Lite
(64 routed). Recorded in DESIGN.md §4.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,                  # MLA decompresses to full MHA
    d_ff=10944,                     # dense FFN (layer 0)
    vocab_size=102400,
    head_dim=192,                   # qk_nope (128) + qk_rope (64)
    moe=MoEConfig(
        n_experts=64,
        experts_per_token=6,
        n_shared_experts=2,
        expert_d_ff=1408,
        moe_layer_start=1,          # first layer dense
        moe_layer_stride=1,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_rope_head_dim=64,
        qk_nope_head_dim=128,
        v_head_dim=128,
    ),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=512, head_dim=24,
        moe=MoEConfig(n_experts=8, experts_per_token=2, n_shared_experts=1,
                      expert_d_ff=32, moe_layer_start=1),
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_rope_head_dim=8,
                      qk_nope_head_dim=16, v_head_dim=16),
        remat=False,
    )
