"""RWKV6-7B "Finch" [arXiv:2404.05892; hf] — attention-free RNN with
data-dependent decay; O(1) decode state, so long_500k runs."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                     # wkv heads = d_model / head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    block_pattern=("rwkv6",) * 32,
    ssm=SSMConfig(state_size=64, head_dim=64),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=224, vocab_size=512, head_dim=16,
        block_pattern=("rwkv6",) * 2, ssm=SSMConfig(state_size=16, head_dim=16),
        remat=False,
    )
