"""The paper's own experimental configuration (§VI).

8-parameter Sagittarius-stream + background MLE over SDSS stripe data;
1000 evaluations per regression phase and 1000 per line-search phase.
``repro.data.sdss`` generates the synthetic star catalogs ("stripes").
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class AnmPaperConfig:
    n_params: int = 8
    regression_points: int = 1000       # paper: 1000 per regression phase
    line_search_points: int = 1000      # paper: 1000 per line-search phase
    n_stars: int = 100_000              # paper: 92k-112k stars per stripe
    max_iterations: int = 20            # paper: stripe 79 -> 5, stripe 86 -> 20
    alpha_min: float = 0.0
    alpha_max: float = 2.0
    # volunteer grid shape (MilkyWay@Home ~35k hosts; simulator default smaller)
    n_hosts: int = 2048
    host_failure_prob: float = 0.05
    host_malicious_prob: float = 0.01
    validation_quorum: int = 2


CONFIG = AnmPaperConfig()


def smoke() -> AnmPaperConfig:
    return AnmPaperConfig(
        n_params=4, regression_points=64, line_search_points=64,
        n_stars=2_000, max_iterations=6, n_hosts=64,
    )
