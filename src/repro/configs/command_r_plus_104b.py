"""Command-R-Plus-104B [hf:CohereForAI; unverified] — GQA(kv=8), no bias,
cohere-style parallel attention+FFN block, LayerNorm, huge 256k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    parallel_block=True,
    use_layernorm=True,
    tie_embeddings=True,
    rope_theta=75_000_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512, head_dim=16,
        parallel_block=True, use_layernorm=True, tie_embeddings=True, remat=False,
    )
