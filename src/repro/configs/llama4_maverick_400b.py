"""Llama-4-Maverick-400B-A17B [hf:meta-llama; unverified] — interleaved MoE,
early fusion (VQ image tokens via stub frontend).

128 routed experts, top-1 routing + 1 shared expert, expert_d_ff=8192;
MoE on every other layer (interleave step 2), dense layers use d_ff=16384.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,                     # dense interleaved layers
    vocab_size=202048,
    head_dim=128,
    head_pad_to=48,  # TP16 alignment (inert masked heads; see DESIGN.md)
    qk_norm=True,
    rope_theta=500_000.0,
    frontend="vision_stub",
    moe=MoEConfig(
        n_experts=128,
        experts_per_token=1,
        n_shared_experts=1,
        expert_d_ff=8192,
        moe_layer_start=1,
        moe_layer_stride=2,         # every other layer is MoE
    ),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=512, head_dim=16,
        qk_norm=True, frontend="vision_stub",
        moe=MoEConfig(n_experts=8, experts_per_token=1, n_shared_experts=1,
                      expert_d_ff=64, moe_layer_start=1, moe_layer_stride=2),
        remat=False,
    )
