"""H2O-Danube-3-4B [arXiv:2401.16818; unverified] — llama+mistral mix, SWA.

Sliding-window attention (mistral-style, window 8192) makes this arch
sub-quadratic in cache memory, so it participates in ``long_500k``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=8192,
    rope_theta=100_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=12,
        sliding_window=16, remat=False,
    )
