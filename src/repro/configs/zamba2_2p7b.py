"""Zamba2-2.7B [arXiv:2411.15242; hf] — hybrid: Mamba2 backbone plus a
weight-SHARED GQA transformer block applied every 6 mamba blocks.

54 Mamba2 blocks, d_model=2560, ssm_state=64; shared attention block has
32 heads (kv=32) and d_ff=10240. The shared block re-uses one parameter set
at every application (per-use LoRA deltas omitted; noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, SSMConfig


def _pattern(n_mamba: int, every: int):
    out = []
    for i in range(n_mamba):
        out.append("mamba2")
        if (i + 1) % every == 0:
            out.append("shared_attn")
    return tuple(out)


CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    block_pattern=_pattern(54, 6),
    shared_attn_every=6,
    ssm=SSMConfig(state_size=64, expand=2, head_dim=64, conv_width=4),
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=512, head_dim=16,
        block_pattern=_pattern(4, 2), shared_attn_every=2,
        ssm=SSMConfig(state_size=16, expand=2, head_dim=16, conv_width=4),
        tie_embeddings=True, remat=False,
    )
