"""HuBERT-XLarge [arXiv:2106.07447; unverified] — encoder-only audio model.

The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (batch, frames, d_model); training is
masked-frame prediction over a 504-unit codebook. No decode shapes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    head_dim=80,
    causal=False,
    is_encoder=True,
    use_layernorm=True,
    frontend="audio_stub",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=64, head_dim=16,
        causal=False, is_encoder=True, use_layernorm=True,
        frontend="audio_stub", remat=False,
    )
