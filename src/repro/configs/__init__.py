"""Config registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Ten assigned architectures plus the paper's own 8-parameter astronomy
optimization problem (``paper-anm``, see repro.data.sdss).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    cell_is_runnable,
)

_ARCH_MODULES: Dict[str, str] = {
    "qwen2-72b": "repro.configs.qwen2_72b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
}

ARCH_NAMES: List[str] = list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return importlib.import_module(_ARCH_MODULES[name]).smoke()


def runnable_cells():
    """Yield (arch_name, shape_name, runnable, reason) for all 40 cells."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, reason = cell_is_runnable(cfg, shape)
            yield arch, shape_name, ok, reason
