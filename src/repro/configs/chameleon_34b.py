"""Chameleon-34B [arXiv:2405.09818; unverified] — early-fusion VLM.

Early fusion happens through discrete VQ image tokens drawn from the same
65536 vocab, so the backbone is a single token-stream decoder; the vision
frontend is a stub per the assignment (``input_specs`` supplies tokens).
Chameleon adds query/key RMSNorm for stability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    qk_norm=True,
    frontend="vision_stub",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab_size=512, head_dim=16,
        qk_norm=True, frontend="vision_stub", remat=False,
    )
