# The paper's primary contribution: the asynchronous Newton method (ANM)
# with regression-based gradient+Hessian estimation, the randomized
# asynchronous line search, and the FGDO work-generation/validation/
# assimilation runtime — plus the pod-scale adaptations (subspace Newton,
# parallel line search).  All substrates drive the one AnmEngine state
# machine in core/engine.py (DESIGN.md §1).
from repro.core.anm import AnmConfig, AnmState, anm_minimize  # noqa: F401
from repro.core.engine import AnmEngine, EvalRequest, EvalResult  # noqa: F401
from repro.core.fgdo import FgdoAnmServer, WorkUnit  # noqa: F401
from repro.core.grid import GridConfig, VolunteerGrid  # noqa: F401
from repro.core.substrates.batched_grid import BatchedVolunteerGrid  # noqa: F401
from repro.core.parallel_line_search import (  # noqa: F401
    LineSearchConfig,
    randomized_line_search,
)
from repro.core.subspace_newton import (  # noqa: F401
    SubspaceNewtonConfig,
    init_state,
    subspace_newton_step,
)
from repro.core.orchestrator import (  # noqa: F401
    FleetScheduler,
    MultiSearchResult,
    SearchDirector,
    SearchSpec,
    multi_start_specs,
)
