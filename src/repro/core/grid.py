"""Discrete-event simulator of a volunteer computing grid (BOINC-like).

Hosts are heterogeneous (lognormal speeds), unreliable (may never return a
result) and possibly malicious (return corrupted fitness).  The simulator
drives any server exposing generate_work/assimilate — i.e. FgdoAnmServer.

Deterministic given a seed; used by the fault-tolerance tests and the
scalability benchmark (time-to-solution vs. #hosts, paper §VI discussion).

This simulator evaluates ONE point per Python event, which makes it the
fidelity reference, not the fast path: at thousands of hosts the run is
Python-bound.  core/substrates/batched_grid.py advances the same host
population (via ``sample_hosts``) in vectorized ticks with one batched
fitness call per tick — use it for scale sweeps (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class GridConfig:
    n_hosts: int = 256
    base_eval_time: float = 60.0        # seconds for a speed-1.0 host
    speed_sigma: float = 0.8            # lognormal spread (heterogeneity)
    failure_prob: float = 0.05          # result never returned
    malicious_prob: float = 0.01        # host returns corrupted fitness
    idle_retry: float = 5.0             # delay before re-request when no work
    seed: int = 0


@dataclasses.dataclass
class GridStats:
    completed: int = 0
    failed: int = 0
    corrupted: int = 0
    sim_time: float = 0.0


def sample_hosts(cfg: GridConfig) -> Tuple[np.ndarray, np.ndarray,
                                           np.random.Generator]:
    """Draw the host population (speeds, malicious mask) for a grid config.
    Shared by the per-event and the batched simulators so a given seed means
    the same fleet in both."""
    rng = np.random.default_rng(cfg.seed)
    speeds = rng.lognormal(0.0, cfg.speed_sigma, cfg.n_hosts)
    malicious = rng.random(cfg.n_hosts) < cfg.malicious_prob
    return speeds, malicious, rng


def malicious_lie(y, u):
    """Sign-safe corrupted fitness shared by both grid simulators AND the
    evaluation backends' on-device corruption lanes.

    Fitness is minimized, so a malicious host "wins" by under-reporting.
    The additive margin is scaled to ``|y| + 1`` so the lie beats the truth
    by at least ``0.2 * (|y| + 1)`` for ``u`` drawn in [0.2, 0.8] — unlike a
    multiplicative ``y * u``, which only fakes an improvement when ``y > 0``
    and silently becomes harmless (or self-defeating) for the negative or
    near-zero fitness values that dominate close to an optimum.

    Array-module agnostic on purpose: the dtype follows the inputs, and
    ``np.abs`` dispatches through ``__array_ufunc__``, so the SAME helper
    runs eagerly on host float64 (the per-event simulator) and traced
    inside the backends' jitted bucket finalization (DESIGN.md §7), where
    corruption is applied on-device as mask lanes shipped with the bucket.
    """
    return y - (abs(y) + 1.0) * u


class VolunteerGrid:
    def __init__(self, f: Callable[[np.ndarray], float], cfg: GridConfig):
        self.f = f
        self.cfg = cfg
        self.speeds, self.malicious, self.rng = sample_hosts(cfg)
        self.stats = GridStats()

    def run(self, server, max_events: int = 2_000_000,
            max_sim_time: float = float("inf")) -> GridStats:
        cfg = self.cfg
        rng = self.rng
        seq = itertools.count()
        events: List = []
        for h in range(cfg.n_hosts):
            heapq.heappush(events, (float(rng.uniform(0, cfg.base_eval_time / 10)),
                                    next(seq), h, "request", None))
        n_events = 0
        now = 0.0
        while events and not server.done and n_events < max_events:
            now, _, host, kind, payload = heapq.heappop(events)
            if now > max_sim_time:
                break
            n_events += 1
            if kind == "request":
                wu = server.generate_work(host, now)
                if wu is None:
                    if not server.done:
                        heapq.heappush(events, (now + cfg.idle_retry, next(seq),
                                                host, "request", None))
                    continue
                dt = cfg.base_eval_time / self.speeds[host] * \
                    float(rng.uniform(0.8, 1.2))
                if rng.random() < cfg.failure_prob:
                    # host vanishes with the result; it re-requests much later
                    self.stats.failed += 1
                    heapq.heappush(events, (now + 4 * dt, next(seq), host,
                                            "request", None))
                else:
                    heapq.heappush(events, (now + dt, next(seq), host,
                                            "complete", wu))
            else:  # complete
                wu = payload
                y = float(self.f(wu.point))
                if self.malicious[host]:
                    y = float(malicious_lie(y, rng.uniform(0.2, 0.8)))
                    self.stats.corrupted += 1
                server.assimilate(wu, y, host, now)
                self.stats.completed += 1
                heapq.heappush(events, (now, next(seq), host, "request", None))
        self.stats.sim_time = now
        return self.stats
