"""Randomized parallel line search along any update direction (paper §IV,
applied to LM training).

After an optimizer proposes an update Δθ, p candidate step scales are
evaluated concurrently (on a pod: one candidate per data-parallel slice;
here: lax.map) and the best-loss candidate wins.  Like the paper's line
search there are no sequential dependencies, any subset of candidate results
suffices, and scales > 1 let training escape shallow basins.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LineSearchConfig:
    p: int = 8
    alpha_min: float = 0.25
    alpha_max: float = 2.0
    include_unit: bool = True        # always test α=1 (plain optimizer step)


def randomized_line_search(loss_fn: Callable, params, update_tree, key,
                           cfg: LineSearchConfig = LineSearchConfig(),
                           completed_mask: Optional[jax.Array] = None):
    """Returns (best_params, best_alpha, best_loss).

    loss_fn: params -> scalar (closure over the evaluation minibatch).
    update_tree: pytree of deltas (same structure as params), i.e. the
    optimizer step already including sign/learning rate.
    completed_mask: optional (p,) bool — candidates that "returned"
    (first-m-of-M straggler semantics); others are ignored.
    """
    r = jax.random.uniform(key, (cfg.p,))
    alphas = cfg.alpha_min + r * (cfg.alpha_max - cfg.alpha_min)
    if cfg.include_unit:
        alphas = alphas.at[0].set(1.0)

    def apply_alpha(alpha):
        cand = jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                          + alpha * u.astype(jnp.float32)).astype(p.dtype),
                            params, update_tree)
        return loss_fn(cand)

    losses = jax.lax.map(apply_alpha, alphas)
    if completed_mask is not None:
        losses = jnp.where(completed_mask, losses, jnp.inf)
    best = jnp.argmin(losses)
    alpha_best = alphas[best]
    best_params = jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                             + alpha_best * u.astype(jnp.float32)).astype(p.dtype),
                               params, update_tree)
    return best_params, alpha_best, losses[best]
