"""Regression-based gradient + Hessian estimation (paper §III, eq. 4–5).

We fit the quadratic surrogate in coordinates CENTERED at x'
    f(x' + δ) ≈ c + g·δ + ½ δᵀ H δ
by least squares over m sampled points.  The paper's eq. (4) uses raw
coordinates, which is numerically ill-conditioned away from the origin; the
centered fit is the same surrogate (exact on quadratics — property-tested).
The paper's eq. (5) flat index `2n+1+ni+j` over-counts the upper triangle;
we use the correct triangular layout.

The normal-equations product XᵀX is the compute hot spot at scale
(m up to ~10⁵, cols = (n²+3n)/2 + 1); kernels/gram.py provides the Pallas
kernel (interpret mode on CPU) and this module the pure-jnp path.
``fit_quadratic`` routes to the kernel automatically once the design matrix
crosses ``GRAM_KERNEL_MIN_ELEMENTS`` — so the one dense hot spot uses the
same code path on every substrate, not only in kernel tests (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# m·cols threshold above which the fused Pallas XᵀX/Xᵀy kernel is used.
# Below it the plain jnp matmul wins (kernel launch/interpret overhead).
GRAM_KERNEL_MIN_ELEMENTS = 32768


def n_columns(n: int) -> int:
    """1 (const) + n (grad) + n (diag) + n(n-1)/2 (off-diag)."""
    return 1 + 2 * n + (n * (n - 1)) // 2


def min_points(n: int) -> int:
    """Minimum evaluations for the regression to be determined (paper: ≥ n²+n;
    exact column count is smaller because H is symmetric)."""
    return n_columns(n)


def design_matrix(deltas: jax.Array) -> jax.Array:
    """deltas: (m, n) points relative to the center.  Returns X (m, cols)."""
    m, n = deltas.shape
    iu, ju = jnp.triu_indices(n, k=1)
    cols = [jnp.ones((m, 1), deltas.dtype), deltas, 0.5 * deltas * deltas,
            deltas[:, iu] * deltas[:, ju]]
    return jnp.concatenate(cols, axis=1)


def unpack(beta: jax.Array, n: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """beta (cols,) -> (c, gradient (n,), Hessian (n,n))  [paper eq. (5)]."""
    c = beta[0]
    g = beta[1 : n + 1]
    h_diag = beta[n + 1 : 2 * n + 1]
    h_off = beta[2 * n + 1 :]
    iu, ju = jnp.triu_indices(n, k=1)
    H = jnp.zeros((n, n), beta.dtype)
    H = H.at[iu, ju].set(h_off)
    H = H + H.T
    H = H + jnp.diag(h_diag)
    return c, g, H


def fit_quadratic(deltas: jax.Array, ys: jax.Array, weights: jax.Array = None,
                  ridge: float = 1e-8, use_kernel: bool = None):
    """Weighted least squares via normal equations (paper eq. 4).

    deltas: (m, n); ys: (m,); weights: (m,) — 0 drops a sample, which is how
    failed/unreturned/outlier evaluations are excluded without stalling
    (the asynchronous robustness property).  Weights must be non-negative
    (the MAD guard emits a 0/1 mask).
    ``use_kernel=None`` routes XᵀX/Xᵀy through the Pallas gram kernel when
    m·cols ≥ GRAM_KERNEL_MIN_ELEMENTS, else uses plain jnp.
    Returns (c, g (n,), H (n,n)).
    """
    m, n = deltas.shape
    x = design_matrix(deltas.astype(jnp.float64) if deltas.dtype == jnp.float64
                      else deltas.astype(jnp.float32))
    y = ys.astype(x.dtype)
    if use_kernel is None:
        # the kernel accumulates in f32; never auto-route a float64 fit
        use_kernel = (x.dtype == jnp.float32
                      and x.shape[0] * x.shape[1] >= GRAM_KERNEL_MIN_ELEMENTS)
    if use_kernel:
        from repro.kernels import ops
        if weights is not None:
            sw = jnp.sqrt(jnp.maximum(weights.astype(x.dtype), 0.0))
            gram, rhs = ops.gram(x * sw[:, None], y * sw)
        else:
            gram, rhs = ops.gram(x, y)
        gram = gram.astype(x.dtype)
        rhs = rhs.astype(x.dtype)
    else:
        xw = x * weights.astype(x.dtype)[:, None] if weights is not None else x
        gram = xw.T @ x                               # (cols, cols)
        rhs = xw.T @ y
    # scale-aware ridge keeps the solve stable when columns differ in magnitude
    diag = jnp.diagonal(gram)
    lam = ridge * jnp.maximum(jnp.max(diag), 1.0)
    beta = jnp.linalg.solve(gram + lam * jnp.eye(x.shape[1], dtype=x.dtype), rhs)
    return unpack(beta, n)


def fit_quadratic_robust(deltas: jax.Array, ys: jax.Array,
                         ridge: float = 1e-8, use_kernel: bool = None):
    """Two-pass robust fit: value-MAD guard -> fit -> residual-MAD guard ->
    refit.  A malicious fitness that stays inside the natural spread of the
    sampling box (e.g. the sign-safe lie ``y - (|y|+1)·u``) passes a MAD
    test on raw values, but sits far off the local quadratic surface — the
    residual pass catches exactly those.  Weights are 0/1 masks, so a clean
    sample set refits to the identical surrogate."""
    w = mad_outlier_weights(ys)
    c, g, H = fit_quadratic(deltas, ys, w, ridge, use_kernel)
    pred = c + deltas @ g + \
        0.5 * jnp.einsum("mi,ij,mj->m", deltas, H, deltas)
    w2 = w * mad_outlier_weights(ys - pred)
    return fit_quadratic(deltas, ys, w2, ridge, use_kernel)


def mad_outlier_weights(ys: jax.Array, k: float = 8.0) -> jax.Array:
    """Median-absolute-deviation outlier mask — drops malicious/corrupt fitness
    values before the fit (robustness guard; see DESIGN.md §2)."""
    finite = jnp.isfinite(ys)
    safe = jnp.where(finite, ys, jnp.nanmedian(jnp.where(finite, ys, jnp.nan)))
    med = jnp.median(safe)
    mad = jnp.median(jnp.abs(safe - med)) + 1e-12
    ok = jnp.abs(safe - med) <= k * 1.4826 * mad
    return (finite & ok).astype(ys.dtype)


def newton_direction(g: jax.Array, H: jax.Array, damping: float = 1e-6) -> jax.Array:
    """d = -(H + λI)⁻¹ g  (paper eq. 3), with eigenvalue-shift damping so the
    direction is a descent direction even for indefinite H."""
    evals, evecs = jnp.linalg.eigh(H)
    lam = jnp.maximum(damping, damping - jnp.min(evals))
    inv = 1.0 / (evals + lam)
    return -(evecs * inv[None, :]) @ (evecs.T @ g)
