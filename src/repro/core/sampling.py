"""Point sampling for ANM (paper §III box sampling and §IV eq. (6) line sampling)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def sample_box(key, center: jax.Array, step: jax.Array, m: int) -> jax.Array:
    """m random points uniform in the box center ± step (paper: x' ± s)."""
    n = center.shape[0]
    u = jax.random.uniform(key, (m, n), minval=-1.0, maxval=1.0)
    return center[None, :] + u * step[None, :]


def clip_alpha_range(center: jax.Array, direction: jax.Array,
                     lo: jax.Array, hi: jax.Array,
                     alpha_min: float, alpha_max: float) -> Tuple[jax.Array, jax.Array]:
    """Shrink [alpha_min, alpha_max] so every x' + α d stays inside [lo, hi]
    (paper §IV: bounds 'increased or decreased so no point along the
    directional line could be outside the search space')."""
    d = direction
    safe = jnp.where(jnp.abs(d) > 1e-30, d, 1e-30)
    t_lo = (lo - center) / safe
    t_hi = (hi - center) / safe
    upper = jnp.where(d > 0, t_hi, jnp.where(d < 0, t_lo, jnp.inf))
    lower = jnp.where(d > 0, t_lo, jnp.where(d < 0, t_hi, -jnp.inf))
    a_hi = jnp.minimum(alpha_max, jnp.min(upper))
    a_lo = jnp.maximum(alpha_min, jnp.max(lower))
    # degenerate (direction points straight out of the box): collapse to 0
    a_hi = jnp.maximum(a_hi, 0.0)
    a_lo = jnp.minimum(jnp.maximum(a_lo, 0.0), a_hi)
    return a_lo, a_hi


def sample_line(key, center: jax.Array, direction: jax.Array,
                alpha_min, alpha_max, m: int) -> Tuple[jax.Array, jax.Array]:
    """Paper eq. (6): x = x' + (α_min + r·(α_max − α_min)) d,  r ~ U[0,1).

    Returns (points (m,n), alphas (m,))."""
    r = jax.random.uniform(key, (m,))
    alphas = alpha_min + r * (alpha_max - alpha_min)
    return center[None, :] + alphas[:, None] * direction[None, :], alphas
