"""FGDO: the asynchronous work-generator / validator / assimilator (paper §V).

Since the engine refactor (DESIGN.md §1) this server holds NO phase logic:
``AnmEngine`` owns regression, line search, quorum validation and commits.
What remains here is the BOINC-shaped substrate adapter —

  * workunit ids and the outstanding-work table,
  * stale filtering (the engine discards by phase id; this layer merely
    carries it through the WorkUnit),
  * per-host turnaround AND return-rate tracking for reliable-host
    scheduling: validation replicas, which gate the next iteration, go
    only to hosts with below-median observed turnaround that actually
    return the work they take — a fast host that vanishes with its
    results records no turnaround at all, so turnaround alone would keep
    it "reliable" forever,
  * a reissue timeout for validation replicas lost to vanished hosts.

Semantics reproduced from the paper:
  * work is generated on demand — a fresh random point per request, no
    dependencies between outstanding workunits (§IV);
  * a phase advances when ANY m results have been assimilated; late results
    are simply discarded as stale (§III — failures never stall);
  * only results that will be USED to generate new work are validated
    (the best line-search point), by quorum re-evaluation (§V, ref [7]);
  * malicious/corrupt fitness values additionally face a MAD outlier guard
    before entering the regression (beyond-paper robustness, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import (AnmConfig, AnmEngine, EngineStats, EvalRequest,
                               EvalResult, IterationRecord, LINESEARCH,
                               VALIDATING)

ServerStats = EngineStats             # back-compat alias


@dataclasses.dataclass
class WorkUnit:
    wu_id: int
    phase_id: int
    point: np.ndarray
    alpha: float = float("nan")
    validates: Optional[int] = None   # wu_id of the result this re-checks
    issued_at: float = 0.0


class FgdoAnmServer:
    """Asynchronous Newton method as a BOINC-style server over AnmEngine."""

    def __init__(self, x0, lo, hi, step, cfg: AnmConfig = AnmConfig(),
                 seed: int = 0, validation_quorum: int = 2,
                 validation_rtol: float = 1e-6,
                 val_reissue_timeout: float = 600.0,
                 min_return_rate: float = 0.5, min_issued_for_rate: int = 4):
        self.engine = AnmEngine(x0, lo, hi, step, cfg, seed=seed,
                                validation_quorum=validation_quorum,
                                validation_rtol=validation_rtol)
        self.cfg = cfg
        self.val_reissue_timeout = val_reissue_timeout
        self.min_return_rate = min_return_rate
        self.min_issued_for_rate = min_issued_for_rate
        self._last_val_issue = 0.0
        self.outstanding: Dict[int, WorkUnit] = {}
        self._host_turnaround: Dict[int, float] = {}
        self._host_issued: Dict[int, int] = {}
        self._host_returned: Dict[int, int] = {}

    # -- engine views (back-compat surface) ---------------------------------

    @property
    def center(self) -> np.ndarray:
        return self.engine.center

    @property
    def step(self) -> np.ndarray:
        return self.engine.step

    @property
    def best_fitness(self) -> float:
        return self.engine.best_fitness

    @property
    def iteration(self) -> int:
        return self.engine.iteration

    @property
    def done(self) -> bool:
        return self.engine.done

    @property
    def phase(self) -> str:
        # validation is the tail of the phase that produced the candidate:
        # the f(x0) probe's quorum round still reads as "bootstrap", any
        # other validation as the line-search tail (BOINC terms)
        p = self.engine.phase
        if p == VALIDATING:
            return "bootstrap" if self.engine.bootstrapping else LINESEARCH
        return p

    @property
    def validating(self) -> bool:
        return self.engine.validating

    @property
    def direction(self) -> Optional[np.ndarray]:
        return self.engine.direction

    @property
    def alpha_range(self) -> Tuple[float, float]:
        return self.engine.alpha_range

    @property
    def stats(self) -> EngineStats:
        return self.engine.stats

    @property
    def history(self) -> List[IterationRecord]:
        return self.engine.history

    # -- reliable-host scheduling -------------------------------------------

    def _host_returns(self, host_id: int) -> bool:
        """Return-rate gate: a host that takes work and vanishes never
        records a turnaround, so turnaround alone is failure-blind — judge
        it by what it RETURNS.  Never bypassed, not even by the reissue
        timeout: handing a latency-critical replica to a known black hole
        guarantees another loss."""
        issued = self._host_issued.get(host_id, 0)
        return not (issued >= self.min_issued_for_rate and
                    self._host_returned.get(host_id, 0) <
                    self.min_return_rate * issued)

    def _host_reliable(self, host_id: int) -> bool:
        if not self._host_returns(host_id):
            return False
        t = self._host_turnaround.get(host_id)
        if t is None or len(self._host_turnaround) < 4:
            return True              # unknown hosts get the benefit of doubt
        med = float(np.median(list(self._host_turnaround.values())))
        return t <= med

    # -- work generation ----------------------------------------------------

    def generate_work(self, host_id: int, now: float) -> Optional[WorkUnit]:
        eng = self.engine
        if eng.done:
            return None
        if eng.validating:
            timed_out = now - self._last_val_issue > self.val_reissue_timeout
            # liveness escape: if even the return-rate gate has starved the
            # quorum for 2x the reissue timeout, hand work to anyone — on a
            # fleet where EVERY host drops most work, refusing forever
            # would deadlock the validation instead of merely retrying
            starving = now - self._last_val_issue > 2 * self.val_reissue_timeout
            if eng.validation_pending <= 0 and not timed_out:
                return None          # quorum already issued; host retries later
            if not self._host_returns(host_id) and not starving:
                return None          # black holes never get validation work
            if not self._host_reliable(host_id) and not timed_out:
                return None          # latency-critical WU: reliable hosts only
            if eng.validation_pending > 0:
                req = eng.generate(1)[0]
            else:
                req = eng.reissue_validation()
            if req is None:
                return None
            self._last_val_issue = now
        else:
            if eng.phase == "bootstrap":
                # the f(x0) probe is identical for every host: keep ~2
                # copies in flight (straggler/loss slack, like the batched
                # grid's overcommit) instead of handing one to each of
                # n_hosts; probes older than the reissue timeout count as
                # lost so a dropped probe can't stall the start forever
                live = sum(1 for wu in self.outstanding.values()
                           if wu.phase_id == eng.phase_id and
                           now - wu.issued_at <= self.val_reissue_timeout)
                if live >= 2:
                    return None
            reqs = eng.generate(1)
            if not reqs:
                return None
            req = reqs[0]
        wu = WorkUnit(req.ticket, req.phase_id, np.asarray(req.point),
                      req.alpha, req.validates, issued_at=now)
        self.outstanding[wu.wu_id] = wu
        self._host_issued[host_id] = self._host_issued.get(host_id, 0) + 1
        return wu

    # -- assimilation -------------------------------------------------------

    def assimilate(self, wu: WorkUnit, y: float, host_id: int, now: float):
        self.outstanding.pop(wu.wu_id, None)
        # track per-host return rate + turnaround for reliable-host scheduling
        self._host_returned[host_id] = self._host_returned.get(host_id, 0) + 1
        ta = max(now - wu.issued_at, 1e-9)
        prev = self._host_turnaround.get(host_id)
        self._host_turnaround[host_id] = ta if prev is None else 0.7 * prev + 0.3 * ta
        if self.engine.done:
            return
        req = EvalRequest(wu.wu_id, wu.phase_id, wu.point, wu.alpha,
                          wu.validates)
        transitions = self.engine.assimilate([EvalResult(req, float(y))])
        # every new validation round (first candidate or post-rejection
        # promotion) restarts the reissue-timeout clock, so the reliable-host
        # gate isn't bypassed by a stale timestamp from the previous round
        if any(t.kind == "validating" for t in transitions):
            self._last_val_issue = now
