"""FGDO: the asynchronous work-generator / validator / assimilator (paper §V).

Since the engine refactor (DESIGN.md §1) this server holds NO phase logic:
``AnmEngine`` owns regression, line search, quorum validation and commits.
What remains here is the BOINC-shaped substrate adapter —

  * workunit ids and the outstanding-work table,
  * stale filtering (the engine discards by phase id; this layer merely
    carries it through the WorkUnit),
  * per-host reliability through the shared ``HostRegistry``
    (``repro/server/registry.py``, DESIGN.md §9): turnaround AND
    return-rate tracking for reliable-host scheduling — validation
    replicas, which gate the next iteration, go only to hosts with
    below-median observed turnaround that actually return the work they
    take (a fast host that vanishes with its results records no
    turnaround at all, so turnaround alone would keep it "reliable"
    forever), with a minimum-sample cold-start grace so a brand-new host
    is not excluded before its first result could possibly arrive,
  * a reissue timeout for validation replicas lost to vanished hosts.

The registry is injectable: the service layer (``repro/server``) shares
ONE registry across every search it fronts and serializes it into its
crash checkpoints; standalone use builds a private one.

Semantics reproduced from the paper:
  * work is generated on demand — a fresh random point per request, no
    dependencies between outstanding workunits (§IV);
  * a phase advances when ANY m results have been assimilated; late results
    are simply discarded as stale (§III — failures never stall);
  * only results that will be USED to generate new work are validated
    (the best line-search point), by quorum re-evaluation (§V, ref [7]);
  * malicious/corrupt fitness values additionally face a MAD outlier guard
    before entering the regression (beyond-paper robustness, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import (AnmConfig, AnmEngine, EngineStats, EvalRequest,
                               EvalResult, IterationRecord, LINESEARCH,
                               Transition, VALIDATING)
from repro.server.registry import HostRegistry

ServerStats = EngineStats             # back-compat alias


@dataclasses.dataclass
class WorkUnit:
    wu_id: int
    phase_id: int
    point: np.ndarray
    alpha: float = float("nan")
    validates: Optional[int] = None   # wu_id of the result this re-checks
    issued_at: float = 0.0


class FgdoAnmServer:
    """Asynchronous Newton method as a BOINC-style server over AnmEngine."""

    def __init__(self, x0=None, lo=None, hi=None, step=None,
                 cfg: AnmConfig = AnmConfig(),
                 seed: int = 0, validation_quorum: int = 2,
                 validation_rtol: float = 1e-6,
                 val_reissue_timeout: float = 600.0,
                 min_return_rate: float = 0.5, min_issued_for_rate: int = 4,
                 *, engine: Optional[AnmEngine] = None,
                 registry: Optional[HostRegistry] = None,
                 overcommit: Optional[float] = None):
        if engine is None:
            engine = AnmEngine(x0, lo, hi, step, cfg, seed=seed,
                               validation_quorum=validation_quorum,
                               validation_rtol=validation_rtol)
        self.engine = engine
        self.cfg = engine.cfg
        self.val_reissue_timeout = val_reissue_timeout
        # one registry per fleet: the service layer shares it across every
        # search it fronts, standalone adapters own a private one
        self.registry = registry if registry is not None else HostRegistry(
            min_return_rate=min_return_rate,
            min_issued_for_rate=min_issued_for_rate)
        # feeder throttle (BOINC's bounded shared-memory feeder, the same
        # policy as the batched grid's issuance cap): outstanding
        # current-phase work is held under ``wanted() × overcommit``.
        # ``None`` (the default) keeps the historical fire-hose behavior —
        # the per-event simulator tests pin trajectories against it — while
        # the service layer passes 2.0 so a phase that needs m results
        # costs ~2m evaluations instead of n_hosts.
        self.overcommit = overcommit
        self._last_val_issue = 0.0
        self.outstanding: Dict[int, WorkUnit] = {}

    # -- engine views (back-compat surface) ---------------------------------

    @property
    def center(self) -> np.ndarray:
        return self.engine.center

    @property
    def step(self) -> np.ndarray:
        return self.engine.step

    @property
    def best_fitness(self) -> float:
        return self.engine.best_fitness

    @property
    def iteration(self) -> int:
        return self.engine.iteration

    @property
    def done(self) -> bool:
        return self.engine.done

    @property
    def phase(self) -> str:
        # validation is the tail of the phase that produced the candidate:
        # the f(x0) probe's quorum round still reads as "bootstrap", any
        # other validation as the line-search tail (BOINC terms)
        p = self.engine.phase
        if p == VALIDATING:
            return "bootstrap" if self.engine.bootstrapping else LINESEARCH
        return p

    @property
    def validating(self) -> bool:
        return self.engine.validating

    @property
    def direction(self) -> Optional[np.ndarray]:
        return self.engine.direction

    @property
    def alpha_range(self) -> Tuple[float, float]:
        return self.engine.alpha_range

    @property
    def stats(self) -> EngineStats:
        return self.engine.stats

    @property
    def history(self) -> List[IterationRecord]:
        return self.engine.history

    # registry views kept for inspection/back-compat (tests read these)

    @property
    def _host_issued(self) -> Dict[int, int]:
        return {h: r.issued for h, r in self.registry.hosts.items()}

    @property
    def _host_returned(self) -> Dict[int, int]:
        return {h: r.returned for h, r in self.registry.hosts.items()}

    @property
    def _host_turnaround(self) -> Dict[int, float]:
        return {h: r.ewma_latency for h, r in self.registry.hosts.items()
                if r.ewma_latency is not None}

    # -- reliable-host scheduling -------------------------------------------

    def _host_returns(self, host_id: int) -> bool:
        """Return-rate gate (cold-start grace included) — see
        ``HostRegistry.returns_work``.  Never bypassed, not even by the
        reissue timeout: handing a latency-critical replica to a known
        black hole guarantees another loss."""
        return self.registry.returns_work(host_id)

    def _host_reliable(self, host_id: int) -> bool:
        return self.registry.reliable(host_id)

    # -- work generation ----------------------------------------------------

    def generate_work(self, host_id: int, now: float) -> Optional[WorkUnit]:
        eng = self.engine
        if eng.done:
            return None
        if eng.validating:
            timed_out = now - self._last_val_issue > self.val_reissue_timeout
            # liveness escape: if even the return-rate gate has starved the
            # quorum for 2x the reissue timeout, hand work to anyone — on a
            # fleet where EVERY host drops most work, refusing forever
            # would deadlock the validation instead of merely retrying
            starving = now - self._last_val_issue > 2 * self.val_reissue_timeout
            if eng.validation_pending <= 0 and not timed_out:
                return None          # quorum already issued; host retries later
            if not self._host_returns(host_id) and not starving:
                return None          # black holes never get validation work
            if not self._host_reliable(host_id) and not timed_out:
                return None          # latency-critical WU: reliable hosts only
            if eng.validation_pending > 0:
                req = eng.generate(1)[0]
            else:
                req = eng.reissue_validation()
            if req is None:
                return None
            self._last_val_issue = now
        else:
            if eng.phase == "bootstrap":
                # the f(x0) probe is identical for every host: keep ~2
                # copies in flight (straggler/loss slack, like the batched
                # grid's overcommit) instead of handing one to each of
                # n_hosts; probes older than the reissue timeout count as
                # lost so a dropped probe can't stall the start forever
                live = sum(1 for wu in self.outstanding.values()
                           if wu.phase_id == eng.phase_id and
                           now - wu.issued_at <= self.val_reissue_timeout)
                if live >= 2:
                    return None
            if self.overcommit is not None:
                # entries from finished phases only feed live counts, so
                # they are pruned rather than held forever (their results,
                # if they ever arrive, are assimilated from the caller's
                # own workunit record and discarded as phase-stale)
                for wid in [wid for wid, wu in self.outstanding.items()
                            if wu.phase_id != eng.phase_id]:
                    del self.outstanding[wid]
                live = sum(1 for wu in self.outstanding.values()
                           if now - wu.issued_at <= self.val_reissue_timeout)
                if live >= int(np.ceil(eng.wanted() * self.overcommit)):
                    return None
            reqs = eng.generate(1)
            if not reqs:
                return None
            req = reqs[0]
        wu = WorkUnit(req.ticket, req.phase_id, np.asarray(req.point),
                      req.alpha, req.validates, issued_at=now)
        self.outstanding[wu.wu_id] = wu
        self.registry.on_issue(host_id, now)
        return wu

    # -- assimilation -------------------------------------------------------

    def assimilate(self, wu: WorkUnit, y: float, host_id: int,
                   now: float) -> List[Transition]:
        self.outstanding.pop(wu.wu_id, None)
        # per-host return rate + turnaround feed reliable-host scheduling;
        # phase-staleness is knowable before the engine sees the result,
        # so the registry's per-host valid-rate costs nothing extra
        self.registry.on_result(host_id, now,
                                max(now - wu.issued_at, 1e-9),
                                stale=wu.phase_id != self.engine.phase_id)
        if self.engine.done:
            return []
        req = EvalRequest(wu.wu_id, wu.phase_id, wu.point, wu.alpha,
                          wu.validates)
        transitions = self.engine.assimilate([EvalResult(req, float(y))])
        # every new validation round (first candidate or post-rejection
        # promotion) restarts the reissue-timeout clock, so the reliable-host
        # gate isn't bypassed by a stale timestamp from the previous round
        if any(t.kind == "validating" for t in transitions):
            self._last_val_issue = now
        return transitions

    # -- state serialization (service layer, DESIGN.md §9) ------------------

    def state_dict(self) -> dict:
        """Adapter state for the crash checkpoint: the engine, the
        outstanding-work table and the reissue clock.  The shared registry
        is serialized ONCE by the owning work server, not per adapter."""
        return {
            "engine": self.engine.state_dict(),
            "last_val_issue": self._last_val_issue,
            "outstanding": [{
                "wu_id": wu.wu_id, "phase_id": wu.phase_id,
                "point": np.asarray(wu.point),
                "alpha": wu.alpha, "validates": wu.validates,
                "issued_at": wu.issued_at,
            } for wu in self.outstanding.values()],
        }

    def load_state(self, d: dict) -> None:
        self.engine.load_state(d["engine"])
        self._last_val_issue = float(d["last_val_issue"])
        self.outstanding = {}
        for w in d["outstanding"]:
            wu = WorkUnit(int(w["wu_id"]), int(w["phase_id"]),
                          np.asarray(w["point"], np.float64),
                          float(w["alpha"]),
                          None if w["validates"] is None
                          else int(w["validates"]),
                          issued_at=float(w["issued_at"]))
            self.outstanding[wu.wu_id] = wu
