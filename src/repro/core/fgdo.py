"""FGDO: the asynchronous work-generator / validator / assimilator (paper §V).

The server is a pure state machine driven by (generate_work, assimilate)
callbacks from a computing substrate — here the discrete-event volunteer grid
in core/grid.py; on a pod, data-parallel workers play the same role.

Semantics reproduced from the paper:
  * work is generated on demand — a fresh random point per request, no
    dependencies between outstanding workunits (§IV);
  * a phase advances when ANY m results have been assimilated; late results
    are simply discarded as stale (§III — failures never stall);
  * only results that will be USED to generate new work are validated
    (the best line-search point), by quorum re-evaluation (§V, ref [7]);
  * malicious/corrupt fitness values additionally face a MAD outlier guard
    before entering the regression (beyond-paper robustness, DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regression, sampling
from repro.core.anm import AnmConfig, IterationRecord

REGRESSION, LINESEARCH = "regression", "linesearch"


@dataclasses.dataclass
class WorkUnit:
    wu_id: int
    phase_id: int
    point: np.ndarray
    alpha: float = float("nan")
    validates: Optional[int] = None   # wu_id of the result this re-checks
    issued_at: float = 0.0


@dataclasses.dataclass
class ServerStats:
    issued: int = 0
    assimilated: int = 0
    stale: int = 0
    validations_issued: int = 0
    validations_failed: int = 0
    candidates_rejected: int = 0


class FgdoAnmServer:
    """Asynchronous Newton method as a BOINC-style server."""

    def __init__(self, x0, lo, hi, step, cfg: AnmConfig = AnmConfig(),
                 seed: int = 0, validation_quorum: int = 2,
                 validation_rtol: float = 1e-6,
                 val_reissue_timeout: float = 600.0):
        self.val_reissue_timeout = val_reissue_timeout
        self._last_val_issue = 0.0
        self.cfg = cfg
        self.center = np.asarray(x0, np.float64)
        self.lo = np.asarray(lo, np.float64)
        self.hi = np.asarray(hi, np.float64)
        self.step = np.asarray(step, np.float64)
        self.rng = np.random.default_rng(seed)
        self.quorum = validation_quorum
        self.vrtol = validation_rtol

        self.phase = REGRESSION
        self.phase_id = 0
        self.iteration = 0
        self.best_fitness = float("inf")
        self.direction: Optional[np.ndarray] = None
        self.alpha_range: Tuple[float, float] = (cfg.alpha_min, cfg.alpha_max)
        self.results: List[Tuple[np.ndarray, float, float, int]] = []  # pt,y,alpha,wu
        self.outstanding: Dict[int, WorkUnit] = {}
        self._wu_counter = itertools.count()
        self.stats = ServerStats()
        self.history: List[IterationRecord] = []
        self.done = False
        # validation bookkeeping: candidate queue (sorted by fitness) and votes
        self._candidates: List[Tuple[float, np.ndarray, float, int]] = []
        self._validating: Optional[Tuple[float, np.ndarray, float, int]] = None
        self._votes: List[float] = []
        self._pending_validation_issues = 0
        self.validating = False      # line-search collection finished, quorum pending
        # BOINC-style reliable-host scheduling: validation replicas (which
        # gate the next iteration) go only to hosts with below-median
        # observed turnaround, so one slow volunteer can't stall the search.
        self._host_turnaround: Dict[int, float] = {}

    def _host_reliable(self, host_id: int) -> bool:
        t = self._host_turnaround.get(host_id)
        if t is None or len(self._host_turnaround) < 4:
            return True              # unknown hosts get the benefit of doubt
        med = float(np.median(list(self._host_turnaround.values())))
        return t <= med

    # -- work generation ----------------------------------------------------

    def generate_work(self, host_id: int, now: float) -> Optional[WorkUnit]:
        if self.done:
            return None
        if self.validating:
            if self._validating is None:
                return None
            timed_out = now - self._last_val_issue > self.val_reissue_timeout
            if self._pending_validation_issues <= 0 and not timed_out:
                return None          # quorum already issued; host retries later
            if not self._host_reliable(host_id) and not timed_out:
                return None          # latency-critical WU: reliable hosts only
            if self._pending_validation_issues > 0:
                self._pending_validation_issues -= 1
            wu_id = next(self._wu_counter)
            self._last_val_issue = now
            wu = WorkUnit(wu_id, self.phase_id, self._validating[1].copy(),
                          self._validating[2], validates=self._validating[3],
                          issued_at=now)
            self.stats.validations_issued += 1
            self.outstanding[wu_id] = wu
            self.stats.issued += 1
            return wu
        wu_id = next(self._wu_counter)
        if self.phase == REGRESSION:
            u = self.rng.uniform(-1.0, 1.0, self.center.shape)
            pt = np.clip(self.center + u * self.step, self.lo, self.hi)
            wu = WorkUnit(wu_id, self.phase_id, pt, issued_at=now)
        else:
            a_lo, a_hi = self.alpha_range
            alpha = float(self.rng.uniform(a_lo, a_hi))
            pt = self.center + alpha * self.direction
            wu = WorkUnit(wu_id, self.phase_id, pt, alpha, issued_at=now)
        self.outstanding[wu_id] = wu
        self.stats.issued += 1
        return wu

    # -- assimilation -------------------------------------------------------

    def assimilate(self, wu: WorkUnit, y: float, host_id: int, now: float):
        self.outstanding.pop(wu.wu_id, None)
        # track per-host turnaround for reliable-host scheduling
        ta = max(now - wu.issued_at, 1e-9)
        prev = self._host_turnaround.get(host_id)
        self._host_turnaround[host_id] = ta if prev is None else 0.7 * prev + 0.3 * ta
        if self.done:
            return
        if wu.phase_id != self.phase_id:
            self.stats.stale += 1
            return
        self.stats.assimilated += 1
        if wu.validates is not None:
            if self.validating and self._validating is not None \
                    and wu.validates == self._validating[3]:
                self._votes.append(y)
                self._check_validation(now)
            else:
                self.stats.stale += 1
            return
        if self.validating:
            self.stats.stale += 1    # late line-search result; phase is sealed
            return
        self.results.append((wu.point, float(y), wu.alpha, wu.wu_id))
        m_needed = (self.cfg.m_regression if self.phase == REGRESSION
                    else self.cfg.m_line_search)
        if len(self.results) >= m_needed:
            if self.phase == REGRESSION:
                self._finish_regression()
            else:
                self._finish_line_search(now)

    # -- phase transitions --------------------------------------------------

    def _finish_regression(self):
        pts = np.stack([r[0] for r in self.results])
        ys = np.array([r[1] for r in self.results])
        w = (np.asarray(regression.mad_outlier_weights(jnp.asarray(ys)))
             if self.cfg.outlier_guard else None)
        deltas = jnp.asarray(pts - self.center[None, :], jnp.float32)
        _, g, H = regression.fit_quadratic(
            deltas, jnp.asarray(ys, jnp.float32),
            None if w is None else jnp.asarray(w, jnp.float32), self.cfg.ridge)
        d = regression.newton_direction(g, H, self.cfg.damping)
        self.direction = np.asarray(d, np.float64)
        a_lo, a_hi = sampling.clip_alpha_range(
            jnp.asarray(self.center, jnp.float32), jnp.asarray(d),
            jnp.asarray(self.lo, jnp.float32), jnp.asarray(self.hi, jnp.float32),
            self.cfg.alpha_min, self.cfg.alpha_max)
        self.alpha_range = (float(a_lo), float(a_hi))
        self._advance_phase(LINESEARCH)

    def _finish_line_search(self, now: float):
        finite = [(y, pt, a, wid) for pt, y, a, wid in self.results
                  if np.isfinite(y)]
        finite.sort(key=lambda r: r[0])
        self._line_avg = float(np.mean([r[0] for r in finite])) if finite else float("nan")
        self._candidates = finite
        self.validating = True
        self._start_validation(now)

    def _start_validation(self, now: float):
        if not self._candidates:
            # nothing usable: shrink step, next iteration from same center
            self._commit(self.center, self.best_fitness, float("nan"), improved=False)
            return
        self._validating = self._candidates.pop(0)
        self._votes = [self._validating[0]]
        self._pending_validation_issues = self.quorum
        self._last_val_issue = now
        # phase stays LINESEARCH; validation WUs carry validates=wu_id

    def _check_validation(self, now: float):
        need = self.quorum + 1
        if len(self._votes) < need:
            return
        votes = np.array(self._votes)
        med = np.median(votes)
        agree = np.sum(np.abs(votes - med) <= self.vrtol * max(1.0, abs(med)))
        cand_y, cand_pt, cand_a, _ = self._validating
        self._validating = None
        if agree >= (need // 2 + 1) and abs(cand_y - med) <= self.vrtol * max(1.0, abs(med)):
            improved = med < self.best_fitness - self.cfg.tol
            self._commit(cand_pt, float(med), cand_a, improved)
        else:
            self.stats.validations_failed += 1
            self.stats.candidates_rejected += 1
            self._start_validation(now)

    def _commit(self, x_next, f_best, alpha, improved: bool):
        if improved:
            self.center = np.asarray(x_next, np.float64)
            self.best_fitness = f_best
        else:
            self.step = self.step * self.cfg.shrink_on_fail
        self.iteration += 1
        self.history.append(IterationRecord(
            iteration=self.iteration, best_fitness=self.best_fitness,
            avg_line_fitness=getattr(self, "_line_avg", float("nan")),
            center=self.center.copy(),
            evals_used=self.stats.assimilated, best_alpha=alpha))
        if self.iteration >= self.cfg.max_iterations or \
                (not improved and float(np.max(self.step)) < 1e-12):
            self.done = True
        self._advance_phase(REGRESSION)

    def _advance_phase(self, phase: str):
        self.phase = phase
        self.phase_id += 1
        self.results = []
        self.validating = False
        self._validating = None
        self._candidates = []
        self._votes = []
        self._pending_validation_issues = 0
