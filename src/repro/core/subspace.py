"""Shared subspace-projection machinery (DESIGN.md §11).

A k-dimensional search over an n-parameter model needs exactly one piece
of geometry: an anchor point θ0, an orthonormal basis V (k, P) over the
raveled parameter vector, and the lift c ↦ θ0 + Σᵢ cᵢ·Vᵢ.  Two consumers
share it:

  * ``core/subspace_newton.py`` — the in-process subspace-Newton optimizer
    (ravel → basis → lift → regression), which re-anchors every step;
  * ``core/substrates/lm_loss.py`` — the LM-loss ``EvalBackend``, which
    fixes one projection for a whole search and evaluates engine
    candidates (subspace coefficient vectors) as model losses.

The lift is computed LEAF BY LEAF (``basis_tree`` mirrors the parameter
pytree with a leading k axis), never through the raveled vector: the
flat form would force every evaluation through one (P,) concatenation,
while the tree form keeps each leaf's contribution a standalone
``tensordot`` — which is what lets the pod backend shard θ0 and the basis
with the model's own ``param_specs`` (the basis leaf for a weight sharded
``P(None, 'model', None)`` is sharded ``P(None, None, 'model', None)``)
and reconstruct full leaves with per-leaf all-gathers.  Both backends run
the SAME per-leaf lift, so in-process and pod evaluations agree bitwise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def ravel_pytree(tree):
    """(flat f32 (P,), unravel) — unravel restores shapes AND leaf dtypes."""
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]

    def unravel(v):
        out, off = [], 0
        for shape, dtype in shapes:
            size = 1
            for s in shape:
                size *= s
            out.append(v[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unravel


def orthonormal_basis(key, n: int, k: int,
                      anchor: Optional[jax.Array] = None) -> jax.Array:
    """(k, P) orthonormal rows: ``anchor`` (momentum/gradient) first when
    given, random normal directions for the rest, Gram-Schmidt via QR on
    the transpose.  Deterministic per (key, n, k, anchor)."""
    if anchor is not None:
        rows = jnp.concatenate(
            [anchor[None, :], jax.random.normal(key, (k - 1, n))], axis=0)
    else:
        rows = jax.random.normal(key, (k, n))
    q, _ = jnp.linalg.qr(rows.T)                    # (P, k)
    return q.T                                      # (k, P)


def basis_to_tree(basis: jax.Array, params) -> Any:
    """Reshape each (P,)-row-slice of the flat basis into a pytree leaf of
    shape (k, *leaf.shape), kept f32 (directions must not round through
    bf16 storage dtypes)."""
    leaves, treedef = jax.tree.flatten(params)
    k = basis.shape[0]
    out, off = [], 0
    for l in leaves:
        size = int(l.size)
        out.append(basis[:, off:off + size].reshape((k,) + l.shape))
        off += size
    return jax.tree.unflatten(treedef, out)


def tree_lift(theta0, basis_tree, c):
    """θ0 + Σᵢ cᵢ·Vᵢ computed per leaf in f32, cast back to each leaf's
    storage dtype.  THE canonical lift: every consumer (optimizer step,
    in-process backend, pod shard_map body) calls this one function, so
    subspace evaluations can never diverge between them."""
    return jax.tree.map(
        lambda p, b: (p.astype(jnp.float32)
                      + jnp.tensordot(c, b, axes=1)).astype(p.dtype),
        theta0, basis_tree)


@dataclasses.dataclass(frozen=True)
class SubspaceProjection:
    """One fixed k-dim affine chart through parameter space.

    ``theta0``: anchor pytree (original leaf dtypes); ``basis``: (k, P)
    f32 orthonormal rows over the raveled vector; ``basis_tree``: the same
    basis reshaped leaf-by-leaf (k, *leaf.shape) — the form evaluation
    actually uses; ``unravel``: (P,) → pytree (kept for flat-space
    consumers like the optimizer's momentum update).
    """
    theta0: Any
    flat0: jax.Array
    basis: jax.Array
    basis_tree: Any
    unravel: Callable = dataclasses.field(repr=False)

    @property
    def k(self) -> int:
        return int(self.basis.shape[0])

    @property
    def n_params(self) -> int:
        return int(self.basis.shape[1])

    @classmethod
    def create(cls, params, k: int, key,
               anchor: Optional[jax.Array] = None) -> "SubspaceProjection":
        flat, unravel = ravel_pytree(params)
        basis = orthonormal_basis(key, flat.shape[0], k, anchor)
        return cls(theta0=params, flat0=flat, basis=basis,
                   basis_tree=basis_to_tree(basis, params), unravel=unravel)

    def lift(self, c):
        """c (k,) → params pytree at θ0 + c·V (leaf-wise lift)."""
        return tree_lift(self.theta0, self.basis_tree, c)

    def lift_flat(self, c):
        """c (k,) → raveled (P,) f32 point (flat-space consumers only)."""
        return self.flat0 + c @ self.basis

    def shift_flat(self, c):
        """c (k,) → the raveled displacement c·V (momentum updates)."""
        return c @ self.basis
