"""ANM lifted to neural-network training: Newton's method in a k-dim subspace.

This is the pod-mode adaptation of the paper (DESIGN.md §2): a "function
evaluation" is a minibatch loss at θ + V·c, the m sample evaluations are
embarrassingly parallel across data-parallel workers (any m of M suffice —
the paper's straggler/fault tolerance, by construction), the regression of
§III recovers the k-dim gradient+Hessian, and the randomized line search of
§IV picks the step.

The subspace basis V mixes the momentum direction, the latest gradient
estimate and random directions, so the method degrades gracefully to
random-subspace descent when the quadratic model is poor.

The ravel/basis/lift geometry lives in ``core/subspace.py``
(``SubspaceProjection``) and is SHARED with the LM-loss evaluation backend
(``core/substrates/lm_loss.py``): this optimizer re-anchors a fresh
projection every step, the backend freezes one for a whole asynchronous
search — but both lift subspace coefficients through the same per-leaf
``tree_lift``, so an engine candidate means the same model parameters
everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import regression
from repro.core.subspace import SubspaceProjection, orthonormal_basis, ravel_pytree


@dataclasses.dataclass(frozen=True)
class SubspaceNewtonConfig:
    k: int = 8                       # subspace dimension
    m: Optional[int] = None          # samples; default 2 * n_columns(k)
    sample_scale: float = 0.05       # box half-width in subspace coords
    alpha_max: float = 2.0
    p_line: int = 16                 # line-search candidates
    damping: float = 1e-4
    ridge: float = 1e-6
    momentum: float = 0.9

    def m_resolved(self) -> int:
        return self.m or 2 * regression.n_columns(self.k)


# kept under their historical names: callers (and the shared-machinery
# contract) reach the one implementation in core/subspace.py
_ravel = ravel_pytree


def init_state(params):
    flat, _ = ravel_pytree(params)
    return {"momentum": jnp.zeros_like(flat), "step": jnp.zeros((), jnp.int32)}


def make_basis(key, flat_params, momentum, k: int):
    """(k, P) orthonormal basis: momentum + random directions."""
    return orthonormal_basis(key, flat_params.shape[0], k, anchor=momentum)


def subspace_newton_step(loss_fn: Callable, params, state,
                         cfg: SubspaceNewtonConfig, key,
                         completed_mask: Optional[jax.Array] = None):
    """One ANM step in a k-dim subspace.

    loss_fn: params -> scalar loss (closure over the minibatch).
    completed_mask: optional (m,) bool — simulates which of the m sample
    evaluations returned (first-m-of-M semantics); dropped samples get
    weight 0 in the regression, exactly like a failed volunteer.
    Returns (new_params, new_state, info dict).
    """
    k = cfg.k
    m = cfg.m_resolved()
    k_basis, k_box, k_line = jax.random.split(key, 3)
    proj = SubspaceProjection.create(params, k, k_basis,
                                     anchor=state["momentum"])

    coeffs = jax.random.uniform(k_box, (m, k), minval=-cfg.sample_scale,
                                maxval=cfg.sample_scale)

    def eval_at(c):
        return loss_fn(proj.lift(c))

    ys = jax.lax.map(eval_at, coeffs)
    weights = None
    if completed_mask is not None:
        weights = completed_mask.astype(jnp.float32)
    _, g, H = regression.fit_quadratic(coeffs, ys, weights, cfg.ridge)
    d = regression.newton_direction(g, H, cfg.damping)           # (k,)

    # randomized line search (paper §IV) over p candidates, vmapped
    alphas = jax.random.uniform(k_line, (cfg.p_line,), minval=0.0,
                                maxval=cfg.alpha_max)
    cand = alphas[:, None] * d[None, :]                          # (p,k)
    f_cand = jax.lax.map(eval_at, cand)
    f0 = loss_fn(params)
    best = jnp.argmin(f_cand)
    take = f_cand[best] < f0
    alpha_best = jnp.where(take, alphas[best], 0.0)

    delta_flat = proj.shift_flat(alpha_best * d)
    new_params = proj.unravel(proj.flat0 + delta_flat)
    mom = cfg.momentum * state["momentum"] + delta_flat
    info = {"loss_before": f0, "loss_after": jnp.minimum(f_cand[best], f0),
            "alpha": alpha_best, "grad_norm": jnp.linalg.norm(g)}
    return new_params, {"momentum": mom, "step": state["step"] + 1}, info
