"""ANM lifted to neural-network training: Newton's method in a k-dim subspace.

This is the pod-mode adaptation of the paper (DESIGN.md §2): a "function
evaluation" is a minibatch loss at θ + V·c, the m sample evaluations are
embarrassingly parallel across data-parallel workers (any m of M suffice —
the paper's straggler/fault tolerance, by construction), the regression of
§III recovers the k-dim gradient+Hessian, and the randomized line search of
§IV picks the step.

The subspace basis V mixes the momentum direction, the latest gradient
estimate and random directions, so the method degrades gracefully to
random-subspace descent when the quadratic model is poor.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import regression, sampling


@dataclasses.dataclass(frozen=True)
class SubspaceNewtonConfig:
    k: int = 8                       # subspace dimension
    m: Optional[int] = None          # samples; default 2 * n_columns(k)
    sample_scale: float = 0.05       # box half-width in subspace coords
    alpha_max: float = 2.0
    p_line: int = 16                 # line-search candidates
    damping: float = 1e-4
    ridge: float = 1e-6
    momentum: float = 0.9

    def m_resolved(self) -> int:
        return self.m or 2 * regression.n_columns(self.k)


def _ravel(tree):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]

    def unravel(v):
        out, off = [], 0
        for shape, dtype in shapes:
            size = 1
            for s in shape:
                size *= s
            out.append(v[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unravel


def init_state(params):
    flat, _ = _ravel(params)
    return {"momentum": jnp.zeros_like(flat), "step": jnp.zeros((), jnp.int32)}


def make_basis(key, flat_params, momentum, k: int):
    """(k, P) orthonormal basis: momentum + random directions."""
    n = flat_params.shape[0]
    dirs = [momentum]
    rnd = jax.random.normal(key, (k - 1, n))
    basis = jnp.concatenate([momentum[None, :], rnd], axis=0)
    # Gram-Schmidt (QR on the transpose)
    q, _ = jnp.linalg.qr(basis.T)                   # (P, k)
    return q.T                                      # (k, P)


def subspace_newton_step(loss_fn: Callable, params, state,
                         cfg: SubspaceNewtonConfig, key,
                         completed_mask: Optional[jax.Array] = None):
    """One ANM step in a k-dim subspace.

    loss_fn: params -> scalar loss (closure over the minibatch).
    completed_mask: optional (m,) bool — simulates which of the m sample
    evaluations returned (first-m-of-M semantics); dropped samples get
    weight 0 in the regression, exactly like a failed volunteer.
    Returns (new_params, new_state, info dict).
    """
    k = cfg.k
    m = cfg.m_resolved()
    flat, unravel = _ravel(params)
    k_basis, k_box, k_line = jax.random.split(key, 3)
    V = make_basis(k_basis, flat, state["momentum"], k)          # (k,P)

    coeffs = jax.random.uniform(k_box, (m, k), minval=-cfg.sample_scale,
                                maxval=cfg.sample_scale)

    def eval_at(c):
        return loss_fn(unravel(flat + c @ V))

    ys = jax.lax.map(eval_at, coeffs)
    weights = None
    if completed_mask is not None:
        weights = completed_mask.astype(jnp.float32)
    _, g, H = regression.fit_quadratic(coeffs, ys, weights, cfg.ridge)
    d = regression.newton_direction(g, H, cfg.damping)           # (k,)

    # randomized line search (paper §IV) over p candidates, vmapped
    alphas = jax.random.uniform(k_line, (cfg.p_line,), minval=0.0,
                                maxval=cfg.alpha_max)
    cand = alphas[:, None] * d[None, :]                          # (p,k)
    f_cand = jax.lax.map(eval_at, cand)
    f0 = loss_fn(params)
    best = jnp.argmin(f_cand)
    take = f_cand[best] < f0
    alpha_best = jnp.where(take, alphas[best], 0.0)

    delta_flat = (alpha_best * d) @ V
    new_flat = flat + delta_flat
    new_params = unravel(new_flat)
    mom = cfg.momentum * state["momentum"] + delta_flat
    info = {"loss_before": f0, "loss_after": jnp.minimum(f_cand[best], f0),
            "alpha": alpha_best, "grad_norm": jnp.linalg.norm(g)}
    return new_params, {"momentum": mom, "step": state["step"] + 1}, info
