"""Pod-mesh evaluation backend: shard_map workunit buckets over the pod.

This is the ROADMAP's "wire the batched grid to the pod mesh" step: instead
of evaluating each tick's workunit block with one local ``f_batch`` call,
``PodMeshEvalBackend`` partitions the padded bucket over the ``data`` axis
of the production mesh (``launch/mesh.py::make_production_mesh``, 16×16 =
256 devices under dryrun's forced 512-device host platform) and lets every
data shard evaluate its ``kp / n_shards`` rows in parallel.  The ``model``
axis is left for the fitness function itself (a replicated closure today;
a model-sharded likelihood slots in without touching the grid).

The backend speaks the shared async ``submit``/``collect`` protocol
(DESIGN.md §7): the shard_map'd evaluation is traced inside the base
class's jitted bucket finalization, so corruption lanes and pad-NaN
masking happen on-device here exactly as in-process, and the bucket
ladder is warmed at construction when ``n_dims``/``max_bucket`` are given.

Key properties (DESIGN.md §6):

  * buckets are powers of two with a floor at the shard count, so every
    shard gets the same whole number of rows and XLA still compiles
    O(log k_max) shapes — shapes depend on the block size and shard count,
    never on the grid's host count;
  * remainder lanes (k < bucket) are padded with the last real point and
    come back NaN-masked by the shared on-device framing — never dropped;
  * rows are evaluated by the SAME per-row computation as in-process
    (``f_batch`` is row-independent), so a given engine seed commits
    bit-identical iterates on either backend — pinned by
    tests/test_substrates_pod_mesh.py and the shootout's parity gate.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.substrates.eval_backend import EvalBackend, bucket_size


def make_data_mesh():
    """Best evaluation mesh for the visible devices: the production pod
    when enough devices exist (e.g. under ``launch/dryrun``'s forced host
    platform), else the largest power-of-two data-parallel mesh that fits
    — down to a degenerate (1, 1) mesh on a single-device CPU, which keeps
    the shard_map path importable and testable anywhere."""
    import jax
    from repro.launch.mesh import make_production_mesh
    try:
        return make_production_mesh()
    except RuntimeError:
        n = len(jax.devices())
        d = 1 << (n.bit_length() - 1)
        return jax.make_mesh((d, 1), ("data", "model"),
                             devices=jax.devices()[:d])


class PodMeshEvalBackend(EvalBackend):
    """Evaluate buckets with ``shard_map`` over the mesh's ``data`` axis.

    f_batch: (rows, n) -> (rows,) fitness, jit-friendly and row-independent
    (each shard calls it on its local rows).  ``mesh`` defaults to
    ``make_data_mesh()``.
    """

    def __init__(self, f_batch: Callable, mesh=None, data_axis: str = "data",
                 *, n_dims: Optional[int] = None,
                 max_bucket: Optional[int] = None):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        self.mesh = make_data_mesh() if mesh is None else mesh
        self.data_axis = data_axis
        self.n_shards = int(self.mesh.shape[data_axis])
        if self.n_shards & (self.n_shards - 1):
            raise ValueError(
                f"data axis must be a power of two to divide the "
                f"power-of-two buckets, got {self.n_shards}")
        self.f_batch = f_batch
        self._sharded = shard_map(
            f_batch, mesh=self.mesh,
            in_specs=P(data_axis, None), out_specs=P(data_axis))
        # floor of 4 rows per shard: XLA CPU picks a different (last-ulp
        # divergent) vectorization for 2-row sub-batches (observed on jax
        # 0.4.37 — every other width is bitwise-stable), and bit-identical
        # iterates vs the in-process backend are a hard contract of this
        # seam.  The parity gates (tests + dryrun smoke + shootout) exist
        # to catch any future regression of this property.
        super().__init__(bucket_size(4 * self.n_shards))
        if n_dims is not None and max_bucket is not None:
            self.warm(n_dims, max_bucket)

    def _raw_eval(self, pts):
        return self._sharded(pts)
