"""Vectorized volunteer-grid substrate: one batched fitness call per tick.

The per-event simulator (core/grid.py) calls ``f(point)`` once per Python
event, so simulating the paper's m=1000-per-phase workloads at thousands of
hosts is Python/dispatch-bound.  This substrate keeps the same physics —
lognormal host speeds, result loss, malicious corruption, identical host
population per seed via ``grid.sample_hosts`` — but advances the whole
fleet with numpy array ops and evaluates ALL workunits completing in a tick
with a single jitted ``f_batch`` call (padded to power-of-two buckets so
XLA compiles O(log n_hosts) shapes, not one per tick).

It drives the ``AnmEngine`` event API directly: requests out, results in,
in completion-time order, so stale filtering and quorum validation behave
exactly as on the per-event grid (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core.engine import AnmEngine, EvalRequest, EvalResult
from repro.core.grid import GridConfig, GridStats, sample_hosts


@dataclasses.dataclass
class BatchedGridStats(GridStats):
    ticks: int = 0
    batch_calls: int = 0
    batched_evals: int = 0            # delivered results summed over ticks


class BatchedVolunteerGrid:
    """Tick-synchronous simulator over thousands of hosts.

    f_batch: (k, n) -> (k,) fitness, jit-friendly.  ``tick_batch`` is how
    many completions are drained per tick (default: n_hosts/16, ≥ 1) — the
    per-event simulator corresponds to tick_batch=1.

    Unlike the per-event simulator, which hands work to every requesting
    host, this substrate throttles issuance to ``engine.wanted() ×
    overcommit`` outstanding current-phase workunits: a phase that needs m
    results gets ~2m in flight (straggler/failure slack), not n_hosts — so
    fleet size stops multiplying evaluation cost.
    """

    def __init__(self, f_batch: Callable, cfg: GridConfig,
                 tick_batch: Optional[int] = None, overcommit: float = 2.0):
        self.f_batch = f_batch
        self.cfg = cfg
        self.speeds, self.malicious, self.rng = sample_hosts(cfg)
        self.tick_batch = tick_batch or max(1, cfg.n_hosts // 16)
        self.overcommit = overcommit
        self.stats = BatchedGridStats()

    def _eval_padded(self, pts: np.ndarray) -> np.ndarray:
        """Evaluate a (k, n) block, padding k to the next power of two so the
        jitted f_batch sees few distinct shapes."""
        import jax.numpy as jnp
        k = pts.shape[0]
        kp = 1 << max(3, (k - 1).bit_length())
        if kp != k:
            pts = np.concatenate([pts, np.repeat(pts[-1:], kp - k, axis=0)])
        ys = np.asarray(self.f_batch(jnp.asarray(pts, jnp.float32)),
                        np.float64)
        self.stats.batch_calls += 1
        return ys[:k]

    def run(self, engine: AnmEngine, max_ticks: int = 1_000_000,
            max_sim_time: float = float("inf")) -> BatchedGridStats:
        cfg = self.cfg
        rng = self.rng
        n = cfg.n_hosts
        busy = np.zeros(n, bool)
        lost = np.zeros(n, bool)      # host took work but will drop the result
        t_done = np.full(n, np.inf)
        req_phase = np.full(n, -1)    # phase_id of the workunit a host holds
        assigned: List[Optional[EvalRequest]] = [None] * n
        now = 0.0
        # hosts come online staggered, like the per-event simulator
        online = rng.uniform(0, cfg.base_eval_time / 10, n)

        while not engine.done and self.stats.ticks < max_ticks \
                and now <= max_sim_time:
            idle = np.flatnonzero(~busy & (online <= now))
            if idle.size:
                in_flight = int(np.sum(busy & (req_phase == engine.phase_id)))
                cap = int(np.ceil(engine.wanted() * self.overcommit))
                k_ask = min(int(idle.size), max(cap - in_flight, 0))
                reqs = engine.generate(k_ask) if k_ask else []
                if not reqs and engine.validating and in_flight == 0:
                    # every pending quorum replica was lost in flight: the
                    # substrate must reissue or the run would deadlock
                    r = engine.reissue_validation()
                    reqs = [r] if r is not None else []
                if reqs:
                    hosts = idle[:len(reqs)]
                    k = hosts.size
                    dt = cfg.base_eval_time / self.speeds[hosts] \
                        * rng.uniform(0.8, 1.2, k)
                    fail = rng.random(k) < cfg.failure_prob
                    self.stats.failed += int(fail.sum())
                    busy[hosts] = True
                    lost[hosts] = fail
                    # a vanishing host re-requests much later (4x the eval)
                    t_done[hosts] = now + np.where(fail, 4 * dt, dt)
                    req_phase[hosts] = [r.phase_id for r in reqs]
                    for h, r in zip(hosts, reqs):
                        assigned[h] = r
            if not busy.any():
                now += cfg.idle_retry
                continue

            # advance to the k-th earliest CURRENT-PHASE completion and drain
            # everything (stale included) that finished by then — ONE batched
            # evaluation for all of it.  k never exceeds what the phase still
            # needs: the phase commits on its first m results and later
            # arrivals go stale, so jumping past the m-th completion would
            # wait on stragglers the paper's any-m semantics exist to ignore.
            busy_idx = np.flatnonzero(busy)
            cur = busy_idx[req_phase[busy_idx] == engine.phase_id]
            want = engine.wanted()
            pool = cur if cur.size else busy_idx
            kth = min(pool.size, self.tick_batch, want if want > 0 else 1)
            horizon = np.partition(t_done[pool], kth - 1)[kth - 1]
            now = float(horizon)
            ready = busy_idx[t_done[busy_idx] <= horizon]
            ready = ready[np.lexsort((ready, t_done[ready]))]  # completion order

            delivered = ready[~lost[ready]]
            if delivered.size:
                pts = np.stack([assigned[h].point for h in delivered])
                ys = self._eval_padded(pts)
                mal = self.malicious[delivered]
                if mal.any():
                    # plausible-looking lie, same distribution as the
                    # per-event simulator's corruption model
                    ys[mal] = ys[mal] * rng.uniform(0.2, 0.8, int(mal.sum()))
                    self.stats.corrupted += int(mal.sum())
                engine.assimilate(
                    [EvalResult(assigned[h], float(y))
                     for h, y in zip(delivered, ys)])
                self.stats.completed += int(delivered.size)
                self.stats.batched_evals += int(delivered.size)
            busy[ready] = False
            lost[ready] = False
            t_done[ready] = np.inf
            req_phase[ready] = -1
            for h in ready:
                assigned[h] = None
            self.stats.ticks += 1
        self.stats.sim_time = now
        return self.stats
