"""Vectorized volunteer-grid substrate: one batched fitness call per tick.

The per-event simulator (core/grid.py) calls ``f(point)`` once per Python
event, so simulating the paper's m=1000-per-phase workloads at thousands of
hosts is Python/dispatch-bound.  This substrate keeps the same physics —
lognormal host speeds, result loss, malicious corruption, identical host
population per seed via ``grid.sample_hosts`` — but advances the whole
fleet with numpy array ops and evaluates ALL workunits completing in a tick
with a single jitted ``f_batch`` call (padded to power-of-two buckets so
XLA compiles O(log n_hosts) shapes, not one per tick).

It drives the ``AnmEngine`` event API directly: requests out, results in,
in completion-time order, so stale filtering and quorum validation behave
exactly as on the per-event grid (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.engine import AnmEngine
from repro.core.grid import GridConfig, GridStats, malicious_lie, sample_hosts
from repro.core.substrates.eval_backend import EvalBackend, InProcessEvalBackend


@dataclasses.dataclass
class BatchedGridStats(GridStats):
    ticks: int = 0
    batch_calls: int = 0
    batched_evals: int = 0            # delivered results summed over ticks


class BatchedVolunteerGrid:
    """Tick-synchronous simulator over thousands of hosts.

    f_batch: (k, n) -> (k,) fitness, jit-friendly.  ``tick_batch`` is how
    many completions are drained per tick (default: n_hosts/16, ≥ 1) — the
    per-event simulator corresponds to tick_batch=1.

    WHERE a tick's block is evaluated is a pluggable ``EvalBackend``
    (DESIGN.md §6): the default wraps ``f_batch`` in-process; pass
    ``backend=PodMeshEvalBackend(f_batch)`` to shard_map each bucket over
    the pod mesh instead — the committed iterates are bit-identical either
    way at a given engine seed.

    Unlike the per-event simulator, which hands work to every requesting
    host, this substrate throttles issuance to ``engine.wanted() ×
    overcommit`` outstanding current-phase workunits: a phase that needs m
    results gets ~2m in flight (straggler/failure slack), not n_hosts — so
    fleet size stops multiplying evaluation cost.
    """

    def __init__(self, f_batch: Optional[Callable], cfg: GridConfig,
                 tick_batch: Optional[int] = None, overcommit: float = 2.0,
                 backend: Optional[EvalBackend] = None):
        if backend is None:
            if f_batch is None:
                raise ValueError("need f_batch or an explicit backend")
            backend = InProcessEvalBackend(f_batch)
        self.backend = backend
        self.cfg = cfg
        self.speeds, self.malicious, self.rng = sample_hosts(cfg)
        self.tick_batch = tick_batch or max(1, cfg.n_hosts // 16)
        self.overcommit = overcommit
        self.stats = BatchedGridStats()

    def _eval_padded(self, pts: np.ndarray) -> np.ndarray:
        """Evaluate a (k, n) block through the backend (which pads k to its
        bucket shape, so the jitted path sees few distinct shapes)."""
        ys = self.backend(pts)
        self.stats.batch_calls += 1
        return ys

    def run(self, engine: AnmEngine, max_ticks: int = 1_000_000,
            max_sim_time: float = float("inf")) -> BatchedGridStats:
        cfg = self.cfg
        rng = self.rng
        n = cfg.n_hosts
        busy = np.zeros(n, bool)
        lost = np.zeros(n, bool)      # host took work but will drop the result
        t_done = np.full(n, np.inf)
        req_phase = np.full(n, -1)    # phase_id of the workunit a host holds
        # assignment is held in ARRAYS, not request objects — paired with
        # the engine's generate_block/assimilate_arrays fast path so a tick
        # moving thousands of results costs array ops, not object churn
        a_ticket = np.full(n, -1, np.int64)
        a_validates = np.full(n, -1, np.int64)
        a_alpha = np.full(n, np.nan)
        a_point = np.zeros((n, engine.n))
        now = 0.0
        # hosts come online staggered, like the per-event simulator
        online = rng.uniform(0, cfg.base_eval_time / 10, n)

        def issue(hosts, tickets, phase_id, pts, alphas, validates):
            k = hosts.size
            dt = cfg.base_eval_time / self.speeds[hosts] \
                * rng.uniform(0.8, 1.2, k)
            fail = rng.random(k) < cfg.failure_prob
            self.stats.failed += int(fail.sum())
            busy[hosts] = True
            lost[hosts] = fail
            # a vanishing host re-requests much later (4x the eval)
            t_done[hosts] = now + np.where(fail, 4 * dt, dt)
            req_phase[hosts] = phase_id
            a_ticket[hosts] = tickets
            a_validates[hosts] = validates
            a_alpha[hosts] = alphas
            a_point[hosts] = pts

        while not engine.done and self.stats.ticks < max_ticks \
                and now <= max_sim_time:
            idle = np.flatnonzero(~busy & (online <= now))
            if idle.size:
                in_flight = int(np.sum(busy & (req_phase == engine.phase_id)))
                cap = int(np.ceil(engine.wanted() * self.overcommit))
                k_ask = min(int(idle.size), max(cap - in_flight, 0))
                block = engine.generate_block(k_ask) if k_ask else None
                if block is not None:
                    tickets, phase_id, pts, alphas = block
                    issue(idle[:len(tickets)], tickets, phase_id, pts,
                          alphas, -1)
                elif k_ask or engine.validating:
                    # bootstrap probes and quorum replicas are handed out as
                    # objects (tiny phases); reissue a replica if every
                    # pending one was lost in flight, or the run deadlocks
                    reqs = engine.generate(k_ask) if k_ask else []
                    if not reqs and engine.validating and in_flight == 0:
                        r = engine.reissue_validation()
                        reqs = [r] if r is not None else []
                    for h, r in zip(idle, reqs):
                        issue(np.array([h]), r.ticket, r.phase_id,
                              r.point, r.alpha,
                              -1 if r.validates is None else r.validates)
            if not busy.any():
                now += cfg.idle_retry
                continue

            # advance to the k-th earliest CURRENT-PHASE completion and drain
            # everything (stale included) that finished by then — ONE batched
            # evaluation for all of it.  k never exceeds what the phase still
            # needs: the phase commits on its first m results and later
            # arrivals go stale, so jumping past the m-th completion would
            # wait on stragglers the paper's any-m semantics exist to ignore.
            busy_idx = np.flatnonzero(busy)
            cur = busy_idx[req_phase[busy_idx] == engine.phase_id]
            # while validating, the phase needs the full outstanding quorum
            # (wanted() is 0 once replicas are handed out) — jump to the
            # last missing vote in ONE tick instead of draining one replica
            # per tick
            want = (engine.validation_votes_outstanding if engine.validating
                    else engine.wanted())
            # the horizon counts LIVE completions: a host that will drop its
            # result can't contribute the k-th arrival the phase is waiting
            # for, and the simulator already knows the drop (it drew it at
            # issuance) — server-visible behavior is identical, the tick
            # just stops splitting a phase's drain on phantom arrivals
            cur_live = cur[~lost[cur]]
            pool = (cur_live if cur_live.size
                    else (cur if cur.size else busy_idx))
            kth = min(pool.size, self.tick_batch, want if want > 0 else 1)
            horizon = np.partition(t_done[pool], kth - 1)[kth - 1]
            now = float(horizon)
            ready = busy_idx[t_done[busy_idx] <= horizon]
            ready = ready[np.lexsort((ready, t_done[ready]))]  # completion order

            delivered = ready[~lost[ready]]
            if delivered.size:
                # pay f_batch only for results the engine can still use:
                # workunits from an already-finished phase are provably
                # discarded by the engine's phase_id check BEFORE it reads
                # y, so stale lanes are delivered with NaN instead of an
                # evaluation — the engine's decisions and stale counts are
                # identical, the wasted fitness work is not
                live_mask = req_phase[delivered] == engine.phase_id
                ys = np.full(delivered.size, np.nan)
                live = delivered[live_mask]
                if live.size:
                    ys_live = self._eval_padded(a_point[live])
                    mal = self.malicious[live]
                    if mal.any():
                        # same sign-safe corruption model as the per-event
                        # simulator (grid.malicious_lie)
                        ys_live[mal] = malicious_lie(
                            ys_live[mal], rng.uniform(0.2, 0.8, int(mal.sum())))
                        self.stats.corrupted += int(mal.sum())
                    ys[live_mask] = ys_live
                engine.assimilate_arrays(
                    req_phase[delivered], a_ticket[delivered],
                    a_point[delivered], a_alpha[delivered],
                    a_validates[delivered], ys)
                self.stats.completed += int(delivered.size)
                self.stats.batched_evals += int(live.size)
            busy[ready] = False
            lost[ready] = False
            t_done[ready] = np.inf
            req_phase[ready] = -1
            a_ticket[ready] = -1
            a_validates[ready] = -1
            self.stats.ticks += 1
        self.stats.sim_time = now
        return self.stats
