"""Vectorized volunteer-grid substrate: pipelined, device-resident ticks.

The per-event simulator (core/grid.py) calls ``f(point)`` once per Python
event, so simulating the paper's m=1000-per-phase workloads at thousands of
hosts is Python/dispatch-bound.  This substrate keeps the same physics —
lognormal host speeds, result loss, malicious corruption, identical host
population per seed via ``grid.sample_hosts`` — but advances the whole
fleet with numpy array ops and evaluates ALL workunits completing in a tick
with a single backend bucket (padded to power-of-two shapes so XLA compiles
O(log n_hosts) shapes, not one per tick).

Since the pipelined refactor (DESIGN.md §7) the hot loop never waits for
the device inside a phase: a tick's bucket is ``submit``ted (JAX async
dispatch) and the host immediately advances fleet physics and issues the
next block SPECULATIVELY (``engine.peek_block``) instead of blocking on
``collect``.  That is safe because, within a phase, generated points
depend only on phase state and the engine rng — never on the pending
``ys`` — and assimilating a partial phase cannot change any of that.  The
grid predicts phase flips exactly (a phase flips iff the queued live
results reach the phase's remaining ``wanted()``), drains the pipeline
with ``collect`` only when assimilation must decide a transition, and the
committed iterates are bit-identical to the non-pipelined path at the same
seed — the hard parity contract, gated in tests, dryrun and the shootout.

It drives the ``AnmEngine`` event API directly: requests out, results in,
in completion-time order, so stale filtering and quorum validation behave
exactly as on the per-event grid (DESIGN.md §3).

The run loop is RESUMABLE (DESIGN.md §8): ``run()`` is ``start()`` + a
``step()``-per-tick loop + ``finish()``, so an external driver — the
multi-search orchestrator — can interleave single ticks from several
concurrent searches over one shared backend.  WHERE a tick's bucket is
dispatched is a second seam, the ``submitter`` (default: the backend
itself): the orchestrator passes a per-search façade that coalesces
blocks from every live search into one shared tagged bucket per
scheduling round.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, NamedTuple, Optional

import numpy as np

from repro.core.engine import LINESEARCH, REGRESSION, AnmEngine
from repro.core.grid import GridConfig, GridStats, sample_hosts
from repro.core.substrates.eval_backend import (STAGING_RING, EvalBackend,
                                                EvalHandle,
                                                InProcessEvalBackend)


@dataclasses.dataclass
class BatchedGridStats(GridStats):
    ticks: int = 0
    batch_calls: int = 0
    batched_evals: int = 0            # delivered results summed over ticks
    device_blocked_s: float = 0.0     # wall seconds blocked in collect()
    host_s: float = 0.0               # wall seconds of host-side simulation
    spec_blocks: int = 0              # blocks issued speculatively (peek)
    spec_discarded: int = 0           # speculative blocks rolled back
    max_in_flight: int = 0            # deepest device pipeline reached
    bucket_hist: Dict[int, int] = dataclasses.field(default_factory=dict)


class _PendingTick(NamedTuple):
    """One tick whose bucket is in flight on the device: the submitted
    handle plus the delivered-result arrays assimilation will need."""
    handle: Optional[EvalHandle]
    d_phase: np.ndarray
    d_ticket: np.ndarray
    d_point: np.ndarray
    d_alpha: np.ndarray
    d_validates: np.ndarray
    live_mask: np.ndarray
    live_n: int


@dataclasses.dataclass
class _RunState:
    """Everything one in-progress ``run`` owns: fleet arrays, simulated
    clock, and the in-flight pipeline.  Kept separate from the grid object
    so a run is an explicit ``start``/``step``/``finish`` lifecycle the
    orchestrator can drive tick-by-tick."""
    engine: AnmEngine
    max_ticks: int
    max_sim_time: float
    busy: np.ndarray
    lost: np.ndarray                  # host took work but will drop the result
    t_done: np.ndarray
    req_phase: np.ndarray             # phase_id of the workunit a host holds
    a_ticket: np.ndarray
    a_validates: np.ndarray
    a_alpha: np.ndarray
    a_point: np.ndarray
    online: np.ndarray                # staggered start, like the per-event sim
    now: float = 0.0
    # in-flight tick buckets, oldest first, and the predicted value of
    # engine.wanted() once they all assimilate (valid iff pending is
    # nonempty; > 0 by construction — a queued tick that would reach the
    # phase's m is flushed immediately, because only then can assimilation
    # flip the phase)
    pending: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    spec_wanted: int = 0
    # host wall-clock accumulated inside start/step/finish calls only, so
    # interleaved multi-search runs don't charge each other's ticks here
    wall_s: float = 0.0
    blocked0: float = 0.0             # device_blocked_s at start()


class BatchedVolunteerGrid:
    """Tick-synchronous simulator over thousands of hosts.

    f_batch: (k, n) -> (k,) fitness, jit-friendly (it is traced inside the
    backend's bucket finalization).  ``tick_batch`` is how many completions
    are drained per tick (default: n_hosts/16, ≥ 1) — the per-event
    simulator corresponds to tick_batch=1.

    WHERE a tick's block is evaluated is a pluggable ``EvalBackend``
    (DESIGN.md §6): the default wraps ``f_batch`` in-process; pass
    ``backend=PodMeshEvalBackend(f_batch)`` to shard_map each bucket over
    the pod mesh instead — the committed iterates are bit-identical either
    way at a given engine seed.

    ``pipelined=True`` (the default) overlaps host simulation with device
    evaluation: up to ``pipeline_depth`` tick buckets ride the device
    queue while the host runs ahead issuing speculative in-phase blocks;
    ``pipelined=False`` collects every bucket synchronously (the PR-2
    behavior).  Both modes commit bit-identical iterates at a given seed.

    Unlike the per-event simulator, which hands work to every requesting
    host, this substrate throttles issuance to ``engine.wanted() ×
    overcommit`` outstanding current-phase workunits: a phase that needs m
    results gets ~2m in flight (straggler/failure slack), not n_hosts — so
    fleet size stops multiplying evaluation cost.
    """

    def __init__(self, f_batch: Optional[Callable], cfg: GridConfig,
                 tick_batch: Optional[int] = None, overcommit: float = 2.0,
                 backend: Optional[EvalBackend] = None,
                 pipelined: bool = True, pipeline_depth: int = 4,
                 submitter=None):
        if backend is None:
            if f_batch is None:
                raise ValueError("need f_batch or an explicit backend")
            backend = InProcessEvalBackend(f_batch)
        self.backend = backend
        # WHERE a tick's block is dispatched: anything with the backend's
        # submit/collect shape.  The orchestrator passes a per-search
        # coalescing façade here (DESIGN.md §8); alone, the backend itself.
        self.submitter = backend if submitter is None else submitter
        self.cfg = cfg
        self.speeds, self.malicious, self.rng = sample_hosts(cfg)
        self.tick_batch = tick_batch or max(1, cfg.n_hosts // 16)
        self.overcommit = overcommit
        self.pipelined = pipelined
        # the backend's staging rings bound how many same-shape buckets may
        # be in flight at once (zero-copy aliasing on CPU) — clamp the
        # pipeline under that with one slot of submit-before-flush slack
        self.pipeline_depth = max(1, min(pipeline_depth, STAGING_RING - 2))
        self.stats = BatchedGridStats()
        self._rs: Optional[_RunState] = None

    @property
    def in_flight(self) -> int:
        """Device buckets currently riding the pipeline (handle-less
        stale-only ticks excluded) — a live gauge for the metrics hub;
        reading it never touches the run state."""
        rs = self._rs
        if rs is None:
            return 0
        return sum(1 for t in rs.pending if t.handle is not None)

    @staticmethod
    def warm_max_bucket(m: int, overcommit: float = 2.0) -> int:
        """Largest live block a run at phase size ``m`` can deliver in one
        tick (the issuance cap plus object-path slack) — THE formula for
        pre-warming a backend's bucket ladder.  ``run()`` warms with this
        internally; external callers that construct warmed backends
        (benchmarks, dryrun) must use it too, or a changed ``overcommit``
        would silently re-introduce mid-run compiles inside their timed
        windows."""
        return int(np.ceil(m * overcommit)) + 8

    # -- the run lifecycle: start / step / finish ---------------------------
    #
    # ``run()`` is the classic single-search entry point; the three-call
    # form exists so the multi-search orchestrator (DESIGN.md §8) can
    # interleave ONE tick per live search per scheduling round over a
    # shared backend.  A tick behaves identically either way — the split
    # is pure control inversion, which is what keeps the coalesced
    # multi-search trajectories bit-identical to solo runs.

    def start(self, engine: AnmEngine, max_ticks: int = 1_000_000,
              max_sim_time: float = float("inf")) -> None:
        """Bind an engine and begin a stepwise run.  Warms the backend's
        bucket ladder (live rows per tick are bounded by the issuance cap,
        so after this no bucket shape can compile mid-run; idempotent when
        already warmed) and initializes the fleet arrays: assignment is
        held in ARRAYS, not request objects — paired with the engine's
        generate_block/assimilate_arrays fast path so a tick moving
        thousands of results costs array ops, not object churn."""
        if self._rs is not None:
            raise RuntimeError("a run is already in progress; finish() it")
        cfg = self.cfg
        n = cfg.n_hosts
        max_live = min(n, self.warm_max_bucket(
            max(engine.cfg.m_regression, engine.cfg.m_line_search),
            self.overcommit))
        # warm BEFORE the wall timer opens: a cold backend's one-time XLA
        # compiles must not be booked as this run's host time
        self.backend.warm(engine.n, max_live)
        t0 = time.perf_counter()
        rs = _RunState(
            engine=engine, max_ticks=max_ticks, max_sim_time=max_sim_time,
            busy=np.zeros(n, bool), lost=np.zeros(n, bool),
            t_done=np.full(n, np.inf), req_phase=np.full(n, -1),
            a_ticket=np.full(n, -1, np.int64),
            a_validates=np.full(n, -1, np.int64),
            a_alpha=np.full(n, np.nan), a_point=np.zeros((n, engine.n)),
            # hosts come online staggered, like the per-event simulator
            online=self.rng.uniform(0, cfg.base_eval_time / 10, n),
            blocked0=self.stats.device_blocked_s)  # host_s per-run-sane
        self._rs = rs
        rs.wall_s += time.perf_counter() - t0

    def _issue(self, rs: _RunState, hosts, tickets, phase_id, pts, alphas,
               validates):
        k = hosts.size
        dt = self.cfg.base_eval_time / self.speeds[hosts] \
            * self.rng.uniform(0.8, 1.2, k)
        fail = self.rng.random(k) < self.cfg.failure_prob
        self.stats.failed += int(fail.sum())
        rs.busy[hosts] = True
        rs.lost[hosts] = fail
        # a vanishing host re-requests much later (4x the eval)
        rs.t_done[hosts] = rs.now + np.where(fail, 4 * dt, dt)
        rs.req_phase[hosts] = phase_id
        rs.a_ticket[hosts] = tickets
        rs.a_validates[hosts] = validates
        rs.a_alpha[hosts] = alphas
        rs.a_point[hosts] = pts

    def _flush_one(self, rs: _RunState) -> None:
        p = rs.pending.popleft()
        ys = np.full(p.d_phase.size, np.nan)
        if p.handle is not None:
            t0 = time.perf_counter()
            ys_live = self.submitter.collect(p.handle)
            self.stats.device_blocked_s += time.perf_counter() - t0
            ys[p.live_mask] = ys_live
            # bucket widths are recorded at collect time: a coalesced
            # lane's width is only known once the shared round dispatches
            kp = p.handle.kp
            self.stats.bucket_hist[kp] = self.stats.bucket_hist.get(kp, 0) + 1
        rs.engine.assimilate_arrays(p.d_phase, p.d_ticket, p.d_point,
                                    p.d_alpha, p.d_validates, ys)
        self.stats.completed += int(p.d_phase.size)
        self.stats.batched_evals += int(p.live_n)

    def _flush_all(self, rs: _RunState) -> None:
        while rs.pending:
            self._flush_one(rs)

    def _throttled_ask(self, rs: _RunState, idle_n: int, wanted: int) -> int:
        """Issuance throttle: top outstanding current-phase work up to
        ``wanted × overcommit`` — the ONE definition both the
        speculative and the engine-current paths share (a one-sided
        edit here would silently break the sync==pipelined parity)."""
        in_flight = int(np.sum(rs.busy
                               & (rs.req_phase == rs.engine.phase_id)))
        cap = int(np.ceil(wanted * self.overcommit))
        return min(idle_n, max(cap - in_flight, 0))

    def step(self) -> bool:
        """Advance the bound run by one tick.  Returns False once the run
        is over (engine done, or a tick/sim-time budget hit) — the caller
        then ``finish()``es to drain the pipeline and seal the stats."""
        rs = self._rs
        if rs is None:
            raise RuntimeError("no run in progress; start() one")
        engine = rs.engine
        if engine.done or self.stats.ticks >= rs.max_ticks \
                or rs.now > rs.max_sim_time:
            return False
        t0 = time.perf_counter()
        cfg = self.cfg
        rng = self.rng
        idle = np.flatnonzero(~rs.busy & (rs.online <= rs.now))
        if idle.size:
            if rs.pending:
                # speculated state: results are still in flight, but
                # they provably cannot flip the phase (spec_wanted > 0),
                # so current-phase issuance needs no ys — generate the
                # next block via the engine's revertible peek
                k_ask = self._throttled_ask(rs, int(idle.size),
                                            rs.spec_wanted)
                if k_ask:
                    block = engine.peek_block(k_ask)
                    if block is None:
                        # the no-flip invariant guarantees a block
                        # phase here; if it ever breaks, roll the peek
                        # back and fall off the speculative path
                        engine.cancel_block()
                        self.stats.spec_discarded += 1
                        self._flush_all(rs)
                    else:
                        self.stats.spec_blocks += 1
                        tickets, phase_id, pts, alphas = block
                        self._issue(rs, idle[:len(tickets)], tickets,
                                    phase_id, pts, alphas, -1)
                        engine.accept_block()
            if not rs.pending:
                k_ask = self._throttled_ask(rs, int(idle.size),
                                            engine.wanted())
                block = engine.generate_block(k_ask) if k_ask else None
                if block is not None:
                    tickets, phase_id, pts, alphas = block
                    self._issue(rs, idle[:len(tickets)], tickets, phase_id,
                                pts, alphas, -1)
                elif k_ask or engine.validating:
                    # bootstrap probes and quorum replicas are handed
                    # out as objects (tiny phases); reissue a replica if
                    # every pending one was lost in flight, or the run
                    # deadlocks
                    reqs = engine.generate(k_ask) if k_ask else []
                    if not reqs and engine.validating and not np.any(
                            rs.busy & (rs.req_phase == engine.phase_id)):
                        r = engine.reissue_validation()
                        reqs = [r] if r is not None else []
                    for h, r in zip(idle, reqs):
                        self._issue(rs, np.array([h]), r.ticket, r.phase_id,
                                    r.point, r.alpha,
                                    -1 if r.validates is None
                                    else r.validates)
        if not rs.busy.any():
            self._flush_all(rs)
            rs.now += cfg.idle_retry
            rs.wall_s += time.perf_counter() - t0
            return True

        # advance to the k-th earliest CURRENT-PHASE completion and drain
        # everything (stale included) that finished by then — ONE batched
        # evaluation for all of it.  k never exceeds what the phase still
        # needs: the phase commits on its first m results and later
        # arrivals go stale, so jumping past the m-th completion would
        # wait on stragglers the paper's any-m semantics exist to ignore.
        busy_idx = np.flatnonzero(rs.busy)
        cur = busy_idx[rs.req_phase[busy_idx] == engine.phase_id]
        # while validating, the phase needs the full outstanding quorum
        # (wanted() is 0 once replicas are handed out) — jump to the
        # last missing vote in ONE tick instead of draining one replica
        # per tick.  With ticks in flight the phase is mid-regression/
        # line-search and the remaining need is the exact prediction.
        if rs.pending:
            want = rs.spec_wanted
        else:
            want = (engine.validation_votes_outstanding
                    if engine.validating else engine.wanted())
        # the horizon counts LIVE completions: a host that will drop its
        # result can't contribute the k-th arrival the phase is waiting
        # for, and the simulator already knows the drop (it drew it at
        # issuance) — server-visible behavior is identical, the tick
        # just stops splitting a phase's drain on phantom arrivals
        cur_live = cur[~rs.lost[cur]]
        pool = (cur_live if cur_live.size
                else (cur if cur.size else busy_idx))
        kth = min(pool.size, self.tick_batch, want if want > 0 else 1)
        horizon = np.partition(rs.t_done[pool], kth - 1)[kth - 1]
        rs.now = float(horizon)
        ready = busy_idx[rs.t_done[busy_idx] <= horizon]
        ready = ready[np.lexsort((ready, rs.t_done[ready]))]  # completion order

        delivered = ready[~rs.lost[ready]]
        tick = None
        if delivered.size:
            # pay the backend only for results the engine can still use:
            # workunits from an already-finished phase are provably
            # discarded by the engine's phase_id check BEFORE it reads
            # y, so stale lanes are delivered as NaN without an
            # evaluation — the engine's decisions and stale counts are
            # identical, the wasted fitness work is not
            live_mask = rs.req_phase[delivered] == engine.phase_id
            live = delivered[live_mask]
            handle = None
            if live.size:
                # corruption ships WITH the bucket as mask lanes (NaN ==
                # honest) and is applied on-device; same sign-safe model
                # and rng draw order as the per-event simulator
                mal = self.malicious[live]
                mal_u = np.full(live.size, np.nan)
                if mal.any():
                    mal_u[mal] = rng.uniform(0.2, 0.8, int(mal.sum()))
                    self.stats.corrupted += int(mal.sum())
                handle = self.submitter.submit(rs.a_point[live], mal_u)
                self.stats.batch_calls += 1
            tick = _PendingTick(handle, rs.req_phase[delivered],
                                rs.a_ticket[delivered],
                                rs.a_point[delivered],
                                rs.a_alpha[delivered],
                                rs.a_validates[delivered],
                                live_mask, int(live.size))
        rs.busy[ready] = False
        rs.lost[ready] = False
        rs.t_done[ready] = np.inf
        rs.req_phase[ready] = -1
        rs.a_ticket[ready] = -1
        rs.a_validates[ready] = -1
        self.stats.ticks += 1

        if tick is not None:
            if rs.pending:
                base = rs.spec_wanted
                block_phase = True       # invariant: mid-REG/LS
            else:
                block_phase = engine.phase in (REGRESSION, LINESEARCH)
                base = engine.wanted() if block_phase else 0
            rs.pending.append(tick)
            # depth counts actual device buckets, not handle-less
            # stale-only ticks riding the queue
            self.stats.max_in_flight = max(
                self.stats.max_in_flight,
                sum(1 for t in rs.pending if t.handle is not None))
            if (self.pipelined and block_phase
                    and base - tick.live_n > 0):
                # in-phase results (a stale-only tick included: its
                # live_n of 0 cannot flip anything): defer the collect,
                # keep the device busy while the host runs ahead
                rs.spec_wanted = base - tick.live_n
                if len(rs.pending) >= self.pipeline_depth:
                    self._flush_one(rs)
            else:
                # this bucket reaches the phase's m (or the phase is
                # bootstrap/validating, whose votes decide transitions):
                # assimilation must decide, so drain the pipeline
                self._flush_all(rs)
        rs.wall_s += time.perf_counter() - t0
        return True

    def finish(self) -> BatchedGridStats:
        """Drain the pipeline, seal sim-time and the host/device wall split,
        and release the run state.  Safe to call on a run stopped early
        (the orchestrator's portfolio kill does exactly that)."""
        rs = self._rs
        if rs is None:
            raise RuntimeError("no run in progress; start() one")
        t0 = time.perf_counter()
        self._flush_all(rs)
        self.stats.sim_time = rs.now
        rs.wall_s += time.perf_counter() - t0
        # accumulate like every other stats field: this run's in-call wall
        # minus this run's device-blocked share (not the all-runs
        # cumulative, and not other searches' ticks between our steps)
        self.stats.host_s += rs.wall_s - (self.stats.device_blocked_s
                                          - rs.blocked0)
        self._rs = None
        return self.stats

    def run(self, engine: AnmEngine, max_ticks: int = 1_000_000,
            max_sim_time: float = float("inf")) -> BatchedGridStats:
        self.start(engine, max_ticks, max_sim_time)
        while self.step():
            pass
        return self.finish()
