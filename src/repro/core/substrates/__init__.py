"""Computing substrates that drive the shared ANM engine (DESIGN.md §1).

A substrate owns hosts, time and fitness evaluation; the engine owns every
optimization decision.  The synchronous driver lives in core/anm.py and the
BOINC-style asynchronous server in core/fgdo.py for historical import
stability; new substrates live here.
"""
from repro.core.substrates.batched_grid import BatchedVolunteerGrid  # noqa: F401
