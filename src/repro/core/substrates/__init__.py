"""Computing substrates that drive the shared ANM engine (DESIGN.md §1).

A substrate owns hosts, time and fitness evaluation; the engine owns every
optimization decision.  The synchronous driver lives in core/anm.py and the
BOINC-style asynchronous server in core/fgdo.py for historical import
stability; new substrates live here.

WHERE a substrate evaluates its workunit blocks is a second, orthogonal
seam — ``EvalBackend`` (DESIGN.md §6–§7): an asynchronous submit/collect
protocol, in-process on the local device by default, or shard_mapped over
the production pod mesh (``pod_mesh.PodMeshEvalBackend``).
"""
from repro.core.substrates.batched_grid import BatchedVolunteerGrid  # noqa: F401
from repro.core.substrates.eval_backend import (  # noqa: F401
    EvalBackend, EvalHandle, InProcessEvalBackend)
from repro.core.substrates.eval_cache import (  # noqa: F401
    CacheStats, CachingSubmitter, EvalCache, JsonlCacheStore,
    MemoryCacheStore, SqliteCacheStore)
from repro.core.substrates.lm_loss import (  # noqa: F401
    LmLossEvalBackend, LmWorkload, make_lm_workload)
from repro.core.substrates.pod_mesh import PodMeshEvalBackend  # noqa: F401
