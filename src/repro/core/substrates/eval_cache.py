"""Persistent cross-search evaluation cache (DESIGN.md §10).

A bit-exact memo layer in front of ``EvalBackend.submit``: every honest
lane a backend ever evaluated can be SERVED instead of re-dispatched, as
long as the staged point is byte-identical and the objective fingerprint
matches.  The paper's economics make volunteer-grid evaluations the
expensive resource, so validation replicas, restarted searches and
crash-restored runs — all of which re-issue byte-identical points — should
pay for a fitness evaluation exactly once.

Why bit-exact-only serving is safe (the determinism argument, pinned by
the parity gates): the backend stages every block as float32
(``buf[:k] = pts``), and the repo-wide row-independence + width-invariance
contract (DESIGN.md §8) already established that a lane's value is a pure
function of its staged f32 bytes — independent of bucket width, bucket
composition and collect timing.  A cache keyed on exactly those bytes
(plus an objective fingerprint) therefore serves the SAME value the
dispatch would have produced, so cache-on runs commit bit-identical
iterates and identical ``EngineStats`` to cache-off runs, on any backend.
Near-miss (quantized) keys are deliberately NOT supported: they would
trade that guarantee for hit rate.

Canonicalization: keys are the f32 bytes of the staged row after mapping
every NaN payload to the canonical quiet NaN and -0.0 to +0.0 (the
objective cannot distinguish them: f(-0.0) == f(+0.0) bitwise for any
even-remotely-sane fitness, and the engine never produces signed zeros on
purpose).  Two float64 points that round to the same f32 row are the same
key — exactly the backend's own staging equivalence.

Malicious lanes (``mal_u`` non-NaN) are NEVER cached and NEVER served:
their value is the corrupted lie, a function of the per-(host, workunit)
draw, not of the point — and quorum validation exists precisely to
re-evaluate suspect results, so short-circuiting it would change what the
validator sees.  Honest validation replicas MAY be served: they carry the
deterministic true value by construction, which is what the quorum
compares.  Non-finite results are not cached either (a NaN fitness has no
reuse value and NaN payloads do not survive every store backend).

``CachingSubmitter`` is ``EvalBackend``-shaped (``submit``/``collect``/
``__call__``/``warm``/``min_bucket``), so it drops into every seam a
backend goes: a grid's ``submitter``/``backend``, the coalescer's inner
backend (cache stripping then applies to the whole shared multi-search
bucket), or the simulated client pool's sync-call backend.  On ``submit``
the exact-hit lanes are STRIPPED from the bucket before dispatch — a
bucket whose misses fit a smaller ladder width dispatches at that smaller
width (width invariance again), and a fully-served bucket dispatches
nothing at all — then spliced back at ``collect``.

Persistence is a seam: ``MemoryCacheStore`` (default),
``JsonlCacheStore`` (append-only, SIGKILL-torn-tail tolerant like the
server's replay log, exact float64 via JSON repr round-trip) and
``SqliteCacheStore`` (stdlib sqlite3).  The server composition —
cache file inside the checkpoint dir, flushed at every snapshot — lives
in ``repro/server/checkpoint.py``/``sim.py`` (DESIGN.md §10).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.substrates.eval_backend import (STAGING_RING, bucket_size)


def canonical_block(pts: np.ndarray) -> np.ndarray:
    """The (k, n) float32 block the backend would stage, canonicalized
    for byte-keying: every NaN becomes THE quiet NaN, -0.0 becomes +0.0.
    The f32 cast is the same C round-to-nearest the backend's
    ``buf[:k] = pts`` assignment performs, so two inputs share a key iff
    they stage identically."""
    a = np.array(pts, np.float32, copy=True)
    if a.ndim == 1:
        a = a[None, :]
    nan = np.isnan(a)
    if nan.any():
        a[nan] = np.float32(np.nan)
    zero = a == 0.0                   # matches both +0.0 and -0.0
    if zero.any():
        a[zero] = np.float32(0.0)
    return np.ascontiguousarray(a)


@dataclasses.dataclass
class CacheStats:
    """Observability counters, shared by every submitter attached to one
    ``EvalCache`` (that sharing IS the cross-search story).  ``hits`` is
    also the lanes-saved count: every hit lane is stripped from its
    bucket before dispatch."""
    hits: int = 0                     # lanes served (== lanes stripped)
    misses: int = 0                   # honest lanes that had to dispatch
    mal_bypassed: int = 0             # malicious lanes (never looked up)
    stores: int = 0                   # new values inserted into the store
    full_buckets: int = 0             # submits fully served (no dispatch)
    ring_drains: int = 0              # early collects for ring pressure

    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)


# -- persistence seam ----------------------------------------------------------


class MemoryCacheStore:
    """The default store: a dict, process-lifetime only."""

    def __init__(self):
        self._d: Dict[bytes, float] = {}

    def get(self, key: bytes) -> Optional[float]:
        return self._d.get(key)

    def put(self, key: bytes, y: float) -> bool:
        """Insert-if-absent; returns True when a new entry landed.  A
        second put of one key is a no-op on purpose — values are
        deterministic, so the first writer is as right as any."""
        if key in self._d:
            return False
        self._d[key] = y
        return True

    def __len__(self) -> int:
        return len(self._d)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlCacheStore(MemoryCacheStore):
    """Append-only JSONL persistence over the in-memory dict: one
    ``{"k": hex-key, "y": value}`` record per insert, flushed every
    ``flush_every`` puts (and on ``flush``/``close``) — the same
    durability model as the server's replay log: a SIGKILL loses only an
    unflushed SUFFIX, never corrupts the prefix.  Loading tolerates a
    torn trailing line (the kill's half-append) and truncates it so
    resumed appends start on a fresh line; float64 values round-trip
    exactly through JSON repr."""

    def __init__(self, path: str, flush_every: int = 64):
        super().__init__()
        self.path = path
        self.flush_every = max(int(flush_every), 1)
        self._since_flush = 0
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._truncate_torn_tail(path)
        try:
            with open(path) as f:
                for line in f:
                    if not line.endswith("\n"):
                        break         # torn tail: stop, don't die
                    try:
                        rec = json.loads(line)
                        self._d[bytes.fromhex(rec["k"])] = float(rec["y"])
                    except (ValueError, KeyError, TypeError):
                        break         # corrupt tail record: stop, don't die
        except FileNotFoundError:
            pass
        self._f = open(path, "a")

    @staticmethod
    def _truncate_torn_tail(path: str) -> int:
        """Drop a SIGKILL-torn trailing partial line so post-restore
        appends never concatenate onto the fragment (same rationale as
        ``ReplayLog.repair``).  Returns bytes dropped."""
        try:
            with open(path, "rb+") as f:
                data = f.read()
                if not data or data.endswith(b"\n"):
                    return 0
                keep = data.rfind(b"\n") + 1
                f.truncate(keep)
                return len(data) - keep
        except FileNotFoundError:
            return 0

    def put(self, key: bytes, y: float) -> bool:
        if not super().put(key, y):
            return False
        self._f.write(json.dumps({"k": key.hex(), "y": float(y)},
                                 separators=(",", ":")) + "\n")
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()
        return True

    def flush(self) -> None:
        self._f.flush()
        self._since_flush = 0

    def close(self) -> None:
        self.flush()
        self._f.close()


class SqliteCacheStore:
    """Stdlib sqlite3 persistence: one ``(key BLOB PRIMARY KEY, y REAL)``
    table, committed every ``flush_every`` inserts.  REAL is float64, so
    values round-trip exactly (non-finite values are never stored — the
    submitter filters them — which sidesteps sqlite's NaN-to-NULL
    coercion)."""

    def __init__(self, path: str, flush_every: int = 64):
        import sqlite3

        self.path = path
        self.flush_every = max(int(flush_every), 1)
        self._since_flush = 0
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._db = sqlite3.connect(path)
        self._db.execute(
            "CREATE TABLE IF NOT EXISTS eval_cache "
            "(key BLOB PRIMARY KEY, y REAL NOT NULL)")
        self._db.commit()

    def get(self, key: bytes) -> Optional[float]:
        row = self._db.execute(
            "SELECT y FROM eval_cache WHERE key = ?", (key,)).fetchone()
        return None if row is None else float(row[0])

    def put(self, key: bytes, y: float) -> bool:
        cur = self._db.execute(
            "INSERT OR IGNORE INTO eval_cache (key, y) VALUES (?, ?)",
            (key, float(y)))
        if cur.rowcount <= 0:
            return False
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()
        return True

    def __len__(self) -> int:
        return int(self._db.execute(
            "SELECT COUNT(*) FROM eval_cache").fetchone()[0])

    def flush(self) -> None:
        self._db.commit()
        self._since_flush = 0

    def close(self) -> None:
        self.flush()
        self._db.close()


# -- the cache + its submitter -------------------------------------------------


class EvalCache:
    """Key derivation + store + shared counters.  ``fingerprint`` is the
    objective/spec identity (any stable string naming the fitness
    function and its data); its digest prefixes every key, so two caches
    over different objectives can share one store without ever serving
    each other's values (the isolation pin in the tests)."""

    def __init__(self, store=None, fingerprint: str = ""):
        self.store = MemoryCacheStore() if store is None else store
        self.fingerprint = fingerprint
        self._prefix = hashlib.sha256(fingerprint.encode()).digest()[:12]
        self.stats = CacheStats()

    def key_block(self, pts: np.ndarray) -> List[bytes]:
        blk = canonical_block(pts)
        prefix = self._prefix
        return [prefix + row.tobytes() for row in blk]

    def key(self, pt: np.ndarray) -> bytes:
        return self.key_block(np.asarray(pt)[None, :])[0]

    def __len__(self) -> int:
        return len(self.store)

    def status(self) -> dict:
        """The read-only counter doc surfaced by the wire protocol's
        ``status`` reply and the examples."""
        s = self.stats
        return {"hits": s.hits, "misses": s.misses,
                "lanes_saved": s.hits, "mal_bypassed": s.mal_bypassed,
                "stores": s.stores, "full_buckets": s.full_buckets,
                "hit_rate": s.hit_rate(), "store_size": len(self.store)}


class _CachedHandle:
    """In-flight submit through the cache: the inner backend handle (over
    the MISS lanes only, ``None`` when fully served) plus the splice
    plan.  Quacks enough like an ``EvalHandle`` (``kp``, ``seq``) for
    every consumer that inspects handles — a fully-served bucket reports
    ``kp == 0``, the honest width it paid."""
    __slots__ = ("inner", "k", "keys", "miss_idx", "hit_idx", "hit_vals",
                 "store_mask", "tags", "seq", "ys")

    def __init__(self, inner, k, keys, miss_idx, hit_idx, hit_vals,
                 store_mask, tags, seq):
        self.inner = inner
        self.k = k
        self.keys = keys
        self.miss_idx = miss_idx      # positions dispatched to the backend
        self.hit_idx = hit_idx        # positions served from the cache
        self.hit_vals = hit_vals
        self.store_mask = store_mask  # which dispatched lanes may be stored
        self.tags = tags
        self.seq = seq
        self.ys: Optional[np.ndarray] = None

    @property
    def kp(self) -> int:
        return 0 if self.inner is None else self.inner.kp


class CachingSubmitter:
    """The memo layer: an ``EvalBackend``-shaped wrapper that strips
    exact-hit honest lanes from every submitted bucket, dispatches only
    the misses (at the smaller ladder width they now fit), and splices
    the served values back at ``collect``.

    Ring safety: stripping changes dispatched bucket shapes, so shapes
    that were distinct upstream can collapse onto ONE inner staging ring
    — upstream pressure accounting (the coalescer's, a grid's depth
    clamp, the scheduler's shared guard) is keyed on pre-strip widths and
    cannot see that.  The submitter therefore keeps its own per-inner-
    shape in-flight deques and materializes the oldest handle early when
    a submit would overrun the ring (the §7 contract makes early collects
    invisible to engines; ``collect`` is idempotent via the cached
    ``ys``)."""

    def __init__(self, backend, cache: Optional[EvalCache] = None):
        self.backend = backend
        self.cache = EvalCache() if cache is None else cache
        self._inflight: Dict[int, Deque[_CachedHandle]] = {}
        self._seq = 0

    @property
    def min_bucket(self) -> int:
        return self.backend.min_bucket

    @property
    def compile_count(self) -> int:
        return self.backend.compile_count

    def warm(self, n_dims: int, max_k: int) -> "CachingSubmitter":
        self.backend.warm(n_dims, max_k)
        return self

    def submit(self, pts: np.ndarray,
               mal_u: Optional[np.ndarray] = None,
               lane_tags: Optional[np.ndarray] = None) -> _CachedHandle:
        pts = np.asarray(pts)
        k = len(pts)
        keys = self.cache.key_block(pts)
        stats = self.cache.stats
        store = self.cache.store
        if mal_u is None:
            honest = np.ones(k, bool)
        else:
            mal_u = np.asarray(mal_u, np.float64)
            honest = np.isnan(mal_u)
        hit = np.zeros(k, bool)
        hit_vals: List[float] = []
        for i in range(k):
            if not honest[i]:
                stats.mal_bypassed += 1   # no lookup, no store: quorum
                continue                  # validation must re-evaluate
            y = store.get(keys[i])
            if y is None:
                stats.misses += 1
            else:
                hit[i] = True
                hit_vals.append(y)
                stats.hits += 1
        miss_idx = np.flatnonzero(~hit)
        hit_idx = np.flatnonzero(hit)
        self._seq += 1
        tags = None if lane_tags is None else np.asarray(lane_tags)
        handle = _CachedHandle(None, k, keys, miss_idx, hit_idx,
                               np.asarray(hit_vals, np.float64), honest,
                               tags, self._seq)
        if len(miss_idx) == 0:            # fully served: no dispatch at all
            stats.full_buckets += 1
            return handle
        if len(miss_idx) == k:            # nothing served: dispatch as-is
            handle.inner = self._guarded_submit(
                k, pts, mal_u, lane_tags, handle)
        else:
            handle.inner = self._guarded_submit(
                len(miss_idx), pts[miss_idx],
                None if mal_u is None else mal_u[miss_idx],
                None if tags is None else tags[miss_idx], handle)
        return handle

    def _guarded_submit(self, n_miss, pts, mal_u, lane_tags, handle):
        """Drain this inner shape's oldest in-flight handles below the
        ring bound, then dispatch and track."""
        kp = bucket_size(n_miss, self.backend.min_bucket)
        dq = self._inflight.setdefault(kp, collections.deque())
        # positional ring (slots rotate round-robin): everything older
        # than the newest ring-2 submissions of this shape must be
        # materialized before staging another — already-collected handles
        # hold no slot and are not pressure
        while len(dq) > STAGING_RING - 2:
            old = dq.popleft()
            if old.ys is None:
                self._materialize(old)
                self.cache.stats.ring_drains += 1
        inner = self.backend.submit(pts, mal_u, lane_tags=lane_tags)
        dq.append(handle)
        return inner

    def _materialize(self, handle: _CachedHandle) -> None:
        ys = np.empty(handle.k, np.float64)
        if handle.inner is not None:
            got = self.backend.collect(handle.inner)
            ys[handle.miss_idx] = got
            stats = self.cache.stats
            store = self.cache.store
            for j, i in enumerate(handle.miss_idx):
                # store honest, finite results only — malicious lies are
                # per-(host, wu) draws, and NaN carries no reuse value
                if handle.store_mask[i] and np.isfinite(got[j]):
                    if store.put(handle.keys[i], float(got[j])):
                        stats.stores += 1
        if len(handle.hit_idx):
            ys[handle.hit_idx] = handle.hit_vals
        handle.ys = ys

    def collect(self, handle: _CachedHandle) -> np.ndarray:
        if handle.ys is None:
            self._materialize(handle)
        return handle.ys

    def __call__(self, pts: np.ndarray,
                 mal_u: Optional[np.ndarray] = None) -> np.ndarray:
        return self.collect(self.submit(pts, mal_u))
