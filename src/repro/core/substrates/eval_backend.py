"""Evaluation backends for the batched-grid substrate (DESIGN.md §6).

The batched grid decides WHICH points a tick evaluates; a backend decides
HOW that block of points turns into fitness values.  The seam is one call:

    ys = backend(pts)          # (k, n) float block -> (k,) float64

Every backend pads ``k`` up to a fixed power-of-two bucket before
evaluating, so the jitted evaluation function sees O(log k_max) distinct
shapes over a whole run instead of one shape per tick.  The pad lanes
repeat the last real point and are masked off the returned block — never
dropped, so remainder workunits cost a little redundant compute but no
correctness.  Bucket shapes depend only on the block size (and the
backend's shard count floor), NOT on the grid's host count.

Two backends ship with the repo:

  * ``InProcessEvalBackend`` — the default: one jitted ``f_batch`` call on
    the local device (what ``BatchedVolunteerGrid`` inlined before the
    seam existed);
  * ``substrates/pod_mesh.py::PodMeshEvalBackend`` — ``shard_map``s each
    bucket over the ``data`` axis of the production pod mesh.
"""
from __future__ import annotations

from typing import Callable

import numpy as np


def bucket_size(k: int, min_bucket: int = 8) -> int:
    """Smallest power of two ≥ max(k, min_bucket).  ``min_bucket`` must be
    a power of two (backends use their shard count, which is)."""
    if min_bucket & (min_bucket - 1):
        raise ValueError(f"min_bucket must be a power of two, got {min_bucket}")
    return max(min_bucket, 1 << max(k - 1, 0).bit_length())


class EvalBackend:
    """Base class: pad-to-bucket framing around a subclass evaluation.

    Subclasses implement ``_eval_bucket((kp, n) block) -> (kp,) fitness``
    for ``kp`` already padded to a power-of-two multiple of the backend's
    lane count; this class owns padding and remainder masking so every
    backend frames blocks identically (a parity requirement: same engine
    seed must mean the same committed iterates on any backend).
    """

    min_bucket: int = 8

    def __call__(self, pts: np.ndarray) -> np.ndarray:
        k = pts.shape[0]
        kp = bucket_size(k, self.min_bucket)
        if kp != k:
            pts = np.concatenate([pts, np.repeat(pts[-1:], kp - k, axis=0)])
        ys = np.asarray(self._eval_bucket(pts), np.float64)
        return ys[:k]

    def _eval_bucket(self, pts: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class InProcessEvalBackend(EvalBackend):
    """Default backend: one jitted ``f_batch`` call on the local device.

    f_batch: (kp, n) -> (kp,) fitness, jit-friendly.
    """

    def __init__(self, f_batch: Callable, min_bucket: int = 8):
        self.f_batch = f_batch
        self.min_bucket = bucket_size(1, min_bucket)

    def _eval_bucket(self, pts: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp
        return self.f_batch(jnp.asarray(pts, jnp.float32))
