"""Evaluation backends for the batched-grid substrate (DESIGN.md §6–§7).

The batched grid decides WHICH points a tick evaluates; a backend decides
HOW that block of points turns into fitness values.  Since the pipelined
refactor the seam is an asynchronous two-call protocol:

    handle = backend.submit(pts, mal_u)    # frame + dispatch, returns now
    ys = backend.collect(handle)           # block on the device, unpad

``submit`` leans on JAX async dispatch: it returns as soon as the bucket
is enqueued on the device, so the caller overlaps host simulation work
(fleet physics, speculative work generation) with the evaluation and only
pays for the device when ``collect`` materializes the result.  The
synchronous form ``backend(pts, mal_u)`` remains ``collect(submit(...))``,
so non-pipelined callers are unchanged.

Framing.  Every block of ``k`` points is written into a PERSISTENT
per-bucket staging buffer padded up to a power-of-two bucket (pad lanes
repeat the last real point), so the steady state pays one buffer fill per
tick — no per-tick ``np.concatenate``/``np.repeat`` allocations, and on
CPU the XLA client aliases the numpy buffer outright (zero copy): the
staging buffers ARE the device buffers.  That aliasing is exactly why
they form a RING (``STAGING_RING`` deep) per bucket size: a submitted
bucket may still be reading its buffer while the host stages the next
tick, so consecutive submits of one shape rotate through distinct
buffers, classic double-buffering — callers may keep at most
``STAGING_RING`` handles of one bucket shape in flight (enforced per
ring slot, so collecting out of order cannot defeat the check: a
``submit`` that would restage an uncollected handle's buffer raises
instead of silently corrupting it; the pipelined grid clamps its queue
depth well under that).  Bucket shapes depend only on the block size
(and the
backend's shard-count floor), NOT on the grid's host count, so the jitted
path sees O(log k_max) distinct shapes over a whole run; ``warm()``
compiles that whole ladder up front (backends constructed with
``n_dims``/``max_bucket`` warm at construction), so a warmed backend
performs ZERO compiles mid-run — pinned by the ``compile_count`` probe in
the substrate tests.

Results come back FINAL (DESIGN.md §7): the jitted bucket finalization
applies the sign-safe malicious corruption ``grid.malicious_lie`` to the
lanes whose ``mal_u`` draw is non-NaN and masks the pad lanes to NaN
on-device, so ``collect`` never patches values on the host after a
blocking fetch.

Two backends ship with the repo:

  * ``InProcessEvalBackend`` — the default: one ``f_batch`` call on the
    local device inside the shared bucket finalization;
  * ``substrates/pod_mesh.py::PodMeshEvalBackend`` — ``shard_map``s each
    bucket over the ``data`` axis of the production pod mesh.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import numpy as np

from repro.core.grid import malicious_lie

#: THE bucket floor, documented once: blocks smaller than this are padded
#: up to it so tiny phases (the bootstrap probe, quorum replicas) reuse one
#: small compiled shape instead of compiling per exact size.  Backends with
#: stricter needs (the pod mesh's rows-per-shard floor) raise it; callers
#: may lower it to any power of two >= 1.
DEFAULT_MIN_BUCKET = 8

#: staging buffers per bucket shape.  XLA CPU zero-copies numpy inputs, so
#: a buffer must not be restaged while its bucket is still in flight; a
#: ring this deep supports up to STAGING_RING simultaneously in-flight
#: buckets of one shape — restaging a slot whose handle is uncollected
#: raises (per-slot flags, so out-of-order collects are handled exactly).
STAGING_RING = 8


def bucket_size(k: int, min_bucket: int = DEFAULT_MIN_BUCKET) -> int:
    """Smallest power of two ≥ max(k, min_bucket).  ``min_bucket`` must be
    a power of two (backends use their shard count, which is)."""
    if min_bucket & (min_bucket - 1):
        raise ValueError(f"min_bucket must be a power of two, got {min_bucket}")
    return max(min_bucket, 1 << max(k - 1, 0).bit_length())


class EvalHandle(NamedTuple):
    """An in-flight bucket evaluation returned by ``EvalBackend.submit``.

    ``ys`` is the (kp,) device array still materializing under async
    dispatch; touching it with ``np.asarray`` (what ``collect`` does)
    blocks until the device is done.  ``k`` is the number of real lanes,
    ``kp`` the padded bucket width, ``slot`` the staging-ring slot the
    bucket aliases until collected, and ``seq`` the submission's ownership
    token for that slot (a stale or double ``collect`` must not free a
    slot now owned by a newer submission).  ``tags`` is the per-lane
    submitter-id array shipped with a coalesced multi-search bucket
    (DESIGN.md §8) — host-side framing metadata for observability and
    debugging (which search owns each lane); never read by the device
    computation, and not the demux mechanism either (consumers slice by
    lane offsets).  ``None`` for single-submitter buckets.
    """
    ys: Any
    k: int
    kp: int
    slot: int
    seq: int
    tags: Any = None


class EvalBackend:
    """Base class: persistent-buffer bucket framing + on-device result
    finalization around a subclass evaluation.

    Subclasses implement ``_raw_eval((kp, n) f32 block) -> (kp,) fitness``
    — traced inside this class's jitted finalization — for ``kp`` already
    padded to a power-of-two multiple of the backend's lane count.  This
    class owns padding, the malicious-corruption lanes, and pad-lane NaN
    masking, so every backend frames and finalizes blocks identically (a
    parity requirement: the same engine seed must commit the same iterates
    on any backend, pipelined or not).
    """

    def __init__(self, min_bucket: int = DEFAULT_MIN_BUCKET):
        if min_bucket < 1 or (min_bucket & (min_bucket - 1)):
            raise ValueError(
                f"min_bucket must be a power of two >= 1, got {min_bucket}")
        self.min_bucket = min_bucket
        self._bufs: dict = {}            # kp -> ring of ((kp, n), (kp,)) bufs
        self._ring: dict = {}            # kp -> next ring slot
        self._slot_owner: dict = {}      # kp -> per-slot owning seq (or None)
        self._submit_seq = 0             # ownership tokens for ring slots
        self._warmed: set = set()        # (n_dims, kp) already compiled
        #: number of bucket-shape traces performed — a warmed backend must
        #: not grow this mid-run (the zero-compile probe in the tests)
        self.compile_count = 0
        self._eval = self._make_bucket_eval()

    # -- subclass seam -------------------------------------------------------

    def _raw_eval(self, pts):
        """(kp, n) f32 bucket -> (kp,) fitness; called under jit trace."""
        raise NotImplementedError

    def _make_bucket_eval(self):
        import jax
        import jax.numpy as jnp

        def bucket_eval(pts, u, k):
            # this body runs at TRACE time only: one execution per bucket
            # shape, which is exactly what compile_count must count
            self.compile_count += 1
            ys = self._raw_eval(pts)
            # malicious corruption as mask lanes: NaN u == honest lane
            ys = jnp.where(jnp.isnan(u), ys, malicious_lie(ys, u))
            # pad/overhang lanes come back NaN from the device — results
            # are final on arrival, never patched on host
            return jnp.where(jnp.arange(pts.shape[0]) < k, ys, jnp.nan)

        return jax.jit(bucket_eval)

    # -- framing -------------------------------------------------------------

    def _staging(self, kp: int, n: int):
        """Next (points, mal_u, slot) staging triple in the bucket's ring.
        The rotation is what makes restaging safe under async dispatch:
        the previous slots may still be aliased by in-flight buckets —
        and a slot whose bucket is STILL uncollected refuses to restage
        (zero-copy aliasing would silently corrupt it otherwise)."""
        ring = self._bufs.get(kp)
        if ring is None or ring[0][0].shape[1] != n:
            ring = self._bufs[kp] = [
                (np.zeros((kp, n), np.float32),
                 np.full(kp, np.nan, np.float32))
                for _ in range(STAGING_RING)]
            self._ring[kp] = 0
            self._slot_owner[kp] = [None] * STAGING_RING
        slot = self._ring[kp]
        if self._slot_owner[kp][slot] is not None:
            raise RuntimeError(
                f"an uncollected submission still aliases staging slot "
                f"{slot} of bucket shape {kp} (ring depth {STAGING_RING}); "
                f"collect() in-flight handles before submitting more")
        self._ring[kp] = (slot + 1) % STAGING_RING
        return ring[slot][0], ring[slot][1], slot

    def warm(self, n_dims: int, max_k: int) -> "EvalBackend":
        """Compile AND execute the whole bucket ladder (min_bucket up to
        ``bucket_size(max_k)``) so no compile ever lands mid-run, and
        preallocate the persistent staging buffers.  Idempotent: already
        warmed (n_dims, bucket) cells are skipped, so re-warming at the
        start of every ``BatchedVolunteerGrid.run`` costs nothing."""
        handles = []
        kp = bucket_size(1, self.min_bucket)
        top = bucket_size(max_k, self.min_bucket)
        while True:
            if (n_dims, kp) not in self._warmed:
                pts, u, _ = self._staging(kp, n_dims)
                handles.append(self._eval(pts, u, np.int32(kp)))
                self._warmed.add((n_dims, kp))
            if kp >= top:
                break
            kp *= 2
        for h in handles:
            h.block_until_ready()
        return self

    # -- the async protocol --------------------------------------------------

    def submit(self, pts: np.ndarray,
               mal_u: Optional[np.ndarray] = None,
               lane_tags: Optional[np.ndarray] = None) -> EvalHandle:
        """Frame a (k, n) block into its bucket and dispatch the evaluation
        asynchronously.  ``mal_u``: per-lane malicious draw in [0.2, 0.8],
        NaN for honest lanes (None == all honest).  ``lane_tags``: optional
        (k,) per-lane submitter ids for coalesced multi-search buckets —
        carried on the handle so every in-flight bucket is attributable
        lane by lane (observability/debugging; demux itself is positional,
        by lane offset).  The device computation never sees them (lanes
        are row-independent, which is exactly why coalescing is safe).
        Returns immediately; pass the handle to ``collect`` for the
        values."""
        k, n = pts.shape
        kp = bucket_size(k, self.min_bucket)
        buf, ubuf, slot = self._staging(kp, n)
        self._submit_seq += 1
        self._slot_owner[kp][slot] = self._submit_seq
        buf[:k] = pts
        if mal_u is None:
            ubuf[:k] = np.nan
        else:
            ubuf[:k] = mal_u
        if kp != k:
            buf[k:] = buf[k - 1]
            ubuf[k:] = np.nan
        self._warmed.add((n, kp))    # a lazy compile still warms the cell
        return EvalHandle(self._eval(buf, ubuf, np.int32(k)), k, kp, slot,
                          self._submit_seq,
                          None if lane_tags is None
                          else np.asarray(lane_tags))

    def collect(self, handle: EvalHandle) -> np.ndarray:
        """Materialize a submitted bucket (blocks until the device is
        done), free its staging slot, and strip the pad lanes.  The slot
        is freed only if this handle still OWNS it — a double collect, or
        one stale across a ring reallocation, must not clear the flag
        guarding a newer in-flight submission."""
        owners = self._slot_owner.get(handle.kp)
        if owners is not None and owners[handle.slot] == handle.seq:
            owners[handle.slot] = None
        return np.asarray(handle.ys, np.float64)[:handle.k]

    def __call__(self, pts: np.ndarray,
                 mal_u: Optional[np.ndarray] = None) -> np.ndarray:
        return self.collect(self.submit(pts, mal_u))


class InProcessEvalBackend(EvalBackend):
    """Default backend: the bucket is one ``f_batch`` call on the local
    device, inside the shared jitted finalization.

    f_batch: (kp, n) -> (kp,) fitness, jit-friendly (it is traced).
    ``min_bucket`` is validated directly as a power of two — it is NOT
    rounded through ``bucket_size``, whose job is sizing blocks, and the
    default floor lives in one place (``DEFAULT_MIN_BUCKET``).  Pass
    ``n_dims`` + ``max_bucket`` to warm the bucket ladder at construction
    (zero compiles afterwards).
    """

    def __init__(self, f_batch: Callable,
                 min_bucket: int = DEFAULT_MIN_BUCKET, *,
                 n_dims: Optional[int] = None,
                 max_bucket: Optional[int] = None):
        self.f_batch = f_batch
        super().__init__(min_bucket)
        if n_dims is not None and max_bucket is not None:
            self.warm(n_dims, max_bucket)

    def _raw_eval(self, pts):
        return self.f_batch(pts)
