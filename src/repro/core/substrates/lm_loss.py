"""LM-loss evaluation backend: the engine's fitness IS a model forward.

This is the ROADMAP's flagship scenario (DESIGN.md §11): every fitness
evaluation is a real forward + cross-entropy of a ``models/`` network on a
fixed synthetic batch, with the parameters perturbed along a k-dimensional
``SubspaceProjection`` (``core/subspace.py`` — shared with the in-process
subspace-Newton optimizer).  An engine candidate point is a (k,) vector of
subspace coefficients; the backend lifts it to θ0 + c·V leaf-by-leaf and
returns the loss.  Six orders of magnitude more expensive than the SDSS
quadratics, which is exactly the regime where the paper's volunteer-grid
economics bind — and the ``EvalBackend`` seam must not care.

Two evaluation modes, one class:

  * ``mesh=None`` — in-process: ``lax.map`` over the bucket's lanes on
    the local device (the parity reference);
  * ``mesh=make_production_mesh()`` — pod: the bucket's lanes are
    ``shard_map``'d over the ``data`` axis while θ0 and the basis enter
    SHARDED OVER ``model`` with the model's own ``param_specs``
    (``enforce_divisible``'d — a smoke config's 4 heads cannot split 16
    ways and must fall back explicitly), and each shard all-gathers the
    full leaves before evaluating its local lanes.

Why gather-at-use instead of Megatron-style partitioned compute: a TP
matmul splits a contraction across the ``model`` axis and psums partials,
which changes the f32 summation order — and bit-identical iterates
between pod and in-process evaluation are a hard contract of this seam.
Tiled all-gathers reconstruct exactly the original leaf, so every lane
runs the SAME per-lane program both ways; the ``model`` axis contributes
parameter/basis STORAGE scaling (the basis is k× the model's size — at
real scale it is the thing that must shard), lanes scale on ``data``.

Why ``lax.map`` over lanes instead of ``vmap``: a vmapped forward fuses
the lane axis into every matmul, so a lane's numerics could depend on the
bucket width it rides in (the pod_mesh backend needs a 4-rows-per-shard
floor for exactly that reason).  Sequential per-lane evaluation makes
each lane's program width-independent BY CONSTRUCTION — sync, pipelined,
pod, coalesced multi-search buckets and quorum replicas all compute any
given point with the identical instruction sequence.  See DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.core.substrates.eval_backend import (DEFAULT_MIN_BUCKET,
                                                EvalBackend, bucket_size)


@dataclasses.dataclass(frozen=True)
class LmWorkload:
    """One frozen LM fitness problem: smoke config + synthetic batch +
    subspace chart, plus the engine-facing search box.  Everything is
    derived deterministically from (arch, seed), so two processes given
    the same fields build bit-identical fitness functions — the
    work-server restore path and every parity baseline depend on it."""
    arch: str
    cfg: Any                       # ModelConfig (smoke, use_kernels routed)
    batch: Dict[str, np.ndarray]   # fixed synthetic tokens/labels
    proj: Any                      # SubspaceProjection (theta0, basis, ...)
    k: int
    coeff_bound: float
    seed: int

    # -- the engine-facing search space: subspace coefficients ------------
    @property
    def x0(self) -> np.ndarray:
        return np.zeros(self.k, np.float64)          # θ0 itself

    @property
    def lo(self) -> np.ndarray:
        return np.full(self.k, -self.coeff_bound, np.float64)

    @property
    def hi(self) -> np.ndarray:
        return np.full(self.k, self.coeff_bound, np.float64)

    @property
    def step(self) -> np.ndarray:
        return np.full(self.k, 0.2 * self.coeff_bound, np.float64)


def make_lm_workload(arch: str, *, k: int = 8, batch_size: int = 2,
                     seq_len: int = 32, seed: int = 0,
                     coeff_bound: float = 1.0,
                     use_kernels: bool = True) -> LmWorkload:
    """Build the LM fitness problem for one smoke config.

    The ``configs/`` smoke reductions ARE the workload definitions: any
    registered arch name works, and ``use_kernels=True`` routes its
    attention/wkv6 hot paths through ``kernels/ops.py`` (Pallas on TPU,
    ref fallback on CPU — compat.route_pallas) inside the traced ladder.
    """
    import jax

    from repro.configs import get_smoke_config
    from repro.core.subspace import SubspaceProjection

    cfg = dataclasses.replace(get_smoke_config(arch),
                              use_kernels=use_kernels)
    rng = np.random.default_rng(seed * 7919 + 11)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (batch_size, seq_len),
                               dtype=np.int64).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (batch_size, seq_len),
                               dtype=np.int64).astype(np.int32),
    }
    init_key, basis_key = jax.random.split(jax.random.key(seed), 2)
    from repro.models import transformer as T
    params0 = T.init_params(cfg, init_key)
    proj = SubspaceProjection.create(params0, k, basis_key)
    return LmWorkload(arch=arch, cfg=cfg, batch=batch, proj=proj, k=k,
                      coeff_bound=coeff_bound, seed=seed)


class LmLossEvalBackend(EvalBackend):
    """``EvalBackend`` whose ``_raw_eval`` lifts each lane's (k,) subspace
    coefficients to model parameters and returns the forward/CE loss on
    the workload's fixed batch.

    ``mesh=None``: local single-device evaluation.  ``mesh`` given: lanes
    shard over ``data``, θ0/basis storage shards over ``model`` (see
    module docstring).  The async submit/collect framing, staging rings,
    malicious-lane corruption and pad masking are all inherited — so the
    backend composes unchanged with ``CachingSubmitter``, the coalescing
    orchestrator and the work server, which only ever see the seam.
    """

    def __init__(self, workload: LmWorkload, mesh=None, *,
                 data_axis: str = "data", model_axis: str = "model",
                 n_dims: Optional[int] = None,
                 max_bucket: Optional[int] = None):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer as T

        self.workload = workload
        self.mesh = mesh
        loss_fn = T.make_loss_fn(workload.cfg)
        batch = {k_: jnp.asarray(v) for k_, v in workload.batch.items()}
        theta0, basis_tree = workload.proj.theta0, workload.proj.basis_tree

        from repro.core.subspace import tree_lift

        def lanes(pts, theta, basis, batch_):
            # The bucket's lanes, one at a time.  The barrier pins the
            # lift's operands as materialized arrays: without it XLA may
            # fuse the k-contraction with an all_gather (pod) or a
            # constant (in-process) and lower it with different FMA
            # contraction — a last-ulp split that breaks the pod ==
            # in-process bit-identity contract.  With it, every path
            # compiles the same lift-then-forward program per lane.
            theta, basis = jax.lax.optimization_barrier((theta, basis))

            def lane(c):
                return loss_fn(tree_lift(theta, basis, c), batch_)[0]
            return jax.lax.map(lane, pts)

        if mesh is None:
            self._lane_eval = lambda pts: lanes(pts, theta0, basis_tree,
                                                batch)
            min_bucket = DEFAULT_MIN_BUCKET
            self.n_shards = 1
        else:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            from repro.configs.base import ShapeConfig
            from repro.models.sharding import enforce_divisible, input_specs

            self.n_shards = int(mesh.shape[data_axis])
            if self.n_shards & (self.n_shards - 1):
                raise ValueError(
                    f"data axis must be a power of two to divide the "
                    f"power-of-two buckets, got {self.n_shards}")
            # the model's own sharding rules, with every non-dividing
            # entry downgraded EXPLICITLY (smoke dims vs model=16)
            pspecs, self.spec_fallbacks = enforce_divisible(
                workload.cfg, mesh)
            # basis leaves mirror the param leaves with a leading k axis
            bspecs = jax.tree.map(lambda s: P(*((None,) + tuple(s))), pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
            shape = ShapeConfig("lm_subspace",
                                seq_len=batch["tokens"].shape[1],
                                global_batch=batch["tokens"].shape[0],
                                kind="train")
            _, in_pspecs = input_specs(workload.cfg, shape, mesh)

            def _gather_full(tree, specs):
                # tiled all-gather over the model axis reconstructs each
                # sharded leaf exactly (concatenation in axis order) —
                # deterministic, so per-lane numerics match in-process
                def g(leaf, spec):
                    for dim, e in enumerate(spec):
                        axes = e if isinstance(e, tuple) else (e,)
                        if e is not None and model_axis in axes:
                            return jax.lax.all_gather(
                                leaf, model_axis, axis=dim, tiled=True)
                    return leaf
                return jax.tree.map(g, tree, specs,
                                    is_leaf=lambda x: isinstance(x, P))

            def shard_body(pts, theta_sh, basis_sh, batch_sh):
                theta_f = _gather_full(theta_sh, pspecs)
                basis_f = _gather_full(basis_sh, bspecs)
                return lanes(pts, theta_f, basis_f, batch_sh)

            self._sharded = shard_map(
                shard_body, mesh=mesh,
                in_specs=(P(data_axis, None), pspecs, bspecs, in_pspecs),
                out_specs=P(data_axis), check_rep=False)
            # device_put with the enforced specs: θ0 and the basis are
            # STORED model-sharded (the tentpole's storage-scaling claim),
            # and shard_map consumes them without a relayout
            from repro.models.sharding import to_named
            self._theta = jax.device_put(theta0, to_named(pspecs, mesh))
            self._basis = jax.device_put(basis_tree, to_named(bspecs, mesh))
            self._batch = jax.device_put(
                batch, to_named(in_pspecs, mesh))
            self._lane_eval = lambda pts: self._sharded(
                pts, self._theta, self._basis, self._batch)
            # lanes are evaluated sequentially per shard (lax.map), so —
            # unlike the vectorized pod_mesh f_batch — ANY rows-per-shard
            # count is width-stable; the floor is just even division
            min_bucket = bucket_size(self.n_shards)
        super().__init__(min_bucket)
        if n_dims is not None and max_bucket is not None:
            self.warm(n_dims, max_bucket)

    def _raw_eval(self, pts):
        return self._lane_eval(pts)
