"""The Asynchronous Newton Method (paper §III–§V), phase-structured.

``AnmState`` + the two phase functions are deliberately *event-driven*: the
synchronous driver (``anm_minimize``) and the asynchronous FGDO server
(core/fgdo.py) both advance the same state machine — generate points,
assimilate whichever evaluations come back, fit, move.  Any ≥ m_min subset of
results is sufficient for a phase; stragglers/failures never stall an
iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regression, sampling


@dataclasses.dataclass(frozen=True)
class AnmConfig:
    m_regression: int = 1000          # paper §VI: 1000 per regression phase
    m_line_search: int = 1000         # paper §VI: 1000 per line-search phase
    alpha_min: float = 0.0
    alpha_max: float = 2.0
    ridge: float = 1e-8
    damping: float = 1e-6
    max_iterations: int = 50
    tol: float = 1e-10                # stop when best fitness stops improving
    outlier_guard: bool = True        # MAD rejection of malicious results
    shrink_on_fail: float = 0.5       # shrink step vector if no improvement


@dataclasses.dataclass
class IterationRecord:
    iteration: int
    best_fitness: float
    avg_line_fitness: float
    center: np.ndarray
    evals_used: int
    best_alpha: float


@dataclasses.dataclass
class AnmState:
    center: jax.Array                 # x' — regression center
    step: jax.Array                   # s  — user step vector
    lo: jax.Array
    hi: jax.Array
    best_fitness: float = float("inf")
    iteration: int = 0
    direction: Optional[jax.Array] = None
    history: List[IterationRecord] = dataclasses.field(default_factory=list)


def regression_phase(state: AnmState, cfg: AnmConfig, points: jax.Array,
                     ys: jax.Array) -> jax.Array:
    """Fit gradient+Hessian from completed evaluations, return line direction."""
    weights = regression.mad_outlier_weights(ys) if cfg.outlier_guard else None
    deltas = points - state.center[None, :]
    _, g, H = regression.fit_quadratic(deltas, ys, weights, cfg.ridge)
    return regression.newton_direction(g, H, cfg.damping)


def line_search_phase(state: AnmState, cfg: AnmConfig, points: jax.Array,
                      alphas: jax.Array, ys: jax.Array) -> Tuple[jax.Array, float, float]:
    """Select the best validated point (paper §IV). Returns (x_next, f_best, α_best)."""
    ys = jnp.where(jnp.isfinite(ys), ys, jnp.inf)
    i = int(jnp.argmin(ys))
    return points[i], float(ys[i]), float(alphas[i])


def anm_minimize(f_batch: Callable[[jax.Array], jax.Array], x0, lo, hi, step,
                 cfg: AnmConfig = AnmConfig(), key=None,
                 callback=None) -> AnmState:
    """Synchronous reference driver (each phase evaluated as one batch).

    f_batch: (m, n) -> (m,) fitness (lower is better).
    The FGDO server in core/fgdo.py runs the identical phase logic with
    asynchronous, faulty, heterogeneous evaluation.
    """
    if key is None:
        key = jax.random.key(0)
    state = AnmState(center=jnp.asarray(x0, jnp.float32),
                     step=jnp.asarray(step, jnp.float32),
                     lo=jnp.asarray(lo, jnp.float32),
                     hi=jnp.asarray(hi, jnp.float32))
    state.best_fitness = float(f_batch(state.center[None, :])[0])

    for it in range(cfg.max_iterations):
        key, k1, k2 = jax.random.split(key, 3)
        pts = sampling.sample_box(k1, state.center, state.step, cfg.m_regression)
        pts = jnp.clip(pts, state.lo, state.hi)
        ys = f_batch(pts)
        direction = regression_phase(state, cfg, pts, ys)
        state.direction = direction

        a_lo, a_hi = sampling.clip_alpha_range(state.center, direction,
                                               state.lo, state.hi,
                                               cfg.alpha_min, cfg.alpha_max)
        lpts, alphas = sampling.sample_line(k2, state.center, direction,
                                            a_lo, a_hi, cfg.m_line_search)
        lys = f_batch(lpts)
        x_next, f_best, a_best = line_search_phase(state, cfg, lpts, alphas, lys)

        avg = float(jnp.mean(jnp.where(jnp.isfinite(lys), lys,
                                       jnp.nanmax(jnp.where(jnp.isfinite(lys), lys, -jnp.inf)))))
        improved = f_best < state.best_fitness - cfg.tol
        if improved:
            state.center = x_next
            state.best_fitness = f_best
        else:
            state.step = state.step * cfg.shrink_on_fail
        state.iteration = it + 1
        state.history.append(IterationRecord(
            iteration=it + 1, best_fitness=state.best_fitness,
            avg_line_fitness=avg, center=np.asarray(state.center),
            evals_used=cfg.m_regression + cfg.m_line_search, best_alpha=a_best))
        if callback is not None:
            callback(state)
        if not improved and float(jnp.max(state.step)) < 1e-12:
            break
    return state
