"""Synchronous ANM driver — the thinnest substrate over the shared engine.

All phase logic (regression fit, alpha clipping, candidate ranking, quorum
validation, commit/shrink) lives in core/engine.py; this module only turns
each batch of engine requests into ONE ``f_batch`` call and feeds every
result straight back.  The asynchronous FGDO server (core/fgdo.py) and the
vectorized grid simulator (core/substrates/batched_grid.py) drive the
identical engine — that equivalence is what tests/test_engine.py's parity
test pins down.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (AnmConfig, AnmEngine, EvalResult,  # noqa: F401
                               IterationRecord)


@dataclasses.dataclass
class AnmState:
    """Snapshot of the engine exposed to callers of ``anm_minimize``."""
    center: jax.Array                 # x' — regression center
    step: jax.Array                   # s  — user step vector
    lo: jax.Array
    hi: jax.Array
    best_fitness: float = float("inf")
    iteration: int = 0
    direction: Optional[jax.Array] = None
    history: List[IterationRecord] = dataclasses.field(default_factory=list)


def _sync(state: AnmState, engine: AnmEngine) -> None:
    state.center = jnp.asarray(engine.center, jnp.float32)
    state.step = jnp.asarray(engine.step, jnp.float32)
    state.best_fitness = engine.best_fitness
    state.iteration = engine.iteration
    if engine.direction is not None:
        state.direction = jnp.asarray(engine.direction, jnp.float32)


def anm_minimize(f_batch: Callable[[jax.Array], jax.Array], x0, lo, hi, step,
                 cfg: AnmConfig = AnmConfig(), key=None,
                 callback=None) -> AnmState:
    """Synchronous reference driver (each phase evaluated as one batch).

    f_batch: (m, n) -> (m,) fitness (lower is better).  ``key`` seeds the
    engine's sampler; with a deterministic ``f_batch`` the quorum validation
    trivially confirms every candidate, so this driver follows the same
    commit path as the asynchronous substrates.
    """
    seed = 0 if key is None else int(jax.random.randint(key, (), 0, 2**31 - 1))
    engine = AnmEngine(x0, lo, hi, step, cfg, seed=seed)
    engine.set_initial_fitness(
        float(f_batch(jnp.asarray(x0, jnp.float32)[None, :])[0]))
    state = AnmState(center=jnp.asarray(x0, jnp.float32),
                     step=jnp.asarray(step, jnp.float32),
                     lo=jnp.asarray(lo, jnp.float32),
                     hi=jnp.asarray(hi, jnp.float32),
                     best_fitness=engine.best_fitness,
                     history=engine.history)
    while not engine.done:
        reqs = engine.generate()
        if not reqs:
            break                     # defensive: a stuck engine cannot loop
        pts = jnp.asarray(np.stack([r.point for r in reqs]), jnp.float32)
        ys = np.asarray(f_batch(pts), np.float64)
        transitions = engine.assimilate(
            [EvalResult(r, float(y)) for r, y in zip(reqs, ys)])
        for tr in transitions:
            if tr.kind == "commit":
                _sync(state, engine)
                if callback is not None:
                    callback(state)
    _sync(state, engine)
    return state
