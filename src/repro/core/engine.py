"""The ANM engine: one substrate-agnostic Newton state machine (DESIGN.md §1).

The paper's central claim is that a single phase-structured state machine —
box-sampled regression → damped Newton direction → randomized line search →
quorum validation → commit/shrink — runs unchanged on any computing
substrate, from a synchronous MPI batch to an asynchronous, faulty BOINC
grid.  ``AnmEngine`` is that state machine, extracted so it exists exactly
once.  Substrates drive it through a two-call event API:

    reqs = engine.generate(k)        # up to k evaluation requests
    engine.assimilate(results)       # any completed subset, in any order

and never see phase logic.  Three substrates ship with the repo:

  * core/anm.py                      — synchronous batch driver
                                       (one ``f_batch`` call per phase);
  * core/fgdo.py                     — BOINC-style asynchronous server
                                       (workunit ids, stale filtering,
                                       reliable-host scheduling);
  * core/substrates/batched_grid.py  — vectorized grid simulator
                                       (thousands of hosts per tick, one
                                       jitted ``f_batch`` call per tick).

Robustness semantics reproduced from the paper (see DESIGN.md §2):
  * a phase advances when ANY m results have been assimilated; results from
    an earlier phase are discarded as stale — stragglers never stall (§III);
  * only results that will be USED to generate new work (the best
    line-search point) are validated, by quorum re-evaluation (§V);
  * malicious/corrupt fitness values additionally face a MAD outlier guard
    before entering the regression.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import regression, sampling

REGRESSION, LINESEARCH, VALIDATING, DONE = \
    "regression", "linesearch", "validating", "done"


@dataclasses.dataclass(frozen=True)
class AnmConfig:
    m_regression: int = 1000          # paper §VI: 1000 per regression phase
    m_line_search: int = 1000         # paper §VI: 1000 per line-search phase
    alpha_min: float = 0.0
    alpha_max: float = 2.0
    ridge: float = 1e-8
    damping: float = 1e-6
    max_iterations: int = 50
    tol: float = 1e-10                # stop when best fitness stops improving
    outlier_guard: bool = True        # MAD rejection of malicious results
    shrink_on_fail: float = 0.5       # shrink step vector if no improvement


@dataclasses.dataclass
class IterationRecord:
    iteration: int
    best_fitness: float
    avg_line_fitness: float
    center: np.ndarray
    evals_used: int
    best_alpha: float


@dataclasses.dataclass(frozen=True)
class EvalRequest:
    """One requested fitness evaluation.  ``ticket`` is unique per engine;
    ``validates`` carries the ticket of the candidate result this request
    re-checks (quorum replicas only)."""
    ticket: int
    phase_id: int
    point: np.ndarray
    alpha: float = float("nan")
    validates: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class EvalResult:
    request: EvalRequest
    y: float


@dataclasses.dataclass(frozen=True)
class Transition:
    """Phase-machine event returned by ``assimilate`` so substrates can log
    or react without inspecting engine internals."""
    kind: str                         # direction|validating|rejected|commit|done
    iteration: int
    improved: bool = False


@dataclasses.dataclass
class EngineStats:
    issued: int = 0
    assimilated: int = 0
    stale: int = 0
    validations_issued: int = 0
    validations_failed: int = 0
    candidates_rejected: int = 0


class AnmEngine:
    """The unified ANM phase machine.  Owns all decision state; substrates
    own time, hosts, and evaluation."""

    def __init__(self, x0, lo, hi, step, cfg: AnmConfig = AnmConfig(),
                 seed: int = 0, validation_quorum: int = 2,
                 validation_rtol: float = 1e-6):
        self.cfg = cfg
        self.center = np.asarray(x0, np.float64)
        self.lo = np.asarray(lo, np.float64)
        self.hi = np.asarray(hi, np.float64)
        self.step = np.asarray(step, np.float64)
        self.n = self.center.shape[0]
        self.rng = np.random.default_rng(seed)
        self.quorum = validation_quorum
        self.vrtol = validation_rtol

        self.phase = REGRESSION
        self.phase_id = 0
        self.iteration = 0
        self.best_fitness = float("inf")
        self.direction: Optional[np.ndarray] = None
        self.alpha_range: Tuple[float, float] = (cfg.alpha_min, cfg.alpha_max)
        self.results: List[Tuple[np.ndarray, float, float, int]] = []  # pt,y,a,ticket
        self.stats = EngineStats()
        self.history: List[IterationRecord] = []
        self._ticket = itertools.count()
        # validation bookkeeping: ranked candidates and votes for the current one
        self._candidates: List[Tuple[float, np.ndarray, float, int]] = []
        self._candidate: Optional[Tuple[float, np.ndarray, float, int]] = None
        self._votes: List[float] = []
        self._pending_validation = 0
        self._line_avg = float("nan")

    # -- introspection ------------------------------------------------------

    @property
    def done(self) -> bool:
        return self.phase == DONE

    @property
    def validating(self) -> bool:
        return self.phase == VALIDATING

    @property
    def validation_pending(self) -> int:
        """Quorum replicas not yet handed out for the current candidate."""
        return self._pending_validation

    def set_initial_fitness(self, y: float) -> None:
        """Seed the improvement threshold with f(x0) when the substrate can
        afford an up-front evaluation (the synchronous driver does)."""
        self.best_fitness = float(y)

    def wanted(self) -> int:
        """Natural batch size for the current phase — what a substrate with
        unlimited capacity should request."""
        if self.phase == REGRESSION:
            return max(self.cfg.m_regression - len(self.results), 0)
        if self.phase == LINESEARCH:
            return max(self.cfg.m_line_search - len(self.results), 0)
        if self.phase == VALIDATING:
            return self._pending_validation
        return 0

    # -- work generation ----------------------------------------------------

    def generate(self, k: Optional[int] = None) -> List[EvalRequest]:
        """Return up to ``k`` evaluation requests (``k=None``: the phase's
        natural batch).  While validating, only outstanding quorum replicas
        are handed out; an empty list means "nothing to do right now"."""
        if self.phase == DONE:
            return []
        if self.phase == VALIDATING:
            k = self._pending_validation if k is None else \
                min(k, self._pending_validation)
            reqs = []
            for _ in range(max(k, 0)):
                self._pending_validation -= 1
                reqs.append(self._validation_request())
            return reqs
        if self.phase == REGRESSION:
            k = self.wanted() if k is None else k
            if k <= 0:
                return []
            u = self.rng.uniform(-1.0, 1.0, (k, self.n))
            pts = np.clip(self.center[None, :] + u * self.step[None, :],
                          self.lo, self.hi)
            alphas = np.full(k, np.nan)
        else:  # LINESEARCH
            k = self.wanted() if k is None else k
            if k <= 0:
                return []
            a_lo, a_hi = self.alpha_range
            alphas = self.rng.uniform(a_lo, a_hi, k)
            pts = self.center[None, :] + alphas[:, None] * self.direction[None, :]
        self.stats.issued += k
        return [EvalRequest(next(self._ticket), self.phase_id, pts[i],
                            float(alphas[i])) for i in range(k)]

    def reissue_validation(self) -> Optional[EvalRequest]:
        """Extra quorum replica beyond the pending budget — for substrates
        whose replicas can be lost (host failure / reissue timeout)."""
        if self.phase != VALIDATING or self._candidate is None:
            return None
        return self._validation_request()

    def _validation_request(self) -> EvalRequest:
        y, pt, alpha, ticket = self._candidate
        self.stats.validations_issued += 1
        self.stats.issued += 1
        return EvalRequest(next(self._ticket), self.phase_id, pt.copy(),
                           alpha, validates=ticket)

    # -- assimilation -------------------------------------------------------

    def assimilate(self, results: Iterable[EvalResult]) -> List[Transition]:
        """Fold any completed evaluations into the phase machine.  Returns
        the phase transitions they caused (possibly none, possibly several —
        e.g. a rejected candidate followed by a commit)."""
        transitions: List[Transition] = []
        for res in results:
            if self.phase == DONE:
                break
            req = res.request
            if req.phase_id != self.phase_id:
                self.stats.stale += 1
                continue
            self.stats.assimilated += 1
            if req.validates is not None:
                if self._candidate is not None and \
                        req.validates == self._candidate[3]:
                    self._votes.append(float(res.y))
                    transitions.extend(self._check_validation())
                else:
                    self.stats.stale += 1   # replica for an already-decided candidate
                continue
            self.results.append((req.point, float(res.y), req.alpha, req.ticket))
            m_needed = (self.cfg.m_regression if self.phase == REGRESSION
                        else self.cfg.m_line_search)
            if len(self.results) >= m_needed:
                if self.phase == REGRESSION:
                    transitions.extend(self._finish_regression())
                else:
                    transitions.extend(self._finish_line_search())
        return transitions

    # -- phase transitions --------------------------------------------------

    def _finish_regression(self) -> List[Transition]:
        pts = np.stack([r[0] for r in self.results])
        ys = np.array([r[1] for r in self.results])
        w = (np.asarray(regression.mad_outlier_weights(jnp.asarray(ys)))
             if self.cfg.outlier_guard else None)
        deltas = jnp.asarray(pts - self.center[None, :], jnp.float32)
        _, g, H = regression.fit_quadratic(
            deltas, jnp.asarray(ys, jnp.float32),
            None if w is None else jnp.asarray(w, jnp.float32), self.cfg.ridge)
        d = regression.newton_direction(g, H, self.cfg.damping)
        self.direction = np.asarray(d, np.float64)
        a_lo, a_hi = sampling.clip_alpha_range(
            jnp.asarray(self.center, jnp.float32), jnp.asarray(d),
            jnp.asarray(self.lo, jnp.float32), jnp.asarray(self.hi, jnp.float32),
            self.cfg.alpha_min, self.cfg.alpha_max)
        self.alpha_range = (float(a_lo), float(a_hi))
        self._advance(LINESEARCH)
        return [Transition("direction", self.iteration)]

    def _finish_line_search(self) -> List[Transition]:
        finite = [(y, pt, a, t) for pt, y, a, t in self.results
                  if np.isfinite(y)]
        finite.sort(key=lambda r: r[0])
        self._line_avg = (float(np.mean([r[0] for r in finite]))
                          if finite else float("nan"))
        self._advance(VALIDATING)
        self._candidates = finite
        return self._start_validation()

    def _start_validation(self) -> List[Transition]:
        if not self._candidates:
            # nothing usable: shrink step, next iteration from the same center
            return self._commit(self.center, self.best_fitness, float("nan"),
                                improved=False)
        self._candidate = self._candidates.pop(0)
        self._votes = [self._candidate[0]]
        self._pending_validation = self.quorum
        return [Transition("validating", self.iteration)]

    def _check_validation(self) -> List[Transition]:
        need = self.quorum + 1
        if len(self._votes) < need:
            return []
        votes = np.array(self._votes)
        med = np.median(votes)
        agree = np.sum(np.abs(votes - med) <= self.vrtol * max(1.0, abs(med)))
        cand_y, cand_pt, cand_a, _ = self._candidate
        self._candidate = None
        if agree >= (need // 2 + 1) and \
                abs(cand_y - med) <= self.vrtol * max(1.0, abs(med)):
            improved = med < self.best_fitness - self.cfg.tol
            return self._commit(cand_pt, float(med), cand_a, improved)
        self.stats.validations_failed += 1
        self.stats.candidates_rejected += 1
        return [Transition("rejected", self.iteration)] + self._start_validation()

    def _commit(self, x_next, f_best, alpha, improved: bool) -> List[Transition]:
        if improved:
            self.center = np.asarray(x_next, np.float64)
            self.best_fitness = f_best
        else:
            self.step = self.step * self.cfg.shrink_on_fail
        self.iteration += 1
        self.history.append(IterationRecord(
            iteration=self.iteration, best_fitness=self.best_fitness,
            avg_line_fitness=self._line_avg, center=self.center.copy(),
            evals_used=self.stats.assimilated, best_alpha=alpha))
        transitions = [Transition("commit", self.iteration, improved)]
        if self.iteration >= self.cfg.max_iterations or \
                (not improved and float(np.max(self.step)) < 1e-12):
            self._advance(DONE)
            transitions.append(Transition("done", self.iteration))
        else:
            self._advance(REGRESSION)
        return transitions

    def _advance(self, phase: str) -> None:
        self.phase = phase
        self.phase_id += 1
        self.results = []
        self._candidates = []
        self._candidate = None
        self._votes = []
        self._pending_validation = 0
