"""The ANM engine: one substrate-agnostic Newton state machine (DESIGN.md §1).

The paper's central claim is that a single phase-structured state machine —
box-sampled regression → damped Newton direction → randomized line search →
quorum validation → commit/shrink — runs unchanged on any computing
substrate, from a synchronous MPI batch to an asynchronous, faulty BOINC
grid.  ``AnmEngine`` is that state machine, extracted so it exists exactly
once.  Substrates drive it through a two-call event API:

    reqs = engine.generate(k)        # up to k evaluation requests
    engine.assimilate(results)       # any completed subset, in any order

and never see phase logic.  Three substrates ship with the repo:

  * core/anm.py                      — synchronous batch driver
                                       (one ``f_batch`` call per phase);
  * core/fgdo.py                     — BOINC-style asynchronous server
                                       (workunit ids, stale filtering,
                                       reliable-host scheduling);
  * core/substrates/batched_grid.py  — vectorized grid simulator
                                       (thousands of hosts per tick, one
                                       jitted ``f_batch`` call per tick).

Robustness semantics reproduced from the paper (see DESIGN.md §2):
  * the engine's first requests evaluate f(x0) (bootstrap phase), so the
    improvement threshold is seeded on EVERY substrate — the first commit
    can never accept a candidate worse than the start by comparing to inf;
  * a phase advances when ANY m results have been assimilated; results from
    an earlier phase are discarded as stale — stragglers never stall (§III);
  * only results that will be USED to generate new work (the best
    line-search point) are validated, by quorum re-evaluation (§V);
  * malicious/corrupt fitness values additionally face a MAD outlier guard
    before entering the regression.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import regression, sampling


@functools.partial(jax.jit, static_argnames=("outlier_guard", "ridge",
                                             "damping", "a_min", "a_max"))
def _regression_direction(deltas, ys, center, lo, hi, *, outlier_guard,
                          ridge, damping, a_min, a_max):
    """One fused, jitted phase-finish: robust (MAD value + residual pass)
    quadratic fit -> damped Newton direction -> alpha-range clip.  Eagerly
    dispatching the ~30 small ops here costs ~20ms per phase on CPU — far
    more than the math itself at the m values the paper uses."""
    if outlier_guard:
        _, g, H = regression.fit_quadratic_robust(deltas, ys, ridge)
    else:
        _, g, H = regression.fit_quadratic(deltas, ys, None, ridge)
    d = regression.newton_direction(g, H, damping)
    a_lo, a_hi = sampling.clip_alpha_range(center, d, lo, hi, a_min, a_max)
    return d, a_lo, a_hi

BOOTSTRAP, REGRESSION, LINESEARCH, VALIDATING, DONE = \
    "bootstrap", "regression", "linesearch", "validating", "done"


@dataclasses.dataclass(frozen=True)
class AnmConfig:
    m_regression: int = 1000          # paper §VI: 1000 per regression phase
    m_line_search: int = 1000         # paper §VI: 1000 per line-search phase
    alpha_min: float = 0.0
    alpha_max: float = 2.0
    ridge: float = 1e-8
    damping: float = 1e-6
    max_iterations: int = 50
    tol: float = 1e-10                # stop when best fitness stops improving
    outlier_guard: bool = True        # MAD rejection of malicious results
    shrink_on_fail: float = 0.5       # shrink step vector if no improvement


@dataclasses.dataclass
class IterationRecord:
    iteration: int
    best_fitness: float
    avg_line_fitness: float
    center: np.ndarray
    evals_used: int
    best_alpha: float


class EvalRequest(NamedTuple):
    """One requested fitness evaluation.  ``ticket`` is unique per engine;
    ``validates`` carries the ticket of the candidate result this request
    re-checks (quorum replicas only).  A NamedTuple, not a dataclass: the
    batched substrates create one per evaluation, and C-speed construction
    matters at thousands of results per tick."""
    ticket: int
    phase_id: int
    point: np.ndarray
    alpha: float = float("nan")
    validates: Optional[int] = None


class EvalResult(NamedTuple):
    request: EvalRequest
    y: float


@dataclasses.dataclass(frozen=True)
class Transition:
    """Phase-machine event returned by ``assimilate`` so substrates can log
    or react without inspecting engine internals."""
    kind: str                 # bootstrap|direction|validating|rejected|commit|done
    iteration: int
    improved: bool = False


@dataclasses.dataclass
class EngineStats:
    issued: int = 0
    assimilated: int = 0
    stale: int = 0                    # results from an already-finished phase
    validations_issued: int = 0
    validations_stale: int = 0        # replicas for an already-decided candidate
    validations_failed: int = 0
    candidates_rejected: int = 0


def identical_trajectories(a: "AnmEngine", b: "AnmEngine") -> bool:
    """True iff two engines committed bit-identical iterate histories —
    same iteration count AND same centers AND same fitness values.  The
    canonical comparison for backend/substrate parity checks (zipping the
    histories alone would vacuously pass on a shorter, diverged run)."""
    return bool(
        a.iteration == b.iteration and
        len(a.history) == len(b.history) and
        all(np.array_equal(x.center, y.center)
            for x, y in zip(a.history, b.history)) and
        [r.best_fitness for r in a.history] ==
        [r.best_fitness for r in b.history])


class AnmEngine:
    """The unified ANM phase machine.  Owns all decision state; substrates
    own time, hosts, and evaluation."""

    def __init__(self, x0, lo, hi, step, cfg: AnmConfig = AnmConfig(),
                 seed: int = 0, validation_quorum: int = 2,
                 validation_rtol: float = 1e-6):
        self.cfg = cfg
        self.center = np.asarray(x0, np.float64)
        self.lo = np.asarray(lo, np.float64)
        self.hi = np.asarray(hi, np.float64)
        self.step = np.asarray(step, np.float64)
        self.n = self.center.shape[0]
        self.rng = np.random.default_rng(seed)
        self.quorum = validation_quorum
        self.vrtol = validation_rtol

        # every run starts by evaluating f(x0): until that bootstrap result
        # lands, best_fitness is inf and the first commit would count ANY
        # validated candidate as an improvement — even one worse than the
        # start.  The engine owns the guard so every substrate gets it, not
        # just drivers that can afford a synchronous up-front evaluation.
        self.phase = BOOTSTRAP
        self.phase_id = 0
        self.iteration = 0
        self.best_fitness = float("inf")
        self.direction: Optional[np.ndarray] = None
        self.alpha_range: Tuple[float, float] = (cfg.alpha_min, cfg.alpha_max)
        # phase results are stored as array CHUNKS (one per assimilated
        # block), concatenated only at phase finish — the block fast path
        # (``assimilate_arrays``) appends thousands of results without
        # creating a Python object per evaluation
        self._res_pts: List[np.ndarray] = []
        self._res_ys: List[np.ndarray] = []
        self._res_alphas: List[np.ndarray] = []
        self._res_tickets: List[np.ndarray] = []
        self._res_count = 0
        self.stats = EngineStats()
        self.history: List[IterationRecord] = []
        self._next_ticket = 0
        # validation bookkeeping: ranked candidate arrays (+ cursor) and
        # votes for the current candidate
        self._candidates: Optional[Tuple[np.ndarray, ...]] = None
        self._cand_next = 0
        self._candidate: Optional[Tuple[float, np.ndarray, float, int]] = None
        self._votes: List[float] = []
        self._pending_validation = 0
        self._bootstrapping = False   # validating the f(x0) probe itself
        self._line_avg = float("nan")
        # block-speculation snapshot (peek_block/cancel_block): rng state +
        # ticket counter + issuance stats + validation ticket state, enough
        # to make a speculatively generated block fully revertible even
        # when the peek lands mid-validation
        self._spec_snapshot: Optional[Tuple] = None

    # -- introspection ------------------------------------------------------

    def _take_ticket(self) -> int:
        t = self._next_ticket
        self._next_ticket += 1
        return t

    @property
    def results(self) -> List[Tuple[np.ndarray, float, float, int]]:
        """Current-phase results as (point, y, alpha, ticket) tuples —
        materialized from the chunk storage; meant for tests/inspection,
        not hot paths."""
        return [(p, float(y), float(a), int(t))
                for pts, ys, als, tks in zip(self._res_pts, self._res_ys,
                                             self._res_alphas,
                                             self._res_tickets)
                for p, y, a, t in zip(pts, ys, als, tks)]

    @property
    def done(self) -> bool:
        return self.phase == DONE

    @property
    def validating(self) -> bool:
        return self.phase == VALIDATING

    @property
    def bootstrapping(self) -> bool:
        """True until the f(x0) probe has been issued AND quorum-confirmed
        (the bootstrap's own validation round included)."""
        return self.phase == BOOTSTRAP or self._bootstrapping

    @property
    def validation_pending(self) -> int:
        """Quorum replicas not yet handed out for the current candidate."""
        return self._pending_validation

    @property
    def validation_votes_outstanding(self) -> int:
        """Votes still missing for the current candidate (issued or not).
        Substrates batching completions can safely advance time until this
        many replicas have landed — the phase cannot commit on fewer."""
        if self.phase != VALIDATING or self._candidate is None:
            return 0
        return max(self.quorum + 1 - len(self._votes), 0)

    def set_initial_fitness(self, y: float) -> None:
        """Short-circuit the bootstrap phase with a known f(x0) when the
        substrate can afford an up-front evaluation (the synchronous driver
        does) — saves the one-request bootstrap round-trip."""
        self.best_fitness = float(y)
        if self.phase == BOOTSTRAP:
            self._advance(REGRESSION)

    def wanted(self) -> int:
        """Natural batch size for the current phase — what a substrate with
        unlimited capacity should request."""
        if self.phase == BOOTSTRAP:
            return 1
        if self.phase == REGRESSION:
            return max(self.cfg.m_regression - self._res_count, 0)
        if self.phase == LINESEARCH:
            return max(self.cfg.m_line_search - self._res_count, 0)
        if self.phase == VALIDATING:
            return self._pending_validation
        return 0

    # -- work generation ----------------------------------------------------

    def generate(self, k: Optional[int] = None) -> List[EvalRequest]:
        """Return up to ``k`` evaluation requests (``k=None``: the phase's
        natural batch).  While validating, only outstanding quorum replicas
        are handed out; an empty list means "nothing to do right now"."""
        if self.phase == DONE:
            return []
        if self.phase == VALIDATING:
            k = self._pending_validation if k is None else \
                min(k, self._pending_validation)
            reqs = []
            for _ in range(max(k, 0)):
                self._pending_validation -= 1
                reqs.append(self._validation_request())
            return reqs
        if self.phase == BOOTSTRAP:
            # redundant copies of the f(x0) probe are fine (first one in
            # wins, the rest go stale) — a single copy could be lost on a
            # faulty substrate and deadlock the run before it starts
            k = 1 if k is None else k
            if k <= 0:
                return []
            self.stats.issued += k
            return [EvalRequest(self._take_ticket(), self.phase_id,
                                self.center.copy()) for _ in range(k)]
        block = self.generate_block(k)
        if block is None:
            return []
        tickets, phase_id, pts, alphas = block
        return [EvalRequest(int(tickets[i]), phase_id, pts[i],
                            float(alphas[i])) for i in range(len(tickets))]

    def generate_block(self, k: Optional[int] = None):
        """Vectorized work generation for array-based substrates: returns
        ``(tickets (k,), phase_id, points (k, n), alphas (k,))`` with no
        per-request objects, or ``None`` when the phase has nothing to hand
        out this way (empty batch, done, or the tiny bootstrap/validation
        phases — use ``generate()`` there)."""
        if self.phase not in (REGRESSION, LINESEARCH):
            return None
        k = self.wanted() if k is None else k
        if k <= 0:
            return None
        if self.phase == REGRESSION:
            u = self.rng.uniform(-1.0, 1.0, (k, self.n))
            pts = np.clip(self.center[None, :] + u * self.step[None, :],
                          self.lo, self.hi)
            alphas = np.full(k, np.nan)
        else:  # LINESEARCH
            a_lo, a_hi = self.alpha_range
            alphas = self.rng.uniform(a_lo, a_hi, k)
            pts = self.center[None, :] + alphas[:, None] * self.direction[None, :]
        self.stats.issued += k
        tickets = np.arange(self._next_ticket, self._next_ticket + k)
        self._next_ticket += k
        return tickets, self.phase_id, pts, alphas

    # -- block speculation (pipelined substrates, DESIGN.md §7) -------------

    def peek_block(self, k: Optional[int] = None):
        """Speculatively generate a block for the CURRENT phase: exactly the
        draws ``generate_block(k)`` would make, but revertible.  A pipelined
        substrate calls this while earlier results are still in flight on
        the device, betting that assimilating them will not flip the phase
        (within a phase, generated points depend only on phase state and the
        engine rng — never on pending ``ys``).  If the bet loses, the block
        is stale under the new phase_id: ``cancel_block()`` rewinds the rng
        stream, ticket counter and issuance stat as if the peek never
        happened, so a discarded speculation is invisible to the committed
        trajectory.  ``accept_block()`` (or the next peek) drops the
        snapshot once the block has really been handed out.

        The snapshot also covers the validation ticket state
        (``stats.validations_issued`` and the pending-replica budget): a
        peek taken while a validation is pending generates nothing (blocks
        only exist in regression/line-search), but the cancel must still
        leave the quorum bookkeeping exactly as it found it — a substrate
        that interleaves peeks with validation phases (the multi-search
        orchestrator steps many engines in one loop) relies on that."""
        self._spec_snapshot = (self.rng.bit_generator.state,
                               self._next_ticket, self.stats.issued,
                               self.stats.validations_issued,
                               self._pending_validation)
        return self.generate_block(k)

    def accept_block(self) -> None:
        """Commit the last peeked block: the snapshot is dropped, making
        the speculation indistinguishable from a plain ``generate_block``."""
        self._spec_snapshot = None

    def cancel_block(self) -> None:
        """Discard the last peeked block, rewinding every side effect of
        the peek (rng stream, tickets, ``stats.issued``, and the
        validation ticket state the snapshot carries)."""
        if self._spec_snapshot is None:
            return
        state, ticket, issued, val_issued, val_pending = self._spec_snapshot
        self.rng.bit_generator.state = state
        self._next_ticket = ticket
        self.stats.issued = issued
        self.stats.validations_issued = val_issued
        self._pending_validation = val_pending
        self._spec_snapshot = None

    # -- state serialization (service layer, DESIGN.md §9) ------------------

    def state_dict(self) -> dict:
        """The COMPLETE restartable engine state as plain python + numpy:
        an engine built from the same constructor arguments and fed this
        dict through ``load_state`` continues the search bit-identically —
        same rng stream, ticket numbering, phase bookkeeping, candidate
        ranking and stats.  This is the serialization seam the
        crash-recoverable work server (``repro/server``) checkpoints
        through; keep every mutable field here or a restore silently
        diverges.  Numpy arrays stay arrays — the checkpoint layer owns
        the JSON encoding (``repro.server.checkpoint.to_jsonable``)."""
        cand = None
        if self._candidates is not None:
            cand = [np.asarray(a).copy() for a in self._candidates]
        spec = None
        if self._spec_snapshot is not None:
            st, ticket, issued, val_issued, val_pending = self._spec_snapshot
            spec = {"rng_state": st, "ticket": ticket, "issued": issued,
                    "validations_issued": val_issued,
                    "pending_validation": val_pending}
        return {
            "cfg": dataclasses.asdict(self.cfg),
            "n": self.n, "quorum": self.quorum, "vrtol": self.vrtol,
            "center": self.center.copy(), "lo": self.lo.copy(),
            "hi": self.hi.copy(), "step": self.step.copy(),
            "rng_state": self.rng.bit_generator.state,
            "phase": self.phase, "phase_id": self.phase_id,
            "iteration": self.iteration, "best_fitness": self.best_fitness,
            "direction": None if self.direction is None
            else self.direction.copy(),
            "alpha_range": list(self.alpha_range),
            "res_pts": [np.asarray(a).copy() for a in self._res_pts],
            "res_ys": [np.asarray(a).copy() for a in self._res_ys],
            "res_alphas": [np.asarray(a).copy() for a in self._res_alphas],
            "res_tickets": [np.asarray(a).copy() for a in self._res_tickets],
            "res_count": self._res_count,
            "stats": dataclasses.asdict(self.stats),
            "history": [{
                "iteration": r.iteration, "best_fitness": r.best_fitness,
                "avg_line_fitness": r.avg_line_fitness,
                "center": np.asarray(r.center).copy(),
                "evals_used": r.evals_used, "best_alpha": r.best_alpha,
            } for r in self.history],
            "next_ticket": self._next_ticket,
            "candidates": cand, "cand_next": self._cand_next,
            "candidate": None if self._candidate is None else {
                "y": self._candidate[0],
                "point": np.asarray(self._candidate[1]).copy(),
                "alpha": self._candidate[2], "ticket": self._candidate[3]},
            "votes": list(self._votes),
            "pending_validation": self._pending_validation,
            "bootstrapping": self._bootstrapping,
            "line_avg": self._line_avg,
            "spec_snapshot": spec,
        }

    def load_state(self, d: dict) -> None:
        """Restore the state captured by ``state_dict`` into this engine
        (which must have been built with a matching config/dimension —
        checked, since a silent mismatch would produce a plausible but
        wrong continuation)."""
        if int(d["n"]) != self.n:
            raise ValueError(f"state is {d['n']}-dimensional, engine is "
                             f"{self.n}-dimensional")
        if dict(d["cfg"]) != dataclasses.asdict(self.cfg):
            raise ValueError("state was captured under a different AnmConfig")
        self.quorum = int(d["quorum"])
        self.vrtol = float(d["vrtol"])
        self.center = np.asarray(d["center"], np.float64)
        self.lo = np.asarray(d["lo"], np.float64)
        self.hi = np.asarray(d["hi"], np.float64)
        self.step = np.asarray(d["step"], np.float64)
        self.rng.bit_generator.state = d["rng_state"]
        self.phase = d["phase"]
        self.phase_id = int(d["phase_id"])
        self.iteration = int(d["iteration"])
        self.best_fitness = float(d["best_fitness"])
        self.direction = (None if d["direction"] is None
                          else np.asarray(d["direction"], np.float64))
        self.alpha_range = (float(d["alpha_range"][0]),
                            float(d["alpha_range"][1]))
        self._res_pts = [np.asarray(a, np.float64) for a in d["res_pts"]]
        self._res_ys = [np.asarray(a, np.float64) for a in d["res_ys"]]
        self._res_alphas = [np.asarray(a, np.float64)
                            for a in d["res_alphas"]]
        self._res_tickets = [np.asarray(a, np.int64)
                             for a in d["res_tickets"]]
        self._res_count = int(d["res_count"])
        self.stats = EngineStats(**{k: int(v) for k, v in d["stats"].items()})
        self.history = [IterationRecord(
            iteration=int(r["iteration"]),
            best_fitness=float(r["best_fitness"]),
            avg_line_fitness=float(r["avg_line_fitness"]),
            center=np.asarray(r["center"], np.float64),
            evals_used=int(r["evals_used"]),
            best_alpha=float(r["best_alpha"])) for r in d["history"]]
        self._next_ticket = int(d["next_ticket"])
        c = d["candidates"]
        self._candidates = None if c is None else (
            np.asarray(c[0], np.float64), np.asarray(c[1], np.float64),
            np.asarray(c[2], np.float64), np.asarray(c[3], np.int64))
        self._cand_next = int(d["cand_next"])
        cd = d["candidate"]
        self._candidate = None if cd is None else (
            float(cd["y"]), np.asarray(cd["point"], np.float64),
            float(cd["alpha"]), int(cd["ticket"]))
        self._votes = [float(v) for v in d["votes"]]
        self._pending_validation = int(d["pending_validation"])
        self._bootstrapping = bool(d["bootstrapping"])
        self._line_avg = float(d["line_avg"])
        sp = d["spec_snapshot"]
        self._spec_snapshot = None if sp is None else (
            sp["rng_state"], int(sp["ticket"]), int(sp["issued"]),
            int(sp["validations_issued"]), int(sp["pending_validation"]))

    def reissue_validation(self) -> Optional[EvalRequest]:
        """Extra quorum replica beyond the pending budget — for substrates
        whose replicas can be lost (host failure / reissue timeout)."""
        if self.phase != VALIDATING or self._candidate is None:
            return None
        return self._validation_request()

    def _validation_request(self) -> EvalRequest:
        y, pt, alpha, ticket = self._candidate
        self.stats.validations_issued += 1
        self.stats.issued += 1
        return EvalRequest(self._take_ticket(), self.phase_id, pt.copy(),
                           alpha, validates=ticket)

    # -- assimilation -------------------------------------------------------

    def assimilate(self, results: Iterable[EvalResult]) -> List[Transition]:
        """Fold any completed evaluations into the phase machine.  Returns
        the phase transitions they caused (possibly none, possibly several —
        e.g. a rejected candidate followed by a commit)."""
        transitions: List[Transition] = []
        for res in results:
            if self.phase == DONE:
                break
            req = res.request
            self._assimilate_one(req.phase_id, req.ticket, req.point,
                                 req.alpha, req.validates, res.y, transitions)
        return transitions

    def _assimilate_one(self, phase_id: int, ticket: int, point, alpha,
                        validates: Optional[int], y: float,
                        transitions: List[Transition]) -> None:
        """One result through the phase machine — the single source of
        truth shared by the object API and the array fast path."""
        if phase_id != self.phase_id:
            self.stats.stale += 1
            return
        self.stats.assimilated += 1
        if validates is not None:
            if self._candidate is not None and validates == self._candidate[3]:
                self._votes.append(float(y))
                transitions.extend(self._check_validation())
            else:
                # replica for an already-decided candidate: same phase,
                # so not phase-stale — count it separately or the
                # benchmarks' staleness numbers conflate the two
                self.stats.validations_stale += 1
            return
        if self.phase == BOOTSTRAP:
            if not np.isfinite(y):
                # a non-finite start is unusable as a threshold either way;
                # don't spend quorum on it
                self._advance(REGRESSION)
                transitions.append(Transition("bootstrap", self.iteration))
                return
            # the f(x0) claim gates EVERY commit, so it gets the same
            # quorum treatment as a line-search winner (§2): one malicious
            # probe must not be able to poison the improvement threshold
            self._advance(VALIDATING)
            self._bootstrapping = True
            self._candidate = (float(y), self.center.copy(), float("nan"),
                               ticket)
            self._votes = [float(y)]
            self._pending_validation = self.quorum
            transitions.append(Transition("validating", self.iteration))
            return
        self._append_results(np.asarray(point)[None, :],
                             np.array([y], np.float64),
                             np.array([alpha], np.float64),
                             np.array([ticket]), transitions)

    def _append_results(self, pts, ys, alphas, tickets,
                        transitions: List[Transition]) -> None:
        """Buffer current-phase results (a whole chunk at once) and finish
        the phase when it reaches its m."""
        self._res_pts.append(pts)
        self._res_ys.append(ys)
        self._res_alphas.append(alphas)
        self._res_tickets.append(tickets)
        self._res_count += len(ys)
        m_needed = (self.cfg.m_regression if self.phase == REGRESSION
                    else self.cfg.m_line_search)
        if self._res_count >= m_needed:
            if self.phase == REGRESSION:
                transitions.extend(self._finish_regression())
            else:
                transitions.extend(self._finish_line_search())

    def assimilate_arrays(self, phase_ids: np.ndarray, tickets: np.ndarray,
                          points: np.ndarray, alphas: np.ndarray,
                          validates: np.ndarray,
                          ys: np.ndarray) -> List[Transition]:
        """Array fast path of ``assimilate``: semantically identical to
        feeding ``EvalResult``s one by one (same completion order, same
        transitions), but bulk-appends runs of plain current-phase results
        instead of touching Python objects per evaluation.  ``validates``
        uses -1 for "not a replica"."""
        transitions: List[Transition] = []
        k = len(ys)
        i = 0
        while i < k and self.phase != DONE:
            if self.phase in (REGRESSION, LINESEARCH):
                # During regression/line search, current-phase results are
                # the only ones that change state: quorum replicas only
                # carry a VALIDATING phase id, and stale results are merely
                # counted wherever they sit.  So the remaining block
                # collapses to ONE step — append the first `need`
                # current-phase results, count everything else stale.
                # That equals element-wise processing exactly, including
                # the phase flip at the m-th result: later entries all
                # carry an older phase id (they were issued before this
                # drain), so the flip stales them regardless of position.
                cur = phase_ids[i:] == self.phase_id
                idx = np.flatnonzero(cur) + i
                if idx.size and (validates[idx] >= 0).any():
                    # can't happen with our substrates; keep the slow path
                    # as the semantic reference just in case
                    v = int(validates[i])
                    self._assimilate_one(int(phase_ids[i]), int(tickets[i]),
                                         points[i], float(alphas[i]),
                                         None if v < 0 else v, float(ys[i]),
                                         transitions)
                    i += 1
                    continue
                m_needed = (self.cfg.m_regression if self.phase == REGRESSION
                            else self.cfg.m_line_search)
                take = min(idx.size, m_needed - self._res_count)
                self.stats.assimilated += take
                if take > 0:
                    sel = idx[:take]
                    self._append_results(points[sel],
                                         ys[sel].astype(np.float64),
                                         alphas[sel].astype(np.float64),
                                         tickets[sel], transitions)
                if self.phase != DONE:
                    # the tail is stale under whatever phase the take
                    # flipped to — but if the take finished the RUN, the
                    # object path drops the tail uncounted (its loop
                    # breaks at DONE), so mirror that exactly
                    self.stats.stale += (k - i) - take
                i = k
                continue
            # bootstrap/validating: bulk-skip stale stretches, then handle
            # the (rare, tiny) current-phase events one by one
            cur_rest = np.flatnonzero(phase_ids[i:] == self.phase_id)
            nxt = i + int(cur_rest[0]) if cur_rest.size else k
            if nxt > i:
                self.stats.stale += nxt - i
                i = nxt
                continue
            v = int(validates[i])
            self._assimilate_one(int(phase_ids[i]), int(tickets[i]),
                                 points[i], float(alphas[i]),
                                 None if v < 0 else v, float(ys[i]),
                                 transitions)
            i += 1
        # everything after DONE is dropped exactly like the object path
        return transitions

    # -- phase transitions --------------------------------------------------

    def _finish_regression(self) -> List[Transition]:
        pts = np.concatenate(self._res_pts)
        ys = np.concatenate(self._res_ys)
        d, a_lo, a_hi = _regression_direction(
            jnp.asarray(pts - self.center[None, :], jnp.float32),
            jnp.asarray(ys, jnp.float32),
            jnp.asarray(self.center, jnp.float32),
            jnp.asarray(self.lo, jnp.float32),
            jnp.asarray(self.hi, jnp.float32),
            outlier_guard=self.cfg.outlier_guard, ridge=self.cfg.ridge,
            damping=self.cfg.damping, a_min=self.cfg.alpha_min,
            a_max=self.cfg.alpha_max)
        d = np.asarray(d, np.float64)
        if not np.all(np.isfinite(d)):
            # degenerate fit (f32 eigh/solve can overflow when corrupted
            # samples blow the surrogate up): a zero direction makes the
            # line search re-sample the center, the iteration commits as
            # "no improvement" and the step shrinks — the standard
            # recovery — instead of 0*inf=NaN poisoning every line point
            d = np.zeros_like(d)
            self.alpha_range = (0.0, 0.0)
        else:
            self.alpha_range = (float(a_lo), float(a_hi))
        self.direction = d
        self._advance(LINESEARCH)
        return [Transition("direction", self.iteration)]

    def _finish_line_search(self) -> List[Transition]:
        pts = np.concatenate(self._res_pts)
        ys = np.concatenate(self._res_ys)
        alphas = np.concatenate(self._res_alphas)
        tickets = np.concatenate(self._res_tickets)
        fin = np.isfinite(ys)
        self._line_avg = (float(np.mean(ys[fin])) if fin.any()
                          else float("nan"))
        self._advance(VALIDATING)
        # stable sort by fitness == the element-wise ranking (ties keep
        # completion order); candidates stay as arrays + a cursor
        order = np.argsort(ys[fin], kind="stable")
        self._candidates = (ys[fin][order], pts[fin][order],
                            alphas[fin][order], tickets[fin][order])
        self._cand_next = 0
        return self._start_validation()

    def _start_validation(self) -> List[Transition]:
        if self._candidates is None or \
                self._cand_next >= len(self._candidates[0]):
            # nothing usable: shrink step, next iteration from the same center
            return self._commit(self.center, self.best_fitness, float("nan"),
                                improved=False)
        cy, cp, ca, ct = self._candidates
        i = self._cand_next
        self._cand_next += 1
        self._candidate = (float(cy[i]), cp[i], float(ca[i]), int(ct[i]))
        self._votes = [self._candidate[0]]
        self._pending_validation = self.quorum
        return [Transition("validating", self.iteration)]

    def _check_validation(self) -> List[Transition]:
        need = self.quorum + 1
        if len(self._votes) < need:
            return []
        votes = np.array(self._votes)
        med = np.median(votes)
        agree = np.sum(np.abs(votes - med) <= self.vrtol * max(1.0, abs(med)))
        cand_y, cand_pt, cand_a, _ = self._candidate
        self._candidate = None
        if agree >= (need // 2 + 1) and \
                abs(cand_y - med) <= self.vrtol * max(1.0, abs(med)):
            if self._bootstrapping:
                # confirmed f(x0): seed the threshold, no iteration consumed
                self._bootstrapping = False
                if np.isfinite(med):
                    self.best_fitness = float(med)
                self._advance(REGRESSION)
                return [Transition("bootstrap", self.iteration)]
            improved = med < self.best_fitness - self.cfg.tol
            return self._commit(cand_pt, float(med), cand_a, improved)
        self.stats.validations_failed += 1
        self.stats.candidates_rejected += 1
        if self._bootstrapping:
            # the probe lied (or a replica did): re-run the bootstrap from
            # scratch rather than trusting any of the disputed claims
            self._bootstrapping = False
            self._advance(BOOTSTRAP)
            return [Transition("rejected", self.iteration)]
        return [Transition("rejected", self.iteration)] + self._start_validation()

    def _commit(self, x_next, f_best, alpha, improved: bool) -> List[Transition]:
        if improved:
            self.center = np.asarray(x_next, np.float64)
            self.best_fitness = f_best
        else:
            self.step = self.step * self.cfg.shrink_on_fail
        self.iteration += 1
        self.history.append(IterationRecord(
            iteration=self.iteration, best_fitness=self.best_fitness,
            avg_line_fitness=self._line_avg, center=self.center.copy(),
            evals_used=self.stats.assimilated, best_alpha=alpha))
        transitions = [Transition("commit", self.iteration, improved)]
        if self.iteration >= self.cfg.max_iterations or \
                (not improved and float(np.max(self.step)) < 1e-12):
            self._advance(DONE)
            transitions.append(Transition("done", self.iteration))
        else:
            self._advance(REGRESSION)
        return transitions

    def _advance(self, phase: str) -> None:
        self.phase = phase
        self.phase_id += 1
        self._res_pts = []
        self._res_ys = []
        self._res_alphas = []
        self._res_tickets = []
        self._res_count = 0
        self._candidates = None
        self._cand_next = 0
        self._candidate = None
        self._votes = []
        self._pending_validation = 0
