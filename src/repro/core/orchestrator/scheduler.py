"""FleetScheduler: one shared fleet, many concurrent searches (DESIGN.md §8).

The scheduler owns the resource side of multi-search: it partitions the
shared fleet's host capacity into fixed per-search sub-fleets, admits
searches onto them (engine + stepwise ``BatchedVolunteerGrid`` wired to
the coalescing submitter), and advances every live search ONE tick per
scheduling round, flushing the round's shared bucket as a single device
dispatch.

Capacity is fixed at admission for a search's whole lifetime, on purpose:
a search's virtual grid (host speeds, failure draws, completion order) is
a pure function of its ``GridConfig``, so resizing a live search's fleet
would change the trajectory it commits and break the solo-parity
contract — every orchestrated search must remain bit-identical to the
same engine run alone on the same sub-fleet.  Capacity freed by a
finished or killed search is therefore only recycled into NEW searches
(the director's restart policy), never into running ones.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.engine import AnmEngine
from repro.core.grid import GridConfig
from repro.core.substrates.batched_grid import (BatchedGridStats,
                                                BatchedVolunteerGrid)
from repro.core.substrates.eval_backend import (STAGING_RING, EvalBackend,
                                                bucket_size)
from repro.core.substrates.eval_cache import CachingSubmitter, EvalCache
from repro.core.orchestrator.coalesce import CoalescingSubmitter

#: spacing of derived per-slot grid seeds (a prime, so slots never collide
#: with each other or with small user seed offsets)
SLOT_SEED_STRIDE = 7919

RUNNING, DONE, KILLED = "running", "done", "killed"


@dataclasses.dataclass
class FleetSchedulerStats:
    rounds: int = 0                   # scheduling rounds driven
    steps: int = 0                    # per-search ticks stepped
    admitted: int = 0                 # searches ever admitted
    peak_live: int = 0                # most searches live in one round


class _SharedRingGuard:
    """Uncoalesced multi-search submitter: per-search dispatches straight
    to the backend, but ONE guard across all searches for the backend's
    per-shape staging rings.  Each grid clamps only its OWN pipeline
    depth, so K searches pipelining same-shape buckets would jointly
    overrun the ring; before a submit would alias a still-in-flight slot,
    the guard collects the oldest outstanding handle of that shape early
    (the owning grid's later ``collect`` re-reads the already-materialized
    values — the backend's ownership tokens make a second collect safe,
    and collect timing is invisible to engines by the §7 contract)."""

    def __init__(self, backend: EvalBackend):
        self.backend = backend
        self._inflight: Dict[int, collections.deque] = {}  # kp -> handles
        self._collected: set = set()                       # (kp, seq) done
        self.ring_drains = 0

    def submit(self, pts, mal_u=None):
        kp = bucket_size(len(pts), self.backend.min_bucket)
        dq = self._inflight.setdefault(kp, collections.deque())
        # positional ring: everything older than the newest ring-2
        # submissions of this shape must be collected before submitting
        while len(dq) > STAGING_RING - 2:
            old = dq.popleft()
            key = (old.kp, old.seq)
            if key in self._collected:
                self._collected.discard(key)
            else:
                self.backend.collect(old)     # frees the slot early
                self.ring_drains += 1
        handle = self.backend.submit(pts, mal_u)
        dq.append(handle)
        return handle

    def collect(self, handle):
        dq = self._inflight.get(handle.kp)
        # record only handles the guard still tracks (deques are FIFO in
        # seq order, so anything older than the head was already drained)
        if dq and handle.seq >= dq[0].seq:
            self._collected.add((handle.kp, handle.seq))
        return self.backend.collect(handle)


@dataclasses.dataclass
class LiveSearch:
    """One admitted search: its spec, engine, stepwise grid, and status.
    ``grid_stats`` is sealed by the director when the search leaves the
    fleet (done or killed)."""
    spec: "SearchSpec"                # noqa: F821 — defined in director.py
    engine: AnmEngine
    grid: BatchedVolunteerGrid
    search_id: int
    status: str = RUNNING
    grid_stats: Optional[BatchedGridStats] = None


class FleetScheduler:
    """Partitions host capacity and drives live searches tick-by-tick.

    ``fleet`` describes the TOTAL shared fleet; ``partition``/``subfleet``
    derive the per-search slice.  ``coalesce=True`` (default) routes every
    search's tick blocks through one ``CoalescingSubmitter`` so a round
    costs one device dispatch however many searches are live;
    ``coalesce=False`` keeps per-search dispatches (the serial-equivalent
    baseline the benchmarks time against).  Searches default to the
    pipelined tick loop — coalescing pays off exactly when collects are
    deferred to phase boundaries, so most rounds are pure submits.
    """

    def __init__(self, backend: EvalBackend, fleet: GridConfig, *,
                 coalesce: bool = True, pipelined: bool = True,
                 pipeline_depth: int = 4, tick_batch: Optional[int] = None,
                 overcommit: float = 2.0, min_hosts: int = 16,
                 cache: Optional[EvalCache] = None, dedup: bool = True):
        self.raw_backend = backend
        # the memo layer (DESIGN.md §10) wraps the backend BELOW the
        # coalescer, so exact-hit stripping applies to the whole shared
        # multi-search bucket; bit-exact-only serving keeps every search
        # on its cache-off trajectory (the §8 parity contract holds)
        self.cache = cache
        if cache is not None:
            backend = CachingSubmitter(backend, cache)
        self.backend = backend
        self.fleet = fleet
        self.coalescer = (CoalescingSubmitter(backend, dedup=dedup)
                          if coalesce else None)
        # the uncoalesced path still needs ONE cross-search guard for the
        # backend's staging rings (per-grid depth clamps don't compose)
        self.ring_guard = None if coalesce else _SharedRingGuard(backend)
        self.pipelined = pipelined
        self.pipeline_depth = pipeline_depth
        self.tick_batch = tick_batch
        self.overcommit = overcommit
        self.min_hosts = min_hosts
        self.stats = FleetSchedulerStats()

    # -- capacity ------------------------------------------------------------

    def partition(self, n_searches: int) -> int:
        """Hosts per search: an equal split of the fleet, floored so a
        search is never starved below a working sub-fleet."""
        return max(self.min_hosts,
                   self.fleet.n_hosts // max(n_searches, 1))

    def subfleet(self, slot: int, n_searches: int) -> GridConfig:
        """The sub-fleet the search admitted into ``slot`` owns for its
        whole lifetime.  Fully deterministic: same fleet config + slot =>
        same sub-fleet, which is what lets a solo parity run reconstruct
        exactly the grid an orchestrated search saw."""
        return dataclasses.replace(
            self.fleet, n_hosts=self.partition(n_searches),
            seed=self.fleet.seed + SLOT_SEED_STRIDE * slot)

    def warm(self, n_dims: int, specs: Sequence["SearchSpec"]) -> None:  # noqa: F821
        """Warm the shared backend over the bucket ladder multi-search can
        reach.  Coalescing: one round may carry EVERY live search's tick
        block, so the ladder top is the SUM of the per-search warm bounds.
        Uncoalesced: buckets stay per-search, so the top is their MAX —
        warming the sum there would compile shapes no dispatch can ever
        produce.  Without this, the first full round would compile inside
        the timed/parity path (the zero-compile contract of DESIGN.md §7
        extends to §8)."""
        bounds = [min(spec.grid.n_hosts,
                      BatchedVolunteerGrid.warm_max_bucket(
                          max(spec.anm.m_regression,
                              spec.anm.m_line_search), self.overcommit))
                  for spec in specs]
        top = sum(bounds) if self.coalescer is not None else max(bounds,
                                                                 default=1)
        self.backend.warm(n_dims, bucket_size(max(top, 1),
                                              self.backend.min_bucket))

    # -- search lifecycle ----------------------------------------------------

    def admit(self, spec: "SearchSpec", search_id: int,  # noqa: F821
              max_ticks: int = 1_000_000,
              max_sim_time: float = float("inf")) -> LiveSearch:
        """Bind a search onto the fleet: engine from the spec, a stepwise
        grid on the spec's sub-fleet, submitter routed through the
        coalescer (tagged with ``search_id``) when coalescing is on."""
        engine = spec.build_engine()
        submitter = (self.coalescer.lane_submitter(search_id)
                     if self.coalescer is not None else self.ring_guard)
        grid = BatchedVolunteerGrid(
            None, spec.grid, tick_batch=self.tick_batch,
            overcommit=self.overcommit, backend=self.backend,
            pipelined=self.pipelined, pipeline_depth=self.pipeline_depth,
            submitter=submitter)
        grid.start(engine, max_ticks, max_sim_time)
        self.stats.admitted += 1
        return LiveSearch(spec=spec, engine=engine, grid=grid,
                          search_id=search_id)

    def round(self, live: Sequence[LiveSearch]) -> List[LiveSearch]:
        """One scheduling round: every live search advances one tick, then
        the shared bucket (all their submits) dispatches once.  Returns
        the searches whose runs ended this round (engine done or budget
        hit) — the caller finalizes them."""
        finished: List[LiveSearch] = []
        for ls in live:
            if ls.grid.step():
                self.stats.steps += 1
            else:
                finished.append(ls)
        if self.coalescer is not None:
            self.coalescer.flush()
        self.stats.rounds += 1
        self.stats.peak_live = max(self.stats.peak_live, len(live))
        return finished
