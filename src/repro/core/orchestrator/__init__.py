"""Multi-search orchestrator: N concurrent ANM engines, one shared fleet
(DESIGN.md §8).

Three layers, innermost first:

  * ``coalesce``  — ``CoalescingSubmitter`` folds tick blocks from every
                    live search into ONE shared, search-id-tagged backend
                    bucket per scheduling round (dispatch + padding
                    amortization — the speed story);
  * ``scheduler`` — ``FleetScheduler`` partitions the shared fleet's host
                    capacity into fixed per-search sub-fleets and steps
                    every live search one tick per round;
  * ``director``  — ``SearchDirector`` owns the portfolio: multi-start
                    specs, heterogeneous configs, and the fixed /
                    portfolio-kill / restart policies.

The hard contract: orchestration changes WHEN lanes are evaluated, never
what any engine sees — every orchestrated search commits bit-identical
iterates to the same spec run alone (tests/test_orchestrator.py, the
``--substrate multi_search`` dryrun smoke, and the benchmark gates).
"""
from repro.core.orchestrator.coalesce import (  # noqa: F401
    CoalesceStats, CoalescingSubmitter, LaneSlice)
from repro.core.orchestrator.director import (  # noqa: F401
    MultiSearchResult, SearchDirector, SearchSpec, multi_start_specs)
from repro.core.orchestrator.scheduler import (  # noqa: F401
    DONE, KILLED, RUNNING, FleetScheduler, FleetSchedulerStats, LiveSearch)
