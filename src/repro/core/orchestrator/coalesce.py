"""Cross-search bucket coalescing over one shared EvalBackend (DESIGN.md §8).

K concurrent searches submitting their tick blocks separately pay K device
dispatches per scheduling round, and every small block rounds up to its own
power-of-two bucket — at multi-search scale the padding and the dispatch
round-trips, not the fitness FLOPs, dominate.  ``CoalescingSubmitter``
closes both holes: within a scheduling round, each search's block is
appended to one OPEN shared round; the round dispatches as a single
backend bucket whose lanes are tagged with the submitting search's id
(``EvalHandle.tags`` — per-lane attribution for observability; the demux
itself is positional), and each search gets back a ``LaneSlice`` — a
lazy handle onto its contiguous lane range of the shared result.

Why coalescing cannot change what any engine observes (the safety
argument, pinned by the parity gates): a backend bucket is row-
independent — ``f_batch`` maps each lane to its fitness with no cross-lane
terms, the malicious-corruption mask and the pad-NaN mask are per-lane,
and every bucket width the ladder can produce sits in XLA's bitwise-stable
vectorization regime (the pod backend's 4-rows-per-shard floor exists for
exactly the one known-divergent width).  So a lane evaluated inside a
wide shared bucket carries bit-for-bit the value it would have carried in
the search's own small bucket; the only things coalescing changes are the
padded width paid per real lane and WHEN the dispatch happens — and the
pipelined-parity contract (DESIGN.md §7) already established that collect
timing is invisible to the engine.

The façade each search's grid holds (``lane_submitter(search_id)``) quacks
exactly like an ``EvalBackend``'s submit/collect pair, so
``BatchedVolunteerGrid`` needs no coalescing knowledge: its ``submitter``
seam points here instead of at the backend, and everything else —
pipelining, speculation, staging-ring clamps — behaves identically.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.substrates.eval_backend import (STAGING_RING, EvalBackend,
                                                bucket_size)
from repro.core.substrates.eval_cache import canonical_block


@dataclasses.dataclass
class CoalesceStats:
    """The speed story, measurable: ``dispatches`` vs ``lane_blocks`` is
    the dispatch amortization (one device round-trip now serves that many
    per-search blocks), ``padded_lanes`` vs ``solo_padded_lanes`` the
    padding amortization (width actually paid vs what the same blocks
    would have paid in their own buckets)."""
    dispatches: int = 0               # real device buckets submitted
    lane_blocks: int = 0              # per-search blocks folded into them
    lanes: int = 0                    # real lanes across all dispatches
    padded_lanes: int = 0             # padded width actually paid
    solo_padded_lanes: int = 0        # width the same blocks would pay solo
    forced_flushes: int = 0           # rounds dispatched early by a collect
    ring_drains: int = 0              # old rounds materialized to free slots
    lanes_deduped: int = 0            # duplicate honest lanes evaluated once
    bucket_hist: Dict[int, int] = dataclasses.field(default_factory=dict)


class _Round:
    """One shared bucket being assembled (``handle is None``) or in flight
    (``handle`` set, ``ys`` cached after the first collect).  ``src``,
    set when intra-bucket dedup dropped duplicate lanes, maps each
    ORIGINAL lane position to its representative's position in the
    dispatched bucket — the fan-out plan collect applies."""
    __slots__ = ("pts", "mal_u", "tags", "k", "handle", "ys", "src")

    def __init__(self):
        self.pts: List[np.ndarray] = []
        self.mal_u: List[np.ndarray] = []
        self.tags: List[np.ndarray] = []
        self.k = 0
        self.handle = None
        self.ys: Optional[np.ndarray] = None
        self.src: Optional[np.ndarray] = None


class LaneSlice:
    """One search's contiguous lanes inside a shared coalesced bucket —
    the multi-search counterpart of an ``EvalHandle``.  ``kp`` (the width
    the lanes were actually evaluated at, what the grid's bucket histogram
    records) resolves once the round has dispatched; collecting an
    undispatched slice force-flushes its round first, so the value is
    always available by the time a collector reads it."""
    __slots__ = ("round_", "offset", "k", "tag")

    def __init__(self, round_: _Round, offset: int, k: int, tag: int):
        self.round_ = round_
        self.offset = offset
        self.k = k
        self.tag = tag

    @property
    def kp(self) -> Optional[int]:
        h = self.round_.handle
        return None if h is None else h.kp


class _TaggedSubmitter:
    """Per-search façade bound to (coalescer, search id): the object a
    search's ``BatchedVolunteerGrid`` uses as its ``submitter`` seam."""
    __slots__ = ("_co", "tag")

    def __init__(self, co: "CoalescingSubmitter", tag: int):
        self._co = co
        self.tag = tag

    def submit(self, pts: np.ndarray,
               mal_u: Optional[np.ndarray] = None) -> LaneSlice:
        return self._co.submit(self.tag, pts, mal_u)

    def collect(self, lane: LaneSlice) -> np.ndarray:
        return self._co.collect(lane)


class CoalescingSubmitter:
    """Folds blocks from many searches into shared tagged buckets.

    Protocol: searches ``submit`` into the open round at any time; the
    scheduler calls ``flush()`` once per scheduling round (after stepping
    every live search) to dispatch the shared bucket.  A ``collect`` on a
    lane of the still-open round force-flushes it first — a search that
    must decide a phase transition mid-round never waits on the others.
    Rounds are created and flushed strictly in order, so at most one round
    is ever open.
    """

    def __init__(self, backend: EvalBackend, dedup: bool = True):
        self.backend = backend
        #: evaluate identical honest points coalesced from different
        #: searches in one round ONCE, fanning the value out to every
        #: tagged lane at collect — safe for exactly the reason serving a
        #: bit-exact cache hit is (row independence + width invariance:
        #: a lane's value is a pure function of its staged f32 bytes).
        #: Malicious lanes are never deduped (their value is the per-lane
        #: corrupted lie) and never act as representatives.
        self.dedup = dedup
        self._open: Optional[_Round] = None
        # flushed rounds per bucket shape, submission order: K searches
        # each pipelining a few lane handles can hold MORE uncollected
        # same-shape buckets than one search ever could, so the coalescer
        # — not the per-search depth clamp — must keep the staging ring
        # safe (see flush()); the backend still raises if this ever slips
        self._inflight: Dict[int, collections.deque] = {}
        self.stats = CoalesceStats()

    @property
    def ring_pressure(self) -> int:
        """Uncollected dispatched rounds still holding staging-ring slots
        (materialized mid-deque rounds hold none) — a live gauge for the
        metrics hub, complementing the ``ring_drains`` counter."""
        return sum(1 for dq in self._inflight.values()
                   for r in dq if r.ys is None and r.handle is not None)

    def lane_submitter(self, tag: int) -> _TaggedSubmitter:
        """The submit/collect façade a search's grid plugs in as its
        ``submitter``; ``tag`` is the search id stamped on its lanes."""
        return _TaggedSubmitter(self, tag)

    def submit(self, tag: int, pts: np.ndarray,
               mal_u: Optional[np.ndarray] = None) -> LaneSlice:
        r = self._open
        if r is None:
            r = self._open = _Round()
        k = len(pts)
        lane = LaneSlice(r, r.k, k, tag)
        r.pts.append(np.asarray(pts))
        r.mal_u.append(np.full(k, np.nan) if mal_u is None
                       else np.asarray(mal_u))
        r.tags.append(np.full(k, tag, np.int64))
        r.k += k
        self.stats.lane_blocks += 1
        self.stats.lanes += k
        self.stats.solo_padded_lanes += bucket_size(k,
                                                    self.backend.min_bucket)
        return lane

    def flush(self) -> None:
        """Dispatch the open round as ONE tagged backend bucket (no-op when
        nothing was submitted since the last flush).

        Ring safety: submitting the (STAGING_RING)-th uncollected bucket
        of one shape would restage a buffer the device may still read, so
        before dispatching, the oldest in-flight rounds of this shape are
        materialized early (their values are CACHED on the round — later
        lane collects slice the cache, so consumers never notice; collect
        timing is invisible to the engines by the §7 contract)."""
        r = self._open
        if r is None:
            return
        self._open = None
        pts = r.pts[0] if len(r.pts) == 1 else np.concatenate(r.pts)
        mal_u = r.mal_u[0] if len(r.mal_u) == 1 else np.concatenate(r.mal_u)
        tags = r.tags[0] if len(r.tags) == 1 else np.concatenate(r.tags)
        if self.dedup and r.k > 1:
            keep = self._dedup_plan(r, pts, mal_u)
            if keep is not None:
                pts, mal_u, tags = pts[keep], mal_u[keep], tags[keep]
        # ring pressure is keyed on the width actually dispatched (dedup
        # may have shrunk the bucket below the submitted lane count)
        kp = bucket_size(len(pts), self.backend.min_bucket)
        dq = self._inflight.setdefault(kp, collections.deque())
        # the ring is POSITIONAL (slots rotate round-robin), so the real
        # requirement is that everything older than the newest ring-2
        # submissions of this shape is materialized — pop oldest-first,
        # draining only rounds consumers haven't collected yet (a
        # materialized mid-deque round holds no slot and is not pressure)
        while len(dq) > STAGING_RING - 2:
            old = dq.popleft()
            if old.ys is None:
                old.ys = self._materialize(old)
                self.stats.ring_drains += 1
        r.handle = self.backend.submit(pts, mal_u, lane_tags=tags)
        dq.append(r)
        self.stats.dispatches += 1
        self.stats.padded_lanes += r.handle.kp
        self.stats.bucket_hist[r.handle.kp] = \
            self.stats.bucket_hist.get(r.handle.kp, 0) + 1

    def _dedup_plan(self, r: _Round, pts: np.ndarray,
                    mal_u: np.ndarray) -> Optional[np.ndarray]:
        """Indices of the lanes to dispatch, or ``None`` when every lane
        is unique.  Sets ``r.src`` (original lane -> dispatched position)
        when duplicates were dropped.  The cheap vectorized pre-check
        (all first coordinates distinct => no duplicates possible) keeps
        the common all-unique round at ~one ``np.unique`` call instead of
        a per-lane Python loop."""
        blk = canonical_block(pts)
        if len(np.unique(blk[:, 0])) == r.k:
            return None
        seen: Dict[bytes, int] = {}
        keep: List[int] = []
        src = np.empty(r.k, np.int64)
        dups = 0
        for i in range(r.k):
            if not np.isnan(mal_u[i]):    # malicious lane: its value is
                src[i] = len(keep)        # the per-lane lie — never dedup,
                keep.append(i)            # never a representative
                continue
            key = blk[i].tobytes()
            j = seen.get(key)
            if j is None:
                seen[key] = src[i] = len(keep)
                keep.append(i)
            else:
                src[i] = j
                dups += 1
        if not dups:
            return None
        r.src = src
        self.stats.lanes_deduped += dups
        return np.asarray(keep, np.int64)

    def _materialize(self, r: _Round) -> np.ndarray:
        """Collect a dispatched round and expand the dedup fan-out back
        to the full submitted lane order."""
        ys = self.backend.collect(r.handle)
        return ys if r.src is None else ys[r.src]

    def collect(self, lane: LaneSlice) -> np.ndarray:
        """Materialize one search's lanes.  The shared bucket is collected
        exactly once (first caller blocks, frees the staging slot, and
        caches the values); later lane collects slice the cache."""
        r = lane.round_
        if r.handle is None:
            if r is not self._open:
                raise RuntimeError(
                    "lane belongs to a round that was never dispatched")
            # a mid-round phase decision: dispatch what we have now
            self.stats.forced_flushes += 1
            self.flush()
        if r.ys is None:
            r.ys = self._materialize(r)
        return r.ys[lane.offset:lane.offset + lane.k]
