"""SearchDirector: the portfolio layer over the shared fleet (DESIGN.md §8).

The paper's ANM is a *local* optimizer that FGDO schedules as one of many
concurrent searches over a single volunteer grid; this module is that
outer layer for the reproduction.  A director owns N ``SearchSpec``s
(multi-start seeds, heterogeneous ``AnmConfig``s, different starts and
bounds), admits them onto a ``FleetScheduler``, and applies a restart /
portfolio policy between scheduling rounds:

  * ``fixed``     — run every search to completion (pure multi-start);
  * ``portfolio`` — best-of-portfolio with early kill: a search that has
                    had its probation and still trails the incumbent by
                    the kill margin is retired, freeing its capacity;
  * ``restart``   — every finished search hands its capacity to a fresh
                    search started from a perturbation of the incumbent
                    (the classic multi-start-with-restarts portfolio).

Policies only decide WHICH searches are stepped — never what any engine
sees.  A killed search simply stops being stepped (its committed prefix
is exactly what a solo run would have committed); a restart is a brand
new search on a fresh deterministic spec.  The director's own rng draws
restart perturbations only and never touches per-search rngs, so every
orchestrated trajectory stays bit-identical to a solo run of its spec.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.engine import AnmConfig, AnmEngine
from repro.core.grid import GridConfig
from repro.core.orchestrator.coalesce import CoalesceStats
from repro.core.orchestrator.scheduler import (DONE, KILLED, FleetScheduler,
                                               FleetSchedulerStats,
                                               LiveSearch)
from repro.core.substrates.batched_grid import BatchedVolunteerGrid

#: spacing of derived restart seeds (engine and grid), prime like the
#: scheduler's slot stride so independently-derived streams never collide
RESTART_SEED_STRIDE = 104729


def dominated_cut(best: float, kill_margin: float) -> float:
    """THE portfolio kill threshold: a search trailing the incumbent by
    more than ``kill_margin`` on the sign-safe ``|best| + 1`` scale (the
    same scale as ``grid.malicious_lie``) is dominated.  Shared by the
    director's between-round policy and the work server's portfolio
    routing (``repro/server/server.py``), so the two layers can never
    disagree about what "dominated" means."""
    return best + kill_margin * (abs(best) + 1.0)


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """Everything needed to run one search — and to REPRODUCE it alone:
    a solo ``BatchedVolunteerGrid(None, spec.grid, backend=...)`` run of
    an engine built from these fields commits bit-identical iterates to
    the orchestrated search (the parity contract's baseline)."""
    name: str
    x0: np.ndarray
    lo: np.ndarray
    hi: np.ndarray
    step: np.ndarray
    anm: AnmConfig
    grid: GridConfig                  # the search's fixed sub-fleet
    engine_seed: int = 0
    validation_quorum: int = 2

    def build_engine(self) -> AnmEngine:
        """The engine this spec describes — used by the scheduler at
        admission AND by every parity baseline, so the two can never
        drift apart field by field."""
        return AnmEngine(self.x0, self.lo, self.hi, self.step, self.anm,
                         seed=self.engine_seed,
                         validation_quorum=self.validation_quorum)

    def solo_run(self, backend, *, pipelined: bool = True,
                 tick_batch: Optional[int] = None, overcommit: float = 2.0,
                 pipeline_depth: int = 4) -> AnmEngine:
        """THE parity baseline: this spec's engine alone on this spec's
        sub-fleet over ``backend``.  The knobs mirror `FleetScheduler`'s
        — pass the scheduler's values when checking an orchestrated run.
        Every parity gate (tests, dryrun smoke, benchmark) calls this one
        helper, so a spec field can't be silently dropped from the
        contract."""
        engine = self.build_engine()
        BatchedVolunteerGrid(None, self.grid, tick_batch=tick_batch,
                             overcommit=overcommit, backend=backend,
                             pipelined=pipelined,
                             pipeline_depth=pipeline_depth).run(engine)
        return engine


@dataclasses.dataclass
class MultiSearchResult:
    """Outcome of a director run: every search that ever lived (admission
    order), the round count, and the fleet/coalescing instrumentation."""
    outcomes: List[LiveSearch]
    rounds: int
    scheduler_stats: FleetSchedulerStats
    coalesce_stats: Optional[CoalesceStats]

    @property
    def best(self) -> Optional[LiveSearch]:
        """The incumbent: lowest finite committed fitness across the whole
        portfolio (None only if no search ever committed one)."""
        cands = [o for o in self.outcomes
                 if np.isfinite(o.engine.best_fitness)]
        return min(cands, key=lambda o: o.engine.best_fitness,
                   default=None)


def multi_start_specs(scheduler: FleetScheduler, x0, lo, hi, step,
                      anm: AnmConfig, n_searches: int, *, seed: int = 0,
                      jitter: float = 0.25,
                      configs: Optional[Sequence[AnmConfig]] = None,
                      validation_quorum: int = 2,
                      name: str = "search") -> List[SearchSpec]:
    """The standard multi-start portfolio: search 0 keeps the caller's
    start, the rest perturb it by ``jitter × step`` (clipped to bounds);
    engine seeds and sub-fleets are derived deterministically per slot.
    ``configs`` (cycled) makes the portfolio heterogeneous — e.g. half the
    searches on a cheaper ``m`` than the paper's 1000."""
    rng = np.random.default_rng(seed)
    x0 = np.asarray(x0, np.float64)
    lo, hi = np.asarray(lo, np.float64), np.asarray(hi, np.float64)
    step = np.asarray(step, np.float64)
    specs = []
    for i in range(n_searches):
        xi = x0 if i == 0 or jitter <= 0 else np.clip(
            x0 + jitter * step * rng.standard_normal(x0.shape), lo, hi)
        specs.append(SearchSpec(
            name=f"{name}-{i}", x0=xi, lo=lo, hi=hi, step=step,
            anm=(configs[i % len(configs)] if configs else anm),
            grid=scheduler.subfleet(i, n_searches),
            engine_seed=seed + 101 * i + 1,
            validation_quorum=validation_quorum))
    return specs


class SearchDirector:
    """Runs a portfolio of searches over one ``FleetScheduler``.

    ``kill_margin`` is relative on the ``|best| + 1`` scale (the same
    sign-safe scale as ``grid.malicious_lie``), so portfolios near zero
    or negative fitness behave; ``probation_iterations`` committed
    iterations shield young searches from an early incumbent.
    ``max_rounds`` is a hard scheduling budget — leftover searches are
    retired as killed, never silently dropped."""

    def __init__(self, scheduler: FleetScheduler,
                 specs: Sequence[SearchSpec], policy: str = "fixed", *,
                 kill_margin: float = 0.5, probation_iterations: int = 2,
                 max_restarts: int = 0, restart_sigma: float = 0.25,
                 seed: int = 0, max_rounds: int = 10_000_000,
                 kill_schedule: Optional[dict] = None):
        if policy not in ("fixed", "portfolio", "restart"):
            raise ValueError(f"unknown policy {policy!r}")
        self.scheduler = scheduler
        self.specs = list(specs)
        self.policy = policy
        self.kill_margin = kill_margin
        self.probation_iterations = probation_iterations
        self.max_restarts = max_restarts
        self.restart_sigma = restart_sigma
        self.max_rounds = max_rounds
        self._rng = np.random.default_rng(seed)
        self._restarts_used = 0
        # §14 director-level replay seam: a recorded ``kill_log`` from a
        # defended run ({name: round}) re-applied at the same round
        # boundaries — the director twin of the FleetDefense schedule.
        # A round boundary is a pure function of the scheduling sequence,
        # so a scheduled kill lands at the same committed prefix in any
        # two runs of the same specs.
        self.kill_schedule = dict(kill_schedule) if kill_schedule else None
        self.kill_log: List[dict] = []
        self._live: List[LiveSearch] = []
        self._round = 0

    # -- policy helpers ------------------------------------------------------

    @staticmethod
    def _incumbent(searches: Sequence[LiveSearch]):
        cands = [ls for ls in searches
                 if np.isfinite(ls.engine.best_fitness)]
        return min(cands, key=lambda ls: ls.engine.best_fitness,
                   default=None)

    def _dominated(self, live: Sequence[LiveSearch],
                   everyone: Sequence[LiveSearch]) -> List[LiveSearch]:
        inc = self._incumbent(everyone)
        if inc is None:
            return []
        cut = dominated_cut(inc.engine.best_fitness, self.kill_margin)
        return [ls for ls in live
                if ls.engine.iteration >= self.probation_iterations
                and ls.engine.best_fitness > cut]

    def _restart_spec(self, dead: LiveSearch,
                      everyone: Sequence[LiveSearch]) -> SearchSpec:
        """A fresh spec on the dead search's capacity: start from a
        perturbed incumbent (or the dead search's own start if nothing
        committed yet), with freshly-derived engine and grid seeds."""
        j = self._restarts_used
        base = dead.spec
        inc = self._incumbent(everyone)
        if inc is None:
            x0 = base.x0
        else:
            x0 = np.clip(
                np.asarray(inc.engine.center, np.float64)
                + self.restart_sigma * np.asarray(base.step, np.float64)
                * self._rng.standard_normal(len(base.x0)),
                base.lo, base.hi)
        stride = RESTART_SEED_STRIDE * (j + 1)
        return dataclasses.replace(
            base, name=f"{base.name}~r{j}", x0=x0,
            engine_seed=base.engine_seed + stride,
            grid=dataclasses.replace(base.grid,
                                     seed=base.grid.seed + stride))

    def _retire(self, ls: LiveSearch, status: str) -> None:
        ls.grid_stats = ls.grid.finish()   # drain in-flight buckets
        ls.status = status

    def kill_search(self, name) -> bool:
        """Director seam (§14): retire one live search by verdict —
        ``name`` is the spec name or the admission ``search_id``.  The
        kill is logged with the current round, so ``kill_log`` re-applied
        as ``kill_schedule`` reproduces it at the same boundary.  Safe to
        call from a ``FleetDefense`` verdict between rounds; a name that
        is not live is a no-op (False)."""
        for ls in list(self._live):
            if ls.spec.name == name or ls.search_id == name:
                self._live.remove(ls)
                self._retire(ls, KILLED)
                self.kill_log.append({"name": ls.spec.name,
                                      "round": self._round})
                return True
        return False

    def _apply_kill_schedule(self) -> None:
        if not self.kill_schedule:
            return
        for name, rnd in self.kill_schedule.items():
            if int(rnd) == self._round:
                self.kill_search(name)

    # -- the run loop --------------------------------------------------------

    def run(self, max_ticks: int = 1_000_000,
            max_sim_time: float = float("inf")) -> MultiSearchResult:
        sched = self.scheduler
        if self.specs:
            sched.warm(len(self.specs[0].x0), self.specs)
        live = [sched.admit(spec, i, max_ticks, max_sim_time)
                for i, spec in enumerate(self.specs)]
        self._live = live                  # the kill seam's target list
        everyone = list(live)
        next_id = len(live)
        rounds = 0
        self._round = 0
        self._apply_kill_schedule()        # round-0 kills: before any step
        while live and rounds < self.max_rounds:
            finished = sched.round(live)
            rounds += 1
            self._round = rounds
            self._apply_kill_schedule()
            for ls in finished:
                live.remove(ls)
                self._retire(ls, DONE)
                if self.policy == "restart" \
                        and self._restarts_used < self.max_restarts:
                    spec = self._restart_spec(ls, everyone)
                    self._restarts_used += 1
                    # the restart inherits capacity, not history: it must
                    # fit the warmed ladder, which it does by construction
                    # (same sub-fleet size and an anm no larger than base)
                    nls = sched.admit(spec, next_id, max_ticks,
                                      max_sim_time)
                    next_id += 1
                    live.append(nls)
                    everyone.append(nls)
            if self.policy == "portfolio" and live:
                for ls in self._dominated(live, everyone):
                    live.remove(ls)
                    self._retire(ls, KILLED)
        for ls in live:                    # max_rounds budget exhausted
            self._retire(ls, KILLED)
        return MultiSearchResult(
            outcomes=everyone, rounds=rounds,
            scheduler_stats=sched.stats,
            coalesce_stats=(sched.coalescer.stats
                            if sched.coalescer is not None else None))
