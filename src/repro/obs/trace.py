"""Workunit lifecycle tracing (§14): sampled span records off server hooks.

A span follows one workunit through the paths the server already touches:
issued (lease grant) → [lapsed] → reported → committed / stale / dropped.
Hooks fire from ``WorkServer`` behind a single ``is not None`` check, so
an un-traced server pays one attribute compare per lease event and a
traced one pays a dict write — both far inside the §13 overhead ceiling.

Determinism: whether a workunit is traced is decided by a **keyed hash of
(trace seed, search, wu id)** — splitmix64 over the ids, no RNG object,
no sequential state — so the sampled set is identical across runs,
restores and replays of the same message sequence.  Tracing therefore
cannot perturb anything (the hooks only read), and the sampled population
is reproducible: a post-mortem over two runs of the same seed sees the
same workunits.

Completed spans land in a bounded ring (oldest dropped, counted); the
``RetentionSink`` drains the ring into the snapshot store at hub sample
boundaries.  Nothing here enters ``state_dict``: a restored server starts
a fresh tracer (open spans from before the crash are simply never closed
— the store still holds every span completed and flushed before the
kill, which is the post-mortem contract).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

TRACE_VERSION = 1

_MASK = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """The splitmix64 finalizer: a fast, well-mixed 64-bit hash in pure
    int arithmetic (platform-independent, unlike ``hash``)."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return x ^ (x >> 31)


def wu_sampled(seed: int, search: int, wu: int, rate: float) -> bool:
    """Deterministic keyed sampling decision for one workunit: the same
    (seed, search, wu) always answers the same, on any run."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    h = _splitmix64(_splitmix64(_splitmix64(int(seed)) ^ int(search))
                    ^ int(wu))
    return (h >> 11) / float(1 << 53) < rate


class WorkUnitTracer:
    """Collects sampled lifecycle spans through server hooks.

    ``sample_rate`` is the fraction of workunits traced (keyed on
    (seed, search, wu) — see ``wu_sampled``); ``ring`` bounds completed
    spans held between drains.  Span docs are plain JSON-able dicts::

        {"trace_v": 1, "search": s, "wu": w, "host": h,
         "phase": p, "validates": v_or_null,
         "issued_at": t0, "lapsed_at": t_or_null,
         "reported_at": t1, "outcome": "committed|assimilated|stale|"
                                       "dropped", "late": bool,
         "turnaround": t1 - t0}
    """

    def __init__(self, sample_rate: float = 1.0, ring: int = 1024,
                 seed: int = 0):
        self.sample_rate = float(sample_rate)
        self.ring = int(ring)
        self.seed = int(seed)
        self._open: Dict[Tuple[int, int], dict] = {}
        self._done: collections.deque = collections.deque()
        self.sampled = 0                  # spans opened
        self.skipped = 0                  # unsampled lease grants
        self.completed = 0                # spans closed
        self.ring_dropped = 0             # completed spans lost to the bound

    # -- server hooks --------------------------------------------------------

    def on_issue(self, search: int, wu: int, host: int, now: float,
                 phase: int, validates: Optional[int]) -> None:
        if not wu_sampled(self.seed, search, wu, self.sample_rate):
            self.skipped += 1
            return
        self.sampled += 1
        self._open[(search, wu)] = {
            "trace_v": TRACE_VERSION, "search": int(search), "wu": int(wu),
            "host": int(host), "phase": int(phase),
            "validates": None if validates is None else int(validates),
            "issued_at": float(now), "lapsed_at": None,
        }

    def on_lapse(self, search: int, wu: int, now: float) -> None:
        span = self._open.get((int(search), int(wu)))
        if span is not None and span["lapsed_at"] is None:
            span["lapsed_at"] = float(now)

    def on_settle(self, search: int, wu: int, now: float, outcome: str,
                  late: bool = False) -> None:
        span = self._open.pop((int(search), int(wu)), None)
        if span is None:
            return
        span["reported_at"] = float(now)
        span["outcome"] = str(outcome)
        span["late"] = bool(late)
        span["turnaround"] = float(now) - span["issued_at"]
        if len(self._done) >= self.ring:
            self._done.popleft()
            self.ring_dropped += 1
        self._done.append(span)
        self.completed += 1

    # -- consumption ---------------------------------------------------------

    @property
    def open_spans(self) -> int:
        return len(self._open)

    def drain(self) -> List[dict]:
        """Pop every completed span (oldest first) — the retention sink's
        per-sample sweep."""
        out = list(self._done)
        self._done.clear()
        return out

    def summary(self) -> dict:
        return {"trace_v": TRACE_VERSION, "sample_rate": self.sample_rate,
                "sampled": self.sampled, "skipped": self.skipped,
                "completed": self.completed, "open": self.open_spans,
                "ring_dropped": self.ring_dropped}
