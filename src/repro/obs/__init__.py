"""Live observability plane (DESIGN.md §13): MetricsHub counters/probes,
the ``subscribe_stats`` stream, and anomaly-driven fleet defense."""
from repro.obs.anomaly import (PAGE, QUARANTINE, RELEASE, SCHEDULE_VERSION,
                               AnomalyEvent, FleetDefense)
from repro.obs.metrics import (STREAM_VERSION, MetricsHub, attach_cache,
                               attach_coalescer, attach_engine, attach_grid,
                               attach_intake)
from repro.obs.stream import BackgroundSubscriber, StatsSubscriber

__all__ = [
    "MetricsHub", "STREAM_VERSION", "attach_engine", "attach_grid",
    "attach_coalescer", "attach_cache", "attach_intake",
    "AnomalyEvent", "FleetDefense", "SCHEDULE_VERSION",
    "QUARANTINE", "RELEASE", "PAGE",
    "StatsSubscriber", "BackgroundSubscriber",
]
