"""Observability plane: the live half (DESIGN.md §13 — MetricsHub
counters/probes, the ``subscribe_stats`` stream, anomaly-driven fleet
defense) and the post-mortem half (§14 — durable snapshot/trace
retention, workunit lifecycle tracing, windowed drift defense)."""
from repro.obs.anomaly import (KILL, PAGE, QUARANTINE, RELEASE,
                               SCHEDULE_VERSION, AnomalyEvent, FleetDefense)
from repro.obs.metrics import (STREAM_VERSION, MetricsHub, attach_cache,
                               attach_coalescer, attach_engine, attach_grid,
                               attach_intake)
from repro.obs.retention import (OBS_STORE_DB, OBS_STORE_NAME, STORE_VERSION,
                                 RetentionSink, SnapshotStore,
                                 SqliteSnapshotStore, obs_store_path,
                                 open_snapshot_store)
from repro.obs.stream import BackgroundSubscriber, StatsSubscriber
from repro.obs.trace import TRACE_VERSION, WorkUnitTracer, wu_sampled

__all__ = [
    "MetricsHub", "STREAM_VERSION", "attach_engine", "attach_grid",
    "attach_coalescer", "attach_cache", "attach_intake",
    "AnomalyEvent", "FleetDefense", "SCHEDULE_VERSION",
    "QUARANTINE", "RELEASE", "PAGE", "KILL",
    "StatsSubscriber", "BackgroundSubscriber",
    "SnapshotStore", "SqliteSnapshotStore", "RetentionSink",
    "open_snapshot_store", "obs_store_path", "STORE_VERSION",
    "OBS_STORE_NAME", "OBS_STORE_DB",
    "WorkUnitTracer", "wu_sampled", "TRACE_VERSION",
]
