"""Client side of the ``subscribe_stats`` wire extension (DESIGN.md §13).

The stream is cursor-based long-polling over the existing request/reply
framing: the subscriber sends ``{kind: subscribe_stats, since: cursor}``
and the server answers with every ring-retained snapshot newer than the
cursor plus the new cursor.  No server-side subscriber state, no push
channel, no transport changes — a monitoring connection is just another
client, and (like ``status``) its messages are unstamped, uncounted and
unlogged, so polling at ANY wall-clock rate cannot perturb the replayable
applied sequence.  A subscriber that polls slower than the ring turns
over resumes at the oldest retained snapshot — and since §14 the reply
carries an explicit ``dropped`` count for the gap (optionally shrunk by
``from_store`` retention backfill) instead of silently skipped seqs.
"""
from __future__ import annotations

import threading
from typing import Callable, List, Optional

from repro.server import protocol


class StatsSubscriber:
    """Cursor-tracking poller over one connection (loopback or TCP)."""

    def __init__(self, conn, start_cursor: int = -1,
                 from_store: bool = False):
        self.conn = conn
        self.cursor = int(start_cursor)
        self.from_store = bool(from_store)
        self.received = 0                 # snapshots consumed so far
        self.dropped = 0                  # cumulative ring-gap reported
        self.last_dropped = 0             # gap in the most recent reply

    def poll(self) -> List[dict]:
        """One long-poll round-trip; returns the new snapshots (possibly
        empty).  Raises ``ProtocolError`` if the server has no metrics
        hub attached (stats are opt-in server-side)."""
        rep = self.conn.call(protocol.subscribe_stats(
            self.cursor, from_store=self.from_store))
        if rep.get("kind") == "error":
            raise protocol.ProtocolError(rep.get("error", "stats error"))
        if rep.get("kind") != "stats":
            raise protocol.ProtocolError(
                f"expected a stats reply, got {rep.get('kind')!r}")
        snaps = list(rep.get("snapshots", []))
        self.cursor = int(rep.get("cursor", self.cursor))
        self.last_dropped = int(rep.get("dropped", 0))
        self.dropped += self.last_dropped
        self.received += len(snaps)
        return snaps


class BackgroundSubscriber:
    """A daemon thread polling ``subscribe_stats`` while a run is live —
    the dryrun smoke's live TCP subscriber and the dashboard's feed.

    ``connect`` is called on the thread (so a TCP connect cannot block
    the caller); snapshots are appended under a lock and optionally
    forwarded to ``on_snapshot``.  Errors are collected, not raised: a
    monitoring sidecar must never take the run down.  ``stop()`` closes
    the connection out from under a thread blocked in a long-poll (a
    server shutting down mid-poll would otherwise leave the thread stuck
    until the socket times out) and suppresses the teardown error that
    close provokes — bounded join, nothing on stderr.
    """

    def __init__(self, connect: Callable[[], object], poll_s: float = 0.05,
                 on_snapshot: Optional[Callable[[dict], None]] = None,
                 from_store: bool = False):
        self._connect = connect
        self.poll_s = float(poll_s)
        self._on_snapshot = on_snapshot
        self.from_store = bool(from_store)
        self.snapshots: List[dict] = []
        self.errors: List[str] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._conn = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "BackgroundSubscriber":
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="obs-subscriber")
        self._thread.start()
        return self

    def _run(self) -> None:
        conn = None
        try:
            conn = self._connect()
            self._conn = conn
            sub = StatsSubscriber(conn, from_store=self.from_store)
            while not self._stop.is_set():
                try:
                    snaps = sub.poll()
                except protocol.ProtocolError as e:
                    if not self._stop.is_set():
                        with self._lock:
                            self.errors.append(str(e))
                    return
                with self._lock:
                    self.dropped = sub.dropped
                    if snaps:
                        self.snapshots.extend(snaps)
                if snaps and self._on_snapshot is not None:
                    for s in snaps:
                        self._on_snapshot(s)
                self._stop.wait(self.poll_s)
        except Exception as e:  # noqa: BLE001 — sidecar must not raise
            # a closed socket mid-poll after stop() is the EXPECTED
            # shutdown path, not an error worth surfacing
            if not self._stop.is_set():
                with self._lock:
                    self.errors.append(f"{type(e).__name__}: {e}")
        finally:
            self._conn = None
            if conn is not None:
                try:
                    conn.close()
                except Exception:
                    pass

    def stop(self) -> "BackgroundSubscriber":
        self._stop.set()
        # unblock a thread sitting in recv: close the connection under it
        conn = self._conn
        if conn is not None:
            try:
                conn.close()
            except Exception:
                pass
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        return self

    def summary(self) -> dict:
        with self._lock:
            snaps = list(self.snapshots)
            errors = list(self.errors)
            dropped = self.dropped
        seqs = [int(s["seq"]) for s in snaps]
        return {
            "snapshots": len(snaps),
            "first_seq": seqs[0] if seqs else None,
            "last_seq": seqs[-1] if seqs else None,
            # every snapshot must arrive stamped (seq + virtual time) and
            # the seqs strictly increasing — the smoke gates this
            "stamped_ok": all("seq" in s and "now" in s
                              and s.get("stream_v") is not None
                              for s in snaps)
            and all(a < b for a, b in zip(seqs, seqs[1:])),
            "dropped": dropped,
            "errors": errors,
        }
