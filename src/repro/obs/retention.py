"""Durable metrics retention: the post-mortem half of the obs plane (§14).

PR 9's live plane keeps every snapshot in a bounded in-memory ring, so a
SIGKILL erases the evidence exactly when it matters most.  This module
spills the ring into the §10 store family — the same append-only JSONL /
sqlite discipline as the eval cache and the replay log — WITHOUT touching
the recovery contract:

  * the store is never read back into server state, never logged, never
    replayed; §13's recovery-compatibility argument is untouched (replay
    logs are byte-identical with retention on or off);
  * a crash-restored server opens the SAME store and appends under a new
    **epoch marker**: the dead run's records stay intact (SIGKILL loses
    only an unflushed suffix, same torn-tail story as ``ReplayLog``), and
    the post-mortem CLI can tell the killed run's history from the
    restored run's;
  * retention is **size/age-bounded**: a long-running server compacts the
    store in place (atomic tmp + ``os.replace``, like snapshots) instead
    of growing without bound.

Record layout (one JSON object per line / sqlite row)::

    {"t": "epoch",   "epoch": N, "v": STORE_VERSION}
    {"t": "snap",    "epoch": N, "seq": k, "now": ..., "doc": snapshot}
    {"t": "span",    "epoch": N, "seq": -1, "now": ..., "doc": span}
    {"t": "anomaly", "epoch": N, "seq": k, "now": ..., "doc": event}

Every data record carries its epoch inline, so compaction may drop old
epoch markers without losing attribution.  ``RetentionSink`` is the only
writer during a run: it subscribes to the hub's sample boundary (already
off the per-message path — samples fire every ``interval`` virtual
seconds) and drains snapshot + trace-ring + anomaly records with buffered
writes; the checkpoint manager flushes the store at every snapshot via
``attach_store``, exactly like the eval cache.
"""
from __future__ import annotations

import collections
import json
import os
from typing import Dict, Iterable, List, Optional

#: bumped when the record layout changes; stamped into epoch markers
STORE_VERSION = 1

#: canonical retention-store file inside a checkpoint dir — one
#: convention, so ``--resume`` and the post-mortem CLI find it with no
#: extra plumbing (the sqlite variant uses OBS_STORE_DB)
OBS_STORE_NAME = "obs_store.jsonl"
OBS_STORE_DB = "obs_store.sqlite"


def obs_store_path(ckpt_dir: str, backend: str = "jsonl") -> str:
    return os.path.join(
        ckpt_dir, OBS_STORE_DB if backend == "sqlite" else OBS_STORE_NAME)


def _truncate_torn_tail(path: str) -> int:
    """Drop a SIGKILL-torn trailing partial line so post-restore appends
    never concatenate onto the fragment (same rationale as
    ``ReplayLog.repair``).  Returns bytes dropped."""
    try:
        with open(path, "rb+") as f:
            data = f.read()
            if not data or data.endswith(b"\n"):
                return 0
            keep = data.rfind(b"\n") + 1
            f.truncate(keep)
            return len(data) - keep
    except FileNotFoundError:
        return 0


class SnapshotStore:
    """Append-only JSONL retention store with epoch markers.

    Opening for append (the default) truncates a torn tail, scans the
    survivors to find the last epoch, and appends a fresh epoch marker —
    a restored server's records are separable from the killed run's by
    construction.  ``read_only=True`` (the post-mortem CLI) opens without
    marking a new epoch and never writes.

    ``max_records`` bounds the store: once the live record count exceeds
    ``1.25 × max_records`` the file is compacted in place (atomic tmp +
    replace) down to the newest ``max_records`` data records;
    ``max_age`` additionally drops records older than that many virtual
    seconds behind the newest record at compaction time.  Readers see the
    bound as best-effort — durability of the RECENT window is the
    contract, not completeness of all history.
    """

    def __init__(self, path: str, flush_every: int = 32,
                 max_records: Optional[int] = 20_000,
                 max_age: Optional[float] = None,
                 read_only: bool = False):
        self.path = path
        self.flush_every = max(int(flush_every), 1)
        self.max_records = None if max_records is None else int(max_records)
        self.max_age = None if max_age is None else float(max_age)
        self.read_only = bool(read_only)
        self._since_flush = 0
        self._records: List[dict] = []
        self._f = None
        if not read_only:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            _truncate_torn_tail(path)
        self._load(path)
        last = max((int(r["epoch"]) for r in self._records), default=0)
        if read_only:
            self.epoch = last
        else:
            self.epoch = last + 1
            self._f = open(path, "a")
            self._append_raw({"t": "epoch", "epoch": self.epoch,
                              "v": STORE_VERSION})

    def _load(self, path: str) -> None:
        try:
            with open(path) as f:
                for line in f:
                    if not line.endswith("\n"):
                        break         # torn tail: stop, don't die
                    try:
                        self._records.append(json.loads(line))
                    except ValueError:
                        break         # corrupt tail record: stop, don't die
        except FileNotFoundError:
            pass

    # -- writing -------------------------------------------------------------

    def _append_raw(self, rec: dict) -> None:
        self._records.append(rec)
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()

    def append(self, t: str, doc: dict, seq: int = -1,
               now: float = 0.0) -> None:
        if self.read_only:
            raise RuntimeError("store opened read-only")
        self._append_raw({"t": str(t), "epoch": self.epoch,
                          "seq": int(seq), "now": float(now), "doc": doc})
        if self.max_records is not None \
                and self._data_count() > 1.25 * self.max_records:
            self.compact()

    def _data_count(self) -> int:
        return sum(1 for r in self._records if r["t"] != "epoch")

    def compact(self) -> int:
        """Rewrite the file with only the retained window (newest
        ``max_records`` data records, minus anything older than
        ``max_age``).  Atomic: a crash mid-compaction leaves the previous
        file intact.  Returns the number of records dropped."""
        if self.read_only:
            raise RuntimeError("store opened read-only")
        data = [r for r in self._records if r["t"] != "epoch"]
        keep = data if self.max_records is None else data[-self.max_records:]
        if self.max_age is not None and keep:
            horizon = max(float(r.get("now", 0.0)) for r in keep) \
                - self.max_age
            keep = [r for r in keep if float(r.get("now", 0.0)) >= horizon]
        dropped = len(data) - len(keep)
        if dropped <= 0:
            return 0
        # keep one marker per surviving epoch (ordered), then the data
        epochs_kept = sorted({int(r["epoch"]) for r in keep} | {self.epoch})
        out = [{"t": "epoch", "epoch": e, "v": STORE_VERSION}
               for e in epochs_kept] + keep
        tmp = os.path.join(os.path.dirname(self.path) or ".",
                           f".tmp_obs_store_{os.getpid()}")
        with open(tmp, "w") as f:
            for rec in out:
                f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.close()
        os.replace(tmp, self.path)
        self._records = out
        self._f = open(self.path, "a")
        self._since_flush = 0
        return dropped

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()
        self._since_flush = 0

    def close(self) -> None:
        if self._f is not None:
            self.flush()
            self._f.close()
            self._f = None

    # -- reading -------------------------------------------------------------

    def __len__(self) -> int:
        return self._data_count()

    def epochs(self) -> List[int]:
        return sorted({int(r["epoch"]) for r in self._records})

    def records(self, t: Optional[str] = None,
                epoch: Optional[int] = None) -> List[dict]:
        """Raw records (append order), optionally filtered by type and/or
        epoch.  Returns the record envelopes — ``r["doc"]`` is the
        payload."""
        out = []
        for r in self._records:
            if r["t"] == "epoch":
                continue
            if t is not None and r["t"] != t:
                continue
            if epoch is not None and int(r["epoch"]) != epoch:
                continue
            out.append(r)
        return out

    def snapshots(self, epoch: Optional[int] = None) -> List[dict]:
        return [r["doc"] for r in self.records("snap", epoch)]

    def summary(self) -> dict:
        by_t: Dict[str, int] = collections.Counter(
            r["t"] for r in self._records if r["t"] != "epoch")
        return {"path": self.path, "epoch": self.epoch,
                "epochs": self.epochs(), "records": len(self),
                "by_type": dict(by_t)}


class SqliteSnapshotStore:
    """The sqlite variant: one ``obs_records`` table, committed every
    ``flush_every`` appends (commit-every-N like the sqlite eval cache —
    a SIGKILL loses only the uncommitted suffix).  Same epoch/compaction
    semantics as the JSONL store; ``doc`` is stored as JSON text."""

    def __init__(self, path: str, flush_every: int = 32,
                 max_records: Optional[int] = 20_000,
                 max_age: Optional[float] = None,
                 read_only: bool = False):
        import sqlite3

        self.path = path
        self.flush_every = max(int(flush_every), 1)
        self.max_records = None if max_records is None else int(max_records)
        self.max_age = None if max_age is None else float(max_age)
        self.read_only = bool(read_only)
        self._since_flush = 0
        if not read_only:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._db = sqlite3.connect(path)
        self._db.execute(
            "CREATE TABLE IF NOT EXISTS obs_records ("
            "id INTEGER PRIMARY KEY AUTOINCREMENT, t TEXT NOT NULL, "
            "epoch INTEGER NOT NULL, seq INTEGER, now REAL, doc TEXT)")
        self._db.commit()
        row = self._db.execute(
            "SELECT MAX(epoch) FROM obs_records").fetchone()
        last = int(row[0]) if row and row[0] is not None else 0
        if read_only:
            self.epoch = last
        else:
            self.epoch = last + 1
            self._db.execute(
                "INSERT INTO obs_records (t, epoch, seq, now, doc) "
                "VALUES ('epoch', ?, -1, 0.0, ?)",
                (self.epoch, json.dumps({"v": STORE_VERSION})))
            self._db.commit()

    def append(self, t: str, doc: dict, seq: int = -1,
               now: float = 0.0) -> None:
        if self.read_only:
            raise RuntimeError("store opened read-only")
        self._db.execute(
            "INSERT INTO obs_records (t, epoch, seq, now, doc) "
            "VALUES (?, ?, ?, ?, ?)",
            (str(t), self.epoch, int(seq), float(now),
             json.dumps(doc, separators=(",", ":"))))
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()
        if self.max_records is not None \
                and len(self) > 1.25 * self.max_records:
            self.compact()

    def compact(self) -> int:
        if self.read_only:
            raise RuntimeError("store opened read-only")
        n = len(self)
        drop = 0
        if self.max_records is not None and n > self.max_records:
            cut = self._db.execute(
                "SELECT id FROM obs_records WHERE t != 'epoch' "
                "ORDER BY id DESC LIMIT 1 OFFSET ?",
                (self.max_records - 1,)).fetchone()
            if cut is not None:
                cur = self._db.execute(
                    "DELETE FROM obs_records WHERE t != 'epoch' AND id < ?",
                    (int(cut[0]),))
                drop += cur.rowcount
        if self.max_age is not None:
            row = self._db.execute(
                "SELECT MAX(now) FROM obs_records WHERE t != 'epoch'"
            ).fetchone()
            if row and row[0] is not None:
                cur = self._db.execute(
                    "DELETE FROM obs_records WHERE t != 'epoch' AND now < ?",
                    (float(row[0]) - self.max_age,))
                drop += cur.rowcount
        if drop:
            self._db.commit()
        return drop

    def flush(self) -> None:
        self._db.commit()
        self._since_flush = 0

    def close(self) -> None:
        self.flush()
        self._db.close()

    def __len__(self) -> int:
        return int(self._db.execute(
            "SELECT COUNT(*) FROM obs_records WHERE t != 'epoch'"
        ).fetchone()[0])

    def epochs(self) -> List[int]:
        return [int(r[0]) for r in self._db.execute(
            "SELECT DISTINCT epoch FROM obs_records ORDER BY epoch")]

    def records(self, t: Optional[str] = None,
                epoch: Optional[int] = None) -> List[dict]:
        q = ("SELECT t, epoch, seq, now, doc FROM obs_records "
             "WHERE t != 'epoch'")
        args: list = []
        if t is not None:
            q += " AND t = ?"
            args.append(str(t))
        if epoch is not None:
            q += " AND epoch = ?"
            args.append(int(epoch))
        q += " ORDER BY id"
        return [{"t": r[0], "epoch": int(r[1]), "seq": int(r[2]),
                 "now": float(r[3]), "doc": json.loads(r[4])}
                for r in self._db.execute(q, args)]

    def snapshots(self, epoch: Optional[int] = None) -> List[dict]:
        return [r["doc"] for r in self.records("snap", epoch)]

    def summary(self) -> dict:
        by_t = {r[0]: int(r[1]) for r in self._db.execute(
            "SELECT t, COUNT(*) FROM obs_records WHERE t != 'epoch' "
            "GROUP BY t")}
        return {"path": self.path, "epoch": self.epoch,
                "epochs": self.epochs(), "records": len(self),
                "by_type": by_t}


def open_snapshot_store(path: str, **kwargs):
    """Pick the store backend by extension — ``.sqlite``/``.db`` gets the
    sqlite variant, anything else JSONL (the §10 convention)."""
    if path.endswith((".sqlite", ".db")):
        return SqliteSnapshotStore(path, **kwargs)
    return SnapshotStore(path, **kwargs)


class RetentionSink:
    """Drains the live plane into a ``SnapshotStore`` off the hot path.

    Subscribes at the hub's sample boundary — which fires every
    ``interval`` VIRTUAL seconds, never per message — and on each sample:
    appends the snapshot, drains any completed trace spans from the
    tracer's bounded ring, and appends anomaly events the defense emitted
    since the last sample.  All writes are buffered (the store's
    ``flush_every``); the checkpoint manager's ``attach_store`` flushes
    at every server snapshot, so a SIGKILL loses at most the unflushed
    suffix.  The sink is write-only w.r.t. server state: nothing here is
    logged, replayed, or consulted by recovery.
    """

    def __init__(self, hub, store, tracer=None, defense=None):
        self.store = store
        self.tracer = tracer
        self.snapshots_stored = 0
        self.spans_stored = 0
        self.anomalies_stored = 0
        hub.on_sample(self._on_sample)
        if defense is not None:
            defense.on_event(self._on_anomaly)

    def _on_sample(self, snap: dict) -> None:
        self.store.append("snap", snap, seq=int(snap["seq"]),
                          now=float(snap["now"]))
        self.snapshots_stored += 1
        if self.tracer is not None:
            for span in self.tracer.drain():
                self.store.append("span", span,
                                  now=float(span.get("reported_at")
                                            or span.get("issued_at") or 0.0))
                self.spans_stored += 1

    def _on_anomaly(self, ev) -> None:
        self.store.append("anomaly", ev.to_doc(), seq=int(ev.seq),
                          now=float(ev.now))
        self.anomalies_stored += 1

    def drain_remaining(self) -> None:
        """End-of-run sweep: push spans still sitting in the tracer ring
        (completed after the final sample) before the store closes."""
        if self.tracer is not None:
            for span in self.tracer.drain():
                self.store.append("span", span,
                                  now=float(span.get("reported_at")
                                            or span.get("issued_at") or 0.0))
                self.spans_stored += 1

    def summary(self) -> dict:
        return {"snapshots_stored": self.snapshots_stored,
                "spans_stored": self.spans_stored,
                "anomalies_stored": self.anomalies_stored,
                "store": self.store.summary()}
