"""MetricsHub: the live observability plane's collection core (DESIGN.md §13).

Two publication styles, chosen so the hot paths pay nothing they do not
already pay:

  * **pull probes** — every hot layer already maintains cheap stats
    objects (``ServerCounters``, ``BatchedGridStats``, ``CoalesceStats``,
    ``CacheStats``, the registry's churn ledger, the sequenced intake's
    depth counters).  A probe is a zero-argument callable that reads one
    of them into a plain dict; the hub calls it only at SAMPLE time.  The
    hot path has no new branch, no new write — publishing is free between
    samples by construction.
  * **push counters** — ``inc(name)`` for the handful of events that have
    no existing stats object (registry churn transitions use this via the
    registry's own ints; the hub-level counters exist for ad-hoc layers).
    An increment is one dict ``__setitem__`` — cheap enough to stay on.

Sampling is driven by **virtual time**: ``maybe_sample(now)`` is called at
applied-message boundaries with the server's message-derived clock, so
given the same applied message sequence the snapshot boundaries are
deterministic — which is what lets the anomaly-defense layer
(``repro.obs.anomaly``) act on samples and still replay bit-identically
from a recorded schedule.  Snapshots land in a fixed-size ring
(``maxlen=ring``): the hub's memory is bounded no matter how long the
server runs, and ``since(cursor)`` serves the ``subscribe_stats`` wire
extension by cursor — a slow subscriber misses old snapshots instead of
growing server state.

Nothing here is part of any ``state_dict``: snapshots are never logged,
never replayed, and a crash-restored server starts a fresh ring (§13's
recovery-compatibility argument — observability must not perturb the
replay contract, so it owns no replayable state).
"""
from __future__ import annotations

import collections
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: version stamped into every snapshot and ``stats`` reply — a consumer
#: of the stream checks this, not PROTOCOL_VERSION (the framing version)
STREAM_VERSION = 1


def _plain(x):
    """Sanitize probe output for the wire codecs: numpy scalars → python,
    non-finite floats kept (both codecs carry them), dict keys → str
    (msgpack allows int keys but JSON silently rewrites them — emit one
    shape so codec choice can never change a snapshot's schema)."""
    # scalar leaves first (bool is an int subclass, so one check covers
    # it): they are ~90% of snapshot nodes and this walk runs per sample
    if x is None or isinstance(x, (int, float, str)):
        return x
    if isinstance(x, dict):
        return {str(k): _plain(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_plain(v) for v in x]
    item = getattr(x, "item", None)           # numpy scalars
    if callable(item):
        return _plain(item())
    return str(x)


class MetricsHub:
    """Counters + probes in, stamped ring-buffered snapshots out."""

    def __init__(self, interval: float = 25.0, ring: int = 256):
        if interval <= 0:
            raise ValueError("interval must be positive virtual seconds")
        self.interval = float(interval)
        self.ring = int(ring)
        self._probes: "collections.OrderedDict[str, Tuple[Callable[[], dict], Tuple[str, ...]]]" = \
            collections.OrderedDict()
        self._counters: Dict[str, int] = {}
        self._snapshots: collections.deque = collections.deque(maxlen=ring)
        self._seq = 0
        #: next virtual time a ``maybe_sample`` will fire (None: fires on
        #: the first call).  Public so the server's per-message hook can
        #: inline the compare and skip the call entirely between samples.
        self.next_sample_at: Optional[float] = None
        self._prev: Optional[dict] = None      # last snapshot, for rates
        self._subscribers: List[Callable[[dict], None]] = []

    # -- publication side ----------------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        """Monotonic push counter — one dict write, safe on any path."""
        self._counters[name] = self._counters.get(name, 0) + n

    def counter(self, name: str) -> int:
        return self._counters.get(name, 0)

    def register_probe(self, name: str, fn: Callable[[], dict],
                       rates: Sequence[str] = (),
                       plain: bool = False) -> None:
        """Register a sample-time reader.  ``fn()`` must return a plain
        dict of scalars/lists (read-only: a probe must never mutate what
        it reads).  Keys named in ``rates`` additionally get a derived
        ``<key>_per_s`` gauge from the delta vs the previous snapshot in
        virtual time (how ``messages/sec`` is produced without any hot-
        path timing).  ``plain=True`` promises the output is ALREADY
        codec-neutral (python scalars, str keys, fresh dicts) and skips
        the per-sample sanitizing walk — the server's own probes qualify,
        and at fleet scale that walk was a measurable share of the §13
        overhead budget."""
        self._probes[name] = (fn, tuple(rates), bool(plain))

    def on_sample(self, cb: Callable[[dict], None]) -> None:
        """Run ``cb(snapshot)`` synchronously after every sample — the
        anomaly-defense hook.  Callbacks run at the deterministic sample
        boundary, in registration order."""
        self._subscribers.append(cb)

    # -- sampling ------------------------------------------------------------

    def maybe_sample(self, now: float) -> Optional[dict]:
        """Sample iff ``interval`` virtual seconds elapsed since the last
        snapshot (and once immediately on the first call).  Called at
        applied-message boundaries; deterministic in the applied order."""
        if self.next_sample_at is not None and now < self.next_sample_at:
            return None
        snap = self.sample(now)
        self.next_sample_at = now + self.interval
        return snap

    def sample(self, now: float) -> dict:
        groups: Dict[str, dict] = {}
        for name, (fn, rates, plain) in self._probes.items():
            doc = fn() if plain else _plain(fn())
            if rates and self._prev is not None:
                dt = float(now) - float(self._prev["now"])
                prev_doc = self._prev["groups"].get(name, {})
                for key in rates:
                    cur, old = doc.get(key), prev_doc.get(key)
                    if dt > 0 and isinstance(cur, (int, float)) \
                            and isinstance(old, (int, float)):
                        doc[key + "_per_s"] = (cur - old) / dt
            groups[name] = doc
        snap = {
            "stream_v": STREAM_VERSION,
            "seq": self._seq,
            "now": float(now),
            "counters": dict(self._counters),
            "groups": groups,
        }
        self._seq += 1
        self._snapshots.append(snap)
        self._prev = snap
        for cb in self._subscribers:
            cb(snap)
        return snap

    # -- consumption side ----------------------------------------------------

    @property
    def seq(self) -> int:
        """Stamps handed out so far (next snapshot gets this seq)."""
        return self._seq

    def latest(self) -> Optional[dict]:
        return self._snapshots[-1] if self._snapshots else None

    def since(self, cursor: int) -> Tuple[List[dict], int, int]:
        """Snapshots with ``seq > cursor`` (oldest first), the new cursor,
        and the count of snapshots the cursor missed because the ring
        already dropped them.  A consumer that fell off the ring resumes
        at the oldest retained snapshot — by design, not an error — but
        the gap is reported, not silent (§14 satellite)."""
        out = [s for s in self._snapshots if s["seq"] > cursor]
        new_cursor = out[-1]["seq"] if out else max(cursor, self._seq - 1)
        if self._snapshots:
            oldest = self._snapshots[0]["seq"]
        else:
            oldest = self._seq                 # nothing retained at all
        dropped = max(0, oldest - max(cursor, -1) - 1)
        return out, new_cursor, dropped

    def series(self, group: str, key: str) -> List[Tuple[float, float]]:
        """One gauge's retained time-series: [(now, value), ...] — the
        dashboard's sparkline source."""
        out = []
        for s in self._snapshots:
            v = s["groups"].get(group, {}).get(key)
            if isinstance(v, (int, float)) and math.isfinite(float(v)):
                out.append((s["now"], float(v)))
        return out


# -- probe adapters for the hot layers ----------------------------------------
#
# Each helper registers a read-only view over a layer's existing stats
# object.  They live here (not in the layers) so a layer imports nothing
# from the obs plane — instrumentation is attach-time wiring, and a build
# without observability never touches this module.

def attach_engine(hub: MetricsHub, engine, name: str = "engine") -> None:
    """Phase machine + commit trajectory: phase, iteration (== commits),
    best fitness, and the full ``EngineStats`` counter set."""
    import dataclasses

    def probe() -> dict:
        d = dataclasses.asdict(engine.stats)
        d.update(phase=engine.phase, iteration=engine.iteration,
                 best_fitness=engine.best_fitness,
                 commits=len(engine.history))
        return d

    hub.register_probe(name, probe)


def attach_grid(hub: MetricsHub, grid, name: str = "grid") -> None:
    """Tick counters + the live device-pipeline depth of a
    ``BatchedVolunteerGrid``."""
    import dataclasses

    def probe() -> dict:
        d = dataclasses.asdict(grid.stats)
        d["in_flight"] = grid.in_flight
        return d

    hub.register_probe(name, probe, rates=("ticks",))


def attach_coalescer(hub: MetricsHub, submitter,
                     name: str = "coalescer") -> None:
    """Dispatch/padding amortization counters + live ring pressure of a
    ``CoalescingSubmitter``."""
    import dataclasses

    def probe() -> dict:
        d = dataclasses.asdict(submitter.stats)
        d["ring_pressure"] = submitter.ring_pressure
        return d

    hub.register_probe(name, probe)


def attach_cache(hub: MetricsHub, cache, name: str = "cache") -> None:
    """Hit/miss/store counters of an ``EvalCache`` (the same doc the wire
    ``status`` reply carries)."""
    hub.register_probe(name, cache.status, rates=("hits", "misses"))


def attach_intake(hub: MetricsHub, intake, name: str = "intake") -> None:
    """Sequenced-intake pressure: next expected stamp, parked arrivals,
    out-of-band (retry) deliveries."""

    def probe() -> dict:
        return {"next_seq": intake.next_seq, "parked": intake.parked,
                "out_of_band": intake.out_of_band}

    hub.register_probe(name, probe)
