"""Anomaly detection over the stats stream + deterministic fleet defense.

``FleetDefense`` subscribes to a ``MetricsHub`` (``hub.on_sample``) and
watches the server-side snapshot groups — ``registry`` and ``server``
only, the groups whose values are a pure function of the applied message
sequence (client-side groups like the pool's are racy-by-design gauges
and MUST NOT feed a gate).  Detectors:

  * **suspect cohort** — hosts newly flipped alive→suspect/dead since the
    last page.  Gate-affecting: the cohort is QUARANTINED in the
    ``HostRegistry`` (``reliable()`` → False), shrinking the reliable set
    that ``FgdoAnmServer`` draws latency-critical validation replicas
    from.  A host that revives (any-contact) is RELEASED — and, per the
    paging contract, each cohort transition fires exactly once: a host
    that stays suspect across many samples does not re-page, a release
    does not re-page, and only a fresh alive→suspect transition after a
    revival pages again.
  * **stale-rate spike** — phase-stale returns per returned result over
    the last sample window above ``stale_rate_spike``.  Page-only.
  * **duplicate-report spike** — duplicate report deliveries per window
    above ``dup_spike``.  Page-only.
  * **cache hit-rate collapse** — hit rate dropping below
    ``hit_rate_floor`` after having been above it.  Page-only.
  * **turnaround drift** (window detector, §14) — per state-cohort EWMA
    of the registry's mean turnaround: a fast EWMA drifting more than
    ``turnaround_drift`` above the slow baseline EWMA pages the cohort.
    Page-only; armed when ``turnaround_drift > 0``.
  * **search stall** (window detector, §14) — a running search with no
    committed improvement (iteration or best fitness) for
    ``stall_window`` consecutive samples is KILLED through the director
    seam (``director.kill_search(search_id)`` — the work server and the
    orchestrator's ``SearchDirector`` both implement it).  Gate-affecting:
    recorded as a ``kill_search`` event and re-applied at the recorded
    seq on replay, exactly like quarantine.  Armed when
    ``stall_window > 0`` and a director is attached.

Page-only events are recorded but touch no gate: they are operator
signal.  Every event (gate-affecting or not) is appended to a JSON-able
**anomaly schedule** keyed by snapshot ``seq``, and mirrored to any
``on_event`` sink (the retention store's post-mortem feed).

Determinism story (the §13 gate): sampling happens at applied-message
boundaries in virtual time, so snapshot ``seq`` k lands at the same
applied message in any two runs with the same message prefix.  A live
defended run records ``(seq, action, hosts)``; a REPLAY run
(``FleetDefense.replay(schedule)``) applies exactly those actions at
exactly those seqs without consulting the detectors.  By induction the
two runs apply identical registry mutations at identical boundaries —
bit-identical committed iterates, solo-reproducible from the recorded
schedule.  (A crash-restored defended run is reproduced the same way:
re-run from the recorded schedule.  Observability WITHOUT defense owns
no mutable state at all, so its crash story is the unchanged §9 one.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

SCHEDULE_VERSION = 1

#: gate-affecting actions — the only ones a replay applies
QUARANTINE, RELEASE = "quarantine", "release"
#: gate-affecting director action (§14): retire a stalled search
KILL = "kill_search"
#: page-only action: recorded, surfaced, no gate effect
PAGE = "page"


@dataclasses.dataclass
class AnomalyEvent:
    seq: int                          # snapshot seq the verdict fired at
    now: float                        # that snapshot's virtual time
    kind: str                         # suspect_cohort | revived_cohort |
    #                                   stale_spike | dup_spike |
    #                                   cache_collapse
    action: str                       # quarantine | release | page
    hosts: List[int]                  # affected cohort (empty for rates)
    detail: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_doc(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, d: dict) -> "AnomalyEvent":
        return cls(seq=int(d["seq"]), now=float(d["now"]),
                   kind=str(d["kind"]), action=str(d["action"]),
                   hosts=[int(h) for h in d["hosts"]],
                   detail=dict(d.get("detail", {})))


class FleetDefense:
    """Anomaly verdicts paging the registry's scheduling gates.

    Live mode (``schedule=None``): detect on every hub sample, apply
    quarantine/release, record the schedule.  Replay mode (``schedule``
    given): apply the recorded gate actions at their recorded seqs,
    detectors off — the solo-reproducibility twin of a live run.
    """

    def __init__(self, registry, hub, *, schedule: Optional[dict] = None,
                 min_cohort: int = 1, stale_rate_spike: float = 0.5,
                 dup_spike: int = 8, hit_rate_floor: float = 0.2,
                 director=None, stall_window: int = 0,
                 turnaround_drift: float = 0.0, ewma_alpha: float = 0.25):
        self.registry = registry
        self.min_cohort = int(min_cohort)
        self.stale_rate_spike = float(stale_rate_spike)
        self.dup_spike = int(dup_spike)
        self.hit_rate_floor = float(hit_rate_floor)
        # §14 window detectors: ``director`` is the kill seam (anything
        # with ``kill_search(search_id)`` — the work server or the
        # orchestrator's SearchDirector); stall_window counts samples,
        # turnaround_drift is the fractional fast-over-slow EWMA trigger
        self.director = director
        self.stall_window = int(stall_window)
        self.turnaround_drift = float(turnaround_drift)
        self.ewma_alpha = float(ewma_alpha)
        self.events: List[AnomalyEvent] = []
        self._paged: Set[int] = set()         # hosts currently quarantined
        self._rate_latched: Set[str] = set()  # page-only detectors latched
        self._hit_rate_seen_high = False
        self._prev_groups: Optional[dict] = None
        self._killed: Set[int] = set()        # searches killed by verdict
        self._stall: Dict[int, list] = {}     # sid -> [iter, best, count]
        self._ewma: Dict[str, list] = {}      # cohort -> [fast, slow, n]
        self._sinks: List = []
        self._replay: Optional[Dict[int, List[AnomalyEvent]]] = None
        if schedule is not None:
            if int(schedule.get("v", -1)) != SCHEDULE_VERSION:
                raise ValueError(
                    f"anomaly schedule version {schedule.get('v')!r} != "
                    f"{SCHEDULE_VERSION}")
            self._replay = {}
            for ed in schedule["events"]:
                ev = AnomalyEvent.from_doc(ed)
                self._replay.setdefault(ev.seq, []).append(ev)
        hub.on_sample(self._on_sample)

    @classmethod
    def replay(cls, registry, hub, schedule: dict,
               director=None) -> "FleetDefense":
        return cls(registry, hub, schedule=schedule, director=director)

    def on_event(self, cb) -> None:
        """Mirror every recorded event to ``cb(event)`` — the retention
        sink's feed.  Called after the event is applied and appended."""
        self._sinks.append(cb)

    @property
    def live(self) -> bool:
        return self._replay is None

    # -- the sample hook -----------------------------------------------------

    def _on_sample(self, snap: dict) -> None:
        if self._replay is not None:
            for ev in self._replay.get(int(snap["seq"]), []):
                self._apply(ev)
                self._record(ev)
            return
        self._detect_cohort(snap)
        self._detect_rates(snap)
        if self.turnaround_drift > 0.0:
            self._detect_turnaround(snap)
        if self.stall_window > 0 and self.director is not None:
            self._detect_stall(snap)

    def _record(self, ev: AnomalyEvent) -> None:
        self.events.append(ev)
        for cb in self._sinks:
            cb(ev)

    def _apply(self, ev: AnomalyEvent) -> None:
        if ev.action == QUARANTINE:
            for h in ev.hosts:
                self.registry.quarantine(h)
            self._paged.update(ev.hosts)
        elif ev.action == RELEASE:
            for h in ev.hosts:
                self.registry.release(h)
            self._paged.difference_update(ev.hosts)
        elif ev.action == KILL:
            sid = int(ev.detail["search_id"])
            if sid not in self._killed and self.director is not None:
                self.director.kill_search(sid)
            self._killed.add(sid)

    # -- live detectors ------------------------------------------------------

    def _detect_cohort(self, snap: dict) -> None:
        reg = snap["groups"].get("registry")
        if reg is None:
            return
        down = {int(h) for h in reg.get("suspect_ids", [])} \
            | {int(h) for h in reg.get("dead_ids", [])}
        newly = sorted(down - self._paged)
        if len(newly) >= self.min_cohort:
            ev = AnomalyEvent(
                seq=int(snap["seq"]), now=float(snap["now"]),
                kind="suspect_cohort", action=QUARANTINE, hosts=newly,
                detail={"suspect": float(len(down))})
            self._apply(ev)
            self._record(ev)
        revived = sorted(self._paged - down)
        if revived:
            ev = AnomalyEvent(
                seq=int(snap["seq"]), now=float(snap["now"]),
                kind="revived_cohort", action=RELEASE, hosts=revived)
            self._apply(ev)
            self._record(ev)

    def _detect_rates(self, snap: dict) -> None:
        srv = snap["groups"].get("server", {})
        reg = snap["groups"].get("registry", {})
        cache = snap["groups"].get("cache")
        prev = self._prev_groups
        self._prev_groups = {"server": srv, "registry": reg}

        def delta(cur: dict, old: dict, key: str) -> float:
            c, o = cur.get(key), old.get(key)
            if isinstance(c, (int, float)) and isinstance(o, (int, float)):
                return float(c) - float(o)
            return 0.0

        def fire(name: str, cond: bool, detail: Dict[str, float]) -> None:
            # latch per detector: fire on the False→True edge only, re-arm
            # once the condition clears — a sustained spike is one page
            if cond and name not in self._rate_latched:
                self._rate_latched.add(name)
                self._record(AnomalyEvent(
                    seq=int(snap["seq"]), now=float(snap["now"]),
                    kind=name, action=PAGE, hosts=[], detail=detail))
            elif not cond:
                self._rate_latched.discard(name)

        if prev:
            d_ret = delta(reg, prev["registry"], "returned")
            d_stale = delta(reg, prev["registry"], "stale_returns")
            rate = d_stale / d_ret if d_ret > 0 else 0.0
            fire("stale_spike", d_ret > 0 and rate > self.stale_rate_spike,
                 {"stale_rate": rate})
            d_dup = delta(srv, prev["server"], "duplicate_reports")
            fire("dup_spike", d_dup > self.dup_spike,
                 {"duplicate_reports": d_dup})
        if cache is not None:
            hr = cache.get("hit_rate")
            if isinstance(hr, (int, float)):
                if hr >= self.hit_rate_floor:
                    self._hit_rate_seen_high = True
                fire("cache_collapse",
                     self._hit_rate_seen_high and hr < self.hit_rate_floor,
                     {"hit_rate": float(hr)})

    # -- §14 window detectors ------------------------------------------------

    def _detect_turnaround(self, snap: dict) -> None:
        """Per state-cohort EWMA drift: a fast EWMA of the cohort's mean
        turnaround rising more than ``turnaround_drift`` above the slow
        baseline pages that cohort.  Page-only, latched per cohort."""
        reg = snap["groups"].get("registry", {})
        by_state = reg.get("latency_by_state")
        if not isinstance(by_state, dict):
            return
        for state, mean in by_state.items():
            if not isinstance(mean, (int, float)):
                continue
            mean = float(mean)
            ent = self._ewma.get(state)
            if ent is None:
                self._ewma[state] = [mean, mean, 1]
                continue
            a = self.ewma_alpha
            ent[0] += a * (mean - ent[0])            # fast
            ent[1] += (a / 4.0) * (mean - ent[1])    # slow baseline
            ent[2] += 1
            name = f"turnaround_drift:{state}"
            drifted = (ent[2] >= 8 and ent[1] > 0.0
                       and ent[0] > (1.0 + self.turnaround_drift) * ent[1])
            if drifted and name not in self._rate_latched:
                self._rate_latched.add(name)
                self._record(AnomalyEvent(
                    seq=int(snap["seq"]), now=float(snap["now"]),
                    kind="turnaround_drift", action=PAGE, hosts=[],
                    detail={"state_cohort": state, "fast_ewma": ent[0],
                            "slow_ewma": ent[1],
                            "drift": ent[0] / ent[1] - 1.0}))
            elif not drifted:
                self._rate_latched.discard(name)

    def _detect_stall(self, snap: dict) -> None:
        """Per-search stall: a RUNNING search whose (iteration, best) pair
        hasn't moved for ``stall_window`` consecutive samples is retired
        through the director seam.  Gate-affecting, fires once per
        search."""
        srv = snap["groups"].get("server", {})
        searches = srv.get("searches")
        if not isinstance(searches, list):
            return
        for s in searches:
            sid = int(s["search_id"])
            if s.get("status") != "running" or sid in self._killed:
                self._stall.pop(sid, None)
                continue
            prog = (int(s.get("iteration", 0)), float(s.get("best", 0.0)))
            ent = self._stall.get(sid)
            if ent is None or (ent[0], ent[1]) != prog:
                self._stall[sid] = [prog[0], prog[1], 0]
                continue
            ent[2] += 1
            if ent[2] >= self.stall_window:
                ev = AnomalyEvent(
                    seq=int(snap["seq"]), now=float(snap["now"]),
                    kind="search_stall", action=KILL, hosts=[],
                    detail={"search_id": float(sid),
                            "window": float(ent[2]),
                            "iteration": float(prog[0]),
                            "best": prog[1]})
                self._apply(ev)
                self._record(ev)
                self._stall.pop(sid, None)

    # -- the recorded schedule -----------------------------------------------

    def schedule_doc(self) -> dict:
        """The JSON-able record a replay run reproduces this run from.
        Only gate-affecting events matter for reproduction; page-only
        events ride along as the operator log."""
        return {"v": SCHEDULE_VERSION,
                "events": [e.to_doc() for e in self.events]}

    def summary(self) -> dict:
        by_action: Dict[str, int] = {}
        for e in self.events:
            by_action[e.action] = by_action.get(e.action, 0) + 1
        return {"mode": "live" if self.live else "replay",
                "events": len(self.events), "by_action": by_action,
                "quarantined_now": len(self._paged),
                "searches_killed": sorted(self._killed)}
