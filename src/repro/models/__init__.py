"""Model zoo: unified transformer covering the 10 assigned architectures."""
from repro.models.transformer import (  # noqa: F401
    find_segments,
    forward,
    head_weight,
    init_cache,
    init_params,
    layer_sigs,
    make_loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    count_params,
    ShardCtx,
    NULL_CTX,
)
from repro.models.sharding import (  # noqa: F401
    cache_specs,
    enforce_divisible,
    input_specs,
    mesh_axes,
    param_specs,
    to_named,
)
