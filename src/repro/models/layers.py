"""Core transformer layers: norms, RoPE, GQA/MLA/SWA attention, SwiGLU, MoE.

Everything is a pure function over explicit parameter dicts so that pjit /
GSPMD sharding can be annotated from the outside (see transformer.param_specs).
Compute dtype follows the params (bf16 by default); softmax/norm statistics
are accumulated in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.use_layernorm:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(key, cfg: ModelConfig, d: int, dtype) -> Params:
    del key
    p = {"scale": jnp.ones((d,), dtype)}
    if cfg.use_layernorm:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# Rotary position embeddings (GPT-NeoX half-split convention)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    inv_freq = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# int8 cache quantization (per-position scales)
# ---------------------------------------------------------------------------

def quant_write(cache_q, cache_scale, value, idx_prefix):
    """value: (B, 1, ...) new entry -> int8 store + f32 scale at position."""
    v32 = value.astype(jnp.float32)
    red_axes = tuple(range(2, v32.ndim))
    scale = jnp.max(jnp.abs(v32), axis=red_axes, keepdims=False) / 127.0
    scale = jnp.maximum(scale, 1e-8)                  # (B, 1)
    q = jnp.clip(jnp.round(v32 / scale.reshape(scale.shape + (1,) * len(red_axes))),
                 -127, 127).astype(jnp.int8)
    cache_q = jax.lax.dynamic_update_slice(cache_q, q, idx_prefix + (0,) * len(red_axes))
    cache_scale = jax.lax.dynamic_update_slice(cache_scale, scale, idx_prefix[:2])
    return cache_q, cache_scale


def dequant(cache_q, cache_scale, dtype):
    """(B, S, ...) int8 + (B, S) scales -> dtype."""
    extra = cache_q.ndim - 2
    return (cache_q.astype(jnp.float32)
            * cache_scale.reshape(cache_scale.shape + (1,) * extra)).astype(dtype)


# ---------------------------------------------------------------------------
# Dense attention (MHA / GQA), with causal / sliding-window / bidirectional
# masks, prefill and single-token decode with (ring-buffered) KV cache.
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, hkv, hd = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    hq = cfg.padded_heads            # pad heads are inert (masked output+grad)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = d ** -0.5
    p: Params = {
        "wq": (jax.random.normal(k1, (d, hq, hd)) * sd).astype(dtype),
        "wk": (jax.random.normal(k2, (d, hkv, hd)) * sd).astype(dtype),
        "wv": (jax.random.normal(k3, (d, hkv, hd)) * sd).astype(dtype),
        "wo": (jax.random.normal(k4, (hq, hd, d)) * (hq * hd) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def head_mask(cfg: ModelConfig):
    """(Hp,) mask — 1 for real heads, 0 for TP-alignment pad heads.  Applied
    to attention output BEFORE wo, so pad heads contribute zero output and
    receive zero gradient (exactly inert; published arch preserved)."""
    if cfg.padded_heads == cfg.n_heads:
        return None
    return (jnp.arange(cfg.padded_heads) < cfg.n_heads)


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array) -> jax.Array:
    """q: (B,S,H,D)  k/v: (B,T,KV,D)  mask: (B,1,1,S,T) bool -> (B,S,H,D)."""
    b, s, h, dd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, dd)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k).astype(jnp.float32)
    scores = scores * (dd ** -0.5)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(b, s, h, v.shape[-1])


def _prefill_mask(cfg: ModelConfig, positions: jax.Array) -> jax.Array:
    """(B,1,1,S,S) mask from (B,S) positions."""
    qp = positions[:, None, None, :, None]
    kp = positions[:, None, None, None, :]
    if not cfg.causal:
        return jnp.ones_like(qp == kp)
    mask = kp <= qp
    if cfg.sliding_window > 0:
        mask = mask & (qp - kp < cfg.sliding_window)
    return mask


def attention_block(
    x: jax.Array,
    p: Params,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[Params] = None,
    t: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Dense GQA attention. If ``cache`` is given, performs one decode step:
    x is (B, 1, d), ``t`` is the scalar current length; returns updated cache.
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if cfg.use_kernels and cfg.causal:
            # routed hot path (DESIGN.md §11): Pallas flash attention on
            # TPU, kernels/ref.py oracle on CPU.  The kernels take
            # positions as implicit arange, which the loss/train forward
            # guarantees; non-causal and decode paths keep the dense mask.
            from repro.kernels import ops as K
            out = K.routed_attention(q, k, v, causal=True,
                                     window=cfg.sliding_window)
        else:
            mask = _prefill_mask(cfg, positions)
            out = _attend(q, k, v, mask)
    else:
        window = cache["k"].shape[1]
        idx = t % window if cfg.sliding_window > 0 else t
        if cfg.quantized_cache:
            ckq, cks = quant_write(cache["k"], cache["k_scale"], k, (0, idx))
            cvq, cvs = quant_write(cache["v"], cache["v_scale"], v, (0, idx))
            ck = dequant(ckq, cks, q.dtype)
            cv = dequant(cvq, cvs, q.dtype)
            cache = {"k": ckq, "v": cvq, "k_scale": cks, "v_scale": cvs}
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
            cache = {"k": ck, "v": cv}
        valid = jnp.arange(window)[None, None, None, None, :] <= t
        out = _attend(q, ck, cv, valid)
    hm = head_mask(cfg)
    if hm is not None:
        out = out * hm[None, None, :, None].astype(out.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache


def attention_cache_shape(cfg: ModelConfig, batch: int, max_seq: int):
    """Cache held per attention layer (sliding-window archs use a ring buffer)."""
    seq = min(max_seq, cfg.sliding_window) if cfg.sliding_window > 0 else max_seq
    hd = cfg.resolved_head_dim
    shapes = {"k": (batch, seq, cfg.n_kv_heads, hd),
              "v": (batch, seq, cfg.n_kv_heads, hd)}
    if cfg.quantized_cache:
        shapes["k_scale"] = (batch, seq)
        shapes["v_scale"] = (batch, seq)
    return shapes


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2).  The KV cache holds only the
# compressed latent c_kv (rank) plus the shared rope key — the paper-assigned
# arch's memory trick.  ``absorb=True`` uses the weight-absorption decode
# optimization (q projected into latent space; no per-step decompression).
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    keys = jax.random.split(key, 6)
    sd = d ** -0.5
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "wq": (jax.random.normal(keys[0], (d, h, qd)) * sd).astype(dtype),
        "w_dkv": (jax.random.normal(keys[1], (d, m.kv_lora_rank)) * sd).astype(dtype),
        "w_krope": (jax.random.normal(keys[2], (d, m.qk_rope_head_dim)) * sd).astype(dtype),
        "w_uk": (jax.random.normal(keys[3], (m.kv_lora_rank, h, m.qk_nope_head_dim))
                 * m.kv_lora_rank ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(keys[4], (m.kv_lora_rank, h, m.v_head_dim))
                 * m.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(keys[5], (h, m.v_head_dim, d))
               * (h * m.v_head_dim) ** -0.5).astype(dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
    }
    return p


def mla_block(
    x: jax.Array,
    p: Params,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[Params] = None,
    t: Optional[jax.Array] = None,
    absorb: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    m: MLAConfig = cfg.mla
    h = cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_krope"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is None:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", c_kv, p["w_uv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                            (*k_rope.shape[:2], h, m.qk_rope_head_dim))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        mask = _prefill_mask(cfg, positions)
        out = _attend(qq, k, v, mask)
        y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
        return y, None

    if cfg.quantized_cache:
        ckq, cks = quant_write(cache["c_kv"], cache["c_kv_scale"], c_kv, (0, t))
        crq, crs = quant_write(cache["k_rope"], cache["k_rope_scale"], k_rope, (0, t))
        ck = dequant(ckq, cks, x.dtype)
        cr = dequant(crq, crs, x.dtype)
        cache = {"c_kv": ckq, "k_rope": crq, "c_kv_scale": cks, "k_rope_scale": crs}
    else:
        ck = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, t, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, t, 0))
        cache = {"c_kv": ck, "k_rope": cr}
    seq = ck.shape[1]
    valid = (jnp.arange(seq)[None, None, :] <= t)  # (1,1,T)
    if absorb:
        # score = q_nopeᵀ W_uk c_kv  +  q_ropeᵀ k_rope   (no decompression)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat, ck)
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, cr)
        scores = (s_nope + s_rope).astype(jnp.float32)
        scores = scores * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
        scores = jnp.where(valid[:, :, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ck)
        out = jnp.einsum("bshr,rhv->bshv", o_lat, p["w_uv"])
    else:
        k_nope = jnp.einsum("btr,rhk->bthk", ck, p["w_uk"])
        v = jnp.einsum("btr,rhv->bthv", ck, p["w_uv"])
        k = jnp.concatenate([k_nope, jnp.broadcast_to(cr[:, :, None, :],
                            (*cr.shape[:2], h, m.qk_rope_head_dim))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _attend(qq, k, v, valid[:, :, None, None, :])
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, cache


def mla_cache_shape(cfg: ModelConfig, batch: int, max_seq: int):
    m = cfg.mla
    shapes = {"c_kv": (batch, max_seq, m.kv_lora_rank),
              "k_rope": (batch, max_seq, m.qk_rope_head_dim)}
    if cfg.quantized_cache:
        shapes["c_kv_scale"] = (batch, max_seq)
        shapes["k_rope_scale"] = (batch, max_seq)
    return shapes


# ---------------------------------------------------------------------------
# SwiGLU MLP and GShard-style MoE
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, ff)) * d ** -0.5).astype(dtype),
        "w_in": (jax.random.normal(k2, (d, ff)) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(k3, (ff, d)) * ff ** -0.5).astype(dtype),
    }


def mlp_block(x: jax.Array, p: Params) -> jax.Array:
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    return jnp.einsum("bsf,fd->bsd", g * h, p["w_out"])


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d, ff, e = cfg.d_model, m.expert_d_ff, m.n_experts
    keys = jax.random.split(key, 5)
    p: Params = {
        "router": (jax.random.normal(keys[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(keys[1], (e, d, ff)) * d ** -0.5).astype(dtype),
        "w_in": (jax.random.normal(keys[2], (e, d, ff)) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(keys[3], (e, ff, d)) * ff ** -0.5).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(keys[4], d, ff * m.n_shared_experts, dtype)
    return p


def moe_capacity(m: MoEConfig, tokens_per_group: int) -> int:
    cap = int(tokens_per_group * m.experts_per_token * m.capacity_factor / m.n_experts)
    return max(cap, 4)


def moe_block(x: jax.Array, p: Params, cfg: ModelConfig,
              ctx=None) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe.dispatch == "grouped":
        return moe_block_grouped(x, p, cfg, ctx)
    return moe_block_global(x, p, cfg)


def moe_block_grouped(x: jax.Array, p: Params, cfg: ModelConfig,
                      ctx=None) -> Tuple[jax.Array, jax.Array]:
    """Per-batch-row (GShard group) sort-based dispatch.

    Tokens never leave their batch row during sort/position assignment, so
    the only cross-shard movement is buffer<->expert resharding over the
    model axis; the combine payload is (B, S, d) instead of (tokens·k, d).
    Capacity (and hence drops) are per group.  vmapped over the batch dim.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.experts_per_token
    cap = moe_capacity(m, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (b,s,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9, None)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    def dispatch_group(xg, ids_g):
        """xg: (s,d); ids: (s,k) -> buf (e,cap,d)."""
        ids = ids_g.reshape(-1)
        order = jnp.argsort(ids)
        sid = ids[order]
        src = order // k
        counts = jax.ops.segment_sum(jnp.ones_like(sid), sid, num_segments=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(s * k) - starts[sid]
        keep = pos < cap
        pos_c = jnp.where(keep, pos, 0)
        buf = jnp.zeros((e, cap, d), xg.dtype)
        return buf.at[sid, pos_c].add(jnp.where(keep[:, None], xg[src], 0))

    buf = jax.vmap(dispatch_group)(x, gate_idx)
    if ctx is not None and ctx.mesh is not None:
        # group dim over dp, expert dim over tp: resharding into the expert
        # matmul is an all-to-all-shaped exchange, not a token all-reduce
        buf = ctx.cons_spec(buf, ("dp", ctx.tp, None, None))

    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    hmid = jnp.einsum("becd,edf->becf", buf, p["w_in"])
    out_buf = jnp.einsum("becf,efd->becd", g * hmid, p["w_out"])

    def combine_group(out_b, ids_g, wts_g):
        ids = ids_g.reshape(-1)
        order = jnp.argsort(ids)
        sid = ids[order]
        src = order // k
        counts = jax.ops.segment_sum(jnp.ones_like(sid), sid, num_segments=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(s * k) - starts[sid]
        keep = pos < cap
        pos_c = jnp.where(keep, pos, 0)
        w_sorted = wts_g.reshape(-1)[order]
        contrib = out_b[sid, pos_c] * jnp.where(keep, w_sorted, 0.0)[:, None].astype(out_b.dtype)
        return jnp.zeros((s, out_b.shape[-1]), out_b.dtype).at[src].add(contrib)

    y = jax.vmap(combine_group)(out_buf, gate_idx, gate_vals)
    if ctx is not None and ctx.mesh is not None:
        y = ctx.cons(y, None, None)

    if m.n_shared_experts:
        y = y + mlp_block(x, p["shared"])
    return y, aux


def moe_block_global(x: jax.Array, p: Params, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Sort-based top-k dispatch with static per-expert capacity.

    Memory is O(e·cap·d) (vs. O(tokens·e·cap) for one-hot GShard dispatch):
    token→expert assignments are sorted, each expert receives a contiguous
    run scattered into a fixed (e, cap, d) buffer; overflow tokens are
    dropped (capacity_factor controls drop rate).  Returns (y, aux_loss).
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.experts_per_token
    tokens = b * s
    cap = moe_capacity(m, tokens)
    xf = x.reshape(tokens, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (t,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9, None)

    # load-balance aux loss (Switch-style): e * Σ_e f_e · p̄_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e), axis=0)
    aux = e * jnp.sum(me * ce)

    ids = gate_idx.reshape(-1)                               # (t·k,)
    wts = gate_vals.reshape(-1)
    order = jnp.argsort(ids)                                 # stable
    sid = ids[order]
    src = order // k                                         # source token index
    counts = jax.ops.segment_sum(jnp.ones_like(sid), sid, num_segments=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(tokens * k) - starts[sid]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[sid, pos_c].add(jnp.where(keep[:, None], xf[src], 0))

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    hmid = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    out_buf = jnp.einsum("ecf,efd->ecd", g * hmid, p["w_out"])

    contrib = out_buf[sid, pos_c] * jnp.where(keep, wts[order], 0.0)[:, None].astype(x.dtype)
    y = jnp.zeros((tokens, d), x.dtype).at[src].add(contrib)
    y = y.reshape(b, s, d)

    if m.n_shared_experts:
        y = y + mlp_block(x, p["shared"])
    return y, aux
