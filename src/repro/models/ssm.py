"""Attention-free mixers: RWKV6 "Finch" (data-dependent decay) and Mamba2.

Both provide a chunked parallel form for training/prefill (matmul-dominated,
MXU-friendly — this is the TPU adaptation of the CUDA recurrences) and an O(1)
recurrent form for decode.  Sequential oracles live in kernels/ref.py.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

# Clamp on per-step log-decay so the factorized chunked form stays inside
# f32 range (see DESIGN.md; fidelity impact negligible: w >= exp(-3.5)).
# With chunk=16 and midpoint normalization, |exponent| <= 3.5*8 = 28, so the
# masked upper-triangle products stay finite (<= e^56) in f32.
_LOG_DECAY_MIN = -3.5
_RWKV_CHUNK = 16
_MAMBA_CHUNK = 64


# ---------------------------------------------------------------------------
# RWKV6 time mix
# ---------------------------------------------------------------------------

def init_rwkv6(key, cfg: ModelConfig, dtype) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.ssm.head_dim
    h = d // hd
    ks = jax.random.split(key, 10)
    sd = d ** -0.5
    lora = max(32, hd // 2)
    return {
        # time-mix interpolation coefficients (token shift)
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "w_r": (jax.random.normal(ks[0], (d, h, hd)) * sd).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d, h, hd)) * sd).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d, h, hd)) * sd).astype(dtype),
        "w_g": (jax.random.normal(ks[3], (d, h, hd)) * sd).astype(dtype),
        "w_o": (jax.random.normal(ks[4], (h, hd, d)) * sd).astype(dtype),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.linspace(-6.0, -1.0, d).reshape(h, hd).astype(dtype),
        "w_lora_a": (jax.random.normal(ks[5], (d, lora)) * sd).astype(dtype),
        "w_lora_b": (jax.random.normal(ks[6], (lora, h, hd)) * lora ** -0.5).astype(dtype),
        "u": (jax.random.normal(ks[7], (h, hd)) * 0.1).astype(dtype),
        "ln_out": jnp.ones((h, hd), dtype),
        # channel mix
        "mu_k_cm": jnp.full((d,), 0.5, dtype), "mu_r_cm": jnp.full((d,), 0.5, dtype),
        "w_k_cm": (jax.random.normal(ks[8], (d, ff)) * sd).astype(dtype),
        "w_v_cm": (jax.random.normal(ks[9], (ff, d)) * ff ** -0.5).astype(dtype),
        "w_r_cm": (jax.random.normal(ks[0], (d, d)) * sd).astype(dtype),
    }


def wkv6_chunked(r, k, v, lw, u, chunk: int = _RWKV_CHUNK, s0=None):
    """Chunked-parallel WKV6 recurrence.

    r,k,v: (B,T,H,K) — K = head_dim (square K==V per RWKV6).
    lw:    (B,T,H,K) per-channel log decay (<= 0, clamped).
    u:     (H,K) bonus.
    s0:    optional initial state (B,H,K,K).
    Returns (o (B,T,H,K), s_final (B,H,K,K)).

    Semantics (per step): o_t = r_t·(S_{t-1} + diag(u) k_t v_tᵀ);
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ.
    """
    b, t, h, kk = r.shape
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    f32 = jnp.float32
    r_, k_, v_ = (a.astype(f32).reshape(b, nc, chunk, h, kk) for a in (r, k, v))
    # contract: decay is clamped (see _LOG_DECAY_MIN) so factorized exps fit f32
    lw_ = jnp.clip(lw.astype(f32), _LOG_DECAY_MIN, -1e-6)
    lw_ = lw_.reshape(b, nc, chunk, h, kk)

    L = jnp.cumsum(lw_, axis=2)                    # inclusive Σ log w within chunk
    # midpoint normalization keeps exp() in f32 range
    c = L[:, :, chunk // 2 : chunk // 2 + 1]
    Lq = jnp.concatenate([jnp.zeros_like(L[:, :, :1]), L[:, :, :-1]], axis=2)  # L_{t-1}
    rt = r_ * jnp.exp(Lq - c)                      # r̃
    kt = k_ * jnp.exp(c - L)                       # k̃

    # within-chunk token-token term: strictly lower triangular + u-diagonal
    m = jnp.einsum("bnchk,bnshk->bnhcs", rt, kt)
    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)
    m = m * tri
    diag = jnp.einsum("bnchk,hk,bnchk->bnch", r_, u.astype(f32), k_)
    o_intra = jnp.einsum("bnhcs,bnshv->bnchv", m, v_) + diag[..., None] * v_

    # chunk-state contributions and inter-chunk scan:
    #   S_end = exp(L_C)⊙S0 + Σ_τ exp(L_C - L_τ) k_τ v_τᵀ
    decay_full = jnp.exp(L[:, :, -1])              # Π w over chunk (B,nc,H,K)
    add = jnp.einsum("bnshk,bnshv->bnhkv", k_ * jnp.exp(L[:, :, -1:] - L), v_)

    if s0 is None:
        s0 = jnp.zeros((b, h, kk, kk), f32)

    def scan_body(s, inp):
        dec, ad, rt_n, c_n = inp
        o_cross = jnp.einsum("bchk,bhkv->bchv", rt_n * jnp.exp(c_n), s)
        s_new = dec[..., None] * s + ad
        return s_new, o_cross

    # reorganize per-chunk tensors for scan over nc
    dec_s = jnp.moveaxis(decay_full, 1, 0)         # (nc,B,H,K)
    add_s = jnp.moveaxis(add, 1, 0)                # (nc,B,H,K,V)
    rt_s = jnp.moveaxis(rt, 1, 0)                  # (nc,B,C,H,K)
    c_s = jnp.moveaxis(c, 1, 0)                    # (nc,B,1,H,K)
    s_fin, o_cross = jax.lax.scan(scan_body, s0, (dec_s, add_s, rt_s, c_s))
    o_cross = jnp.moveaxis(o_cross, 0, 1)          # (B,nc,C,H,V)

    o = (o_intra + o_cross).reshape(b, t, h, kk)
    return o.astype(r.dtype), s_fin


def wkv6_step(r, k, v, lw, u, s):
    """One recurrent step. r,k,v,lw: (B,H,K); s: (B,H,K,V) f32."""
    f32 = jnp.float32
    r_, k_, v_, lw_ = (a.astype(f32) for a in (r, k, v, lw))
    kv = k_[..., :, None] * v_[..., None, :]               # (B,H,K,V)
    o = jnp.einsum("bhk,bhkv->bhv", r_, s + u.astype(f32)[..., None] * kv)
    s_new = jnp.exp(lw_)[..., None] * s + kv
    return o.astype(r.dtype), s_new


def rwkv6_time_mix(x, p, cfg: ModelConfig, shift_state=None, wkv_state=None):
    """RWKV6 attention replacement.

    x: (B,T,D). If states given, T must be 1 (decode step).
    Returns (y, (new_shift, new_wkv)).
    """
    b, t, d = x.shape
    hd = cfg.ssm.head_dim
    h = d // hd
    if shift_state is None:
        xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xx = shift_state[:, None, :]
    delta = xx - x
    x_r, x_k = x + delta * p["mu_r"], x + delta * p["mu_k"]
    x_v, x_g = x + delta * p["mu_v"], x + delta * p["mu_g"]
    x_w = x + delta * p["mu_w"]

    r = jnp.einsum("btd,dhk->bthk", x_r, p["w_r"])
    k = jnp.einsum("btd,dhk->bthk", x_k, p["w_k"])
    v = jnp.einsum("btd,dhk->bthk", x_v, p["w_v"])
    g = jax.nn.silu(jnp.einsum("btd,dhk->bthk", x_g, p["w_g"]))

    lora = jnp.einsum("btl,lhk->bthk",
                      jnp.tanh(jnp.einsum("btd,dl->btl", x_w, p["w_lora_a"])),
                      p["w_lora_b"])
    lw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32),
                           None, 1.2528))          # exp(1.2528) = 3.5
    lw = jnp.clip(lw, _LOG_DECAY_MIN, -1e-6)

    if wkv_state is None:
        if cfg.use_kernels:
            # routed hot path (DESIGN.md §11): Pallas wkv6 on TPU, the
            # kernels/ref.py sequential oracle on CPU.  Loss/train
            # forwards discard the recurrent state, so the routed leg
            # returns a zero state; prefill-into-cache and decode keep
            # the chunked scan below (which threads it correctly).
            from repro.kernels import ops as K
            o = K.routed_wkv6(r, k, v, lw, p["u"])
            s_fin = jnp.zeros((b, h, hd, hd), jnp.float32)
        else:
            o, s_fin = wkv6_chunked(r, k, v, lw, p["u"])
    else:
        o1, s_fin = wkv6_step(r[:, 0], k[:, 0], v[:, 0], lw[:, 0], p["u"], wkv_state)
        o = o1[:, None]

    # per-head group norm, gate, out proj
    o32 = o.astype(jnp.float32)
    mu = jnp.mean(o32, axis=-1, keepdims=True)
    var = jnp.var(o32, axis=-1, keepdims=True)
    o = ((o32 - mu) * jax.lax.rsqrt(var + 64e-5) * p["ln_out"].astype(jnp.float32)
         ).astype(x.dtype)
    y = jnp.einsum("bthk,hkd->btd", o * g, p["w_o"])
    return y, (x[:, -1, :], s_fin)


def rwkv6_channel_mix(x, p, shift_state=None):
    """RWKV6 FFN (relu² channel mix). Returns (y, new_shift)."""
    if shift_state is None:
        xx = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xx = shift_state[:, None, :]
    delta = xx - x
    x_k = x + delta * p["mu_k_cm"]
    x_r = x + delta * p["mu_r_cm"]
    k = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", x_k, p["w_k_cm"])))
    kv = jnp.einsum("btf,fd->btd", k, p["w_v_cm"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", x_r, p["w_r_cm"]))
    return r * kv, x[:, -1, :]


def rwkv6_state_shape(cfg: ModelConfig, batch: int):
    hd = cfg.ssm.head_dim
    h = cfg.d_model // hd
    return {
        "shift_tm": (batch, cfg.d_model),
        "shift_cm": (batch, cfg.d_model),
        "wkv": (batch, h, hd, hd),
    }


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    """The canonical fused in_proj/conv is split into z / xs / BC / dt parts so
    TP can shard d_inner cleanly (depthwise conv is per-channel, so splitting
    the conv is mathematically identical — see DESIGN.md)."""
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    bc_ch = 2 * s.n_groups * s.state_size
    ks = jax.random.split(key, 6)
    sd = d ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d, d_in)) * sd).astype(dtype),
        "w_xs": (jax.random.normal(ks[1], (d, d_in)) * sd).astype(dtype),
        "w_bc": (jax.random.normal(ks[2], (d, bc_ch)) * sd).astype(dtype),
        "w_dt": (jax.random.normal(ks[3], (d, h)) * sd).astype(dtype),
        "conv_w_xs": (jax.random.normal(ks[4], (s.conv_width, d_in)) * 0.2).astype(dtype),
        "conv_b_xs": jnp.zeros((d_in,), dtype),
        "conv_w_bc": (jax.random.normal(ks[5], (s.conv_width, bc_ch)) * 0.2).astype(dtype),
        "conv_b_bc": jnp.zeros((bc_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "dd": jnp.ones((h,), dtype),
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5).astype(dtype),
    }


def _segsum(a):
    """a: (..., C) log-decays -> (..., C, C) lower-tri decay matrix exp(Σ)."""
    c = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    # decay from s (exclusive) to t (inclusive): cs_t - cs_s
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), k=0)
    # mask BEFORE exp: exp(+big) in the untaken branch would make grads NaN
    return jnp.exp(jnp.where(mask, seg, -jnp.inf))


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv. xbc: (B,T,C); w: (W,C). state: (B,W-1,C)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(out + b), new_state


def mamba2_mixer(x, p, cfg: ModelConfig, state=None):
    """Mamba2 block. x: (B,T,D). state: {"conv": (B,W-1,C), "ssm": (B,H,P,N)} for
    decode (T==1).  Returns (y, new_state)."""
    s = cfg.ssm
    b, t, d = x.shape
    d_in = s.expand * d
    g, n, pdim = s.n_groups, s.state_size, s.head_dim
    h = d_in // pdim

    z = jnp.einsum("btd,de->bte", x, p["w_z"])
    xs_raw = jnp.einsum("btd,de->bte", x, p["w_xs"])
    bc_raw = jnp.einsum("btd,de->bte", x, p["w_bc"])
    dt_raw = jnp.einsum("btd,dh->bth", x, p["w_dt"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)

    cs_xs = None if state is None else state["conv_xs"]
    cs_bc = None if state is None else state["conv_bc"]
    xs_c, new_conv_xs = _causal_conv(xs_raw, p["conv_w_xs"], p["conv_b_xs"], cs_xs)
    bc_c, new_conv_bc = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"], cs_bc)
    xs = xs_c.reshape(b, t, h, pdim)
    bb = bc_c[..., : g * n].reshape(b, t, g, n)
    cc = bc_c[..., g * n :].reshape(b, t, g, n)
    # broadcast groups over heads
    rep = h // g
    bb = jnp.repeat(bb, rep, axis=2)                       # (B,T,H,N)
    cc = jnp.repeat(cc, rep, axis=2)

    a = -jnp.exp(p["a_log"])                               # (H,) negative
    la = (dt * a).astype(jnp.float32)                      # (B,T,H) log decay
    xs32 = xs.astype(jnp.float32) * dt[..., None]          # fold dt into x

    if state is None:
        y, s_fin = _ssd_chunked(xs32, la, bb.astype(jnp.float32),
                                cc.astype(jnp.float32))
    else:
        h0 = state["ssm"]
        dec = jnp.exp(la[:, 0])                            # (B,H)
        s_fin = dec[..., None, None] * h0 + jnp.einsum(
            "bhp,bhn->bhpn", xs32[:, 0], bb[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", cc[:, 0].astype(jnp.float32), s_fin)[:, None]

    y = y + p["dd"].astype(jnp.float32)[:, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    # RMS norm before out projection
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + 1e-5) * p["norm"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    new_state = {"conv_xs": new_conv_xs, "conv_bc": new_conv_bc, "ssm": s_fin}
    return out, new_state


def _ssd_chunked(xs, la, bb, cc, chunk: int = _MAMBA_CHUNK):
    """Chunked SSD. xs: (B,T,H,P) f32 (dt folded in); la: (B,T,H) log decay;
    bb/cc: (B,T,H,N).  Returns (y (B,T,H,P), final state (B,H,P,N))."""
    b, t, h, pdim = xs.shape
    n = bb.shape[-1]
    pad = (-t) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // chunk
    xs = xs.reshape(b, nc, chunk, h, pdim)
    la = la.reshape(b, nc, chunk, h)
    bb = bb.reshape(b, nc, chunk, h, n)
    cc = cc.reshape(b, nc, chunk, h, n)

    lam = jnp.moveaxis(la, 3, 2)                           # (B,nc,H,C)
    dmat = _segsum(lam)                                    # (B,nc,H,C,C)
    # within-chunk
    scores = jnp.einsum("bnchk,bnshk->bnhcs", cc, bb)
    y_diag = jnp.einsum("bnhcs,bnhcs,bnshp->bnchp", scores, dmat, xs)

    # chunk-final states
    cum = jnp.cumsum(lam, axis=-1)                         # (B,nc,H,C)
    dec_to_end = jnp.exp(cum[..., -1:] - cum)              # (B,nc,H,C)
    s_chunk = jnp.einsum("bnhs,bnshk,bnshp->bnhpk", dec_to_end, bb, xs)
    dec_full = jnp.exp(cum[..., -1])                       # (B,nc,H)

    def scan_body(carry, inp):
        dec, sc, cc_n, cum_n = inp
        y_off = jnp.einsum("bchk,bhpk,bhc->bchp", cc_n, carry, jnp.exp(cum_n))
        new = dec[..., None, None] * carry + sc
        return new, y_off

    init = jnp.zeros((b, h, pdim, n), jnp.float32)
    # exp(cum) decays from chunk start (exclusive) to t (inclusive):
    cum_in = jnp.moveaxis(cum, 1, 0)                       # (nc,B,H,C)
    s_fin, y_off = jax.lax.scan(
        scan_body, init,
        (jnp.moveaxis(dec_full, 1, 0), jnp.moveaxis(s_chunk, 1, 0),
         jnp.moveaxis(cc, 1, 0), cum_in))
    y_off = jnp.moveaxis(y_off, 0, 1)                      # (B,nc,C,H,P)

    y = (y_diag + y_off).reshape(b, tt, h, pdim)
    return y[:, :t], s_fin


def mamba2_state_shape(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = d_in // s.head_dim
    return {
        "conv_xs": (batch, s.conv_width - 1, d_in),
        "conv_bc": (batch, s.conv_width - 1, 2 * s.n_groups * s.state_size),
        "ssm": (batch, h, s.head_dim, s.state_size),
    }
