"""Unified model: one implementation covers all 10 assigned architectures.

The layer stack is segmented into maximal repeating units (``find_segments``)
so homogeneous runs are executed with ``jax.lax.scan`` over stacked params —
this keeps HLO size and compile time bounded for 80-layer configs, and gives
the dry-run a scan-structured program (one layer's collectives, not 80 copies).

Everything is pure functions: ``init_params`` / ``forward`` / ``make_train_step``
/ ``make_serve_step`` plus the sharding mirrors ``param_specs`` / ``cache_specs``
/ ``input_specs`` consumed by launch/dryrun.py and launch/train.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import ssm as S

Params = Dict[str, Any]
Sig = Tuple[str, bool]  # (block kind, is_moe)


# ---------------------------------------------------------------------------
# Layer-stack segmentation
# ---------------------------------------------------------------------------

def layer_sigs(cfg: ModelConfig) -> List[Sig]:
    return [(kind, cfg._layer_is_moe(i)) for i, kind in enumerate(cfg.blocks())]


def find_segments(sigs: List[Sig]) -> List[Tuple[Tuple[Sig, ...], int]]:
    """Greedy maximal-coverage periodic segmentation: list of (unit, repeat)."""
    segs, i, n = [], 0, len(sigs)
    while i < n:
        best = None
        for u in range(1, min(16, n - i) + 1):
            r = 1
            while i + u * (r + 1) <= n and sigs[i + u * r : i + u * (r + 1)] == sigs[i : i + u]:
                r += 1
            if r >= 2 and (best is None or u * r > best[0] * best[1]):
                best = (u, r)
        if best:
            u, r = best
            segs.append((tuple(sigs[i : i + u]), r))
            i += u * r
        else:
            segs.append(((sigs[i],), 1))
            i += 1
    return segs


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Carries the mesh + axis names for activation sharding constraints.
    ``mesh=None`` (single-device tests) disables all constraints."""
    mesh: Any = None
    dp: Tuple[str, ...] = ("data",)
    tp: str = "model"

    def cons(self, x, *tail):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self.dp, *tail)))

    def cons_spec(self, x, spec_entries):
        """Constraint with explicit entries; first entry None -> dp axes."""
        if self.mesh is None:
            return x
        entries = tuple(self.dp if e == "dp" else e for e in spec_entries)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries)))


NULL_CTX = ShardCtx()


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------

def _init_block(key, sig: Sig, cfg: ModelConfig, dtype) -> Params:
    kind, is_moe = sig
    if kind == "shared_attn":
        return {}  # weights live in params["shared_attn"]
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(ks[0], cfg, cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = (L.init_mla(ks[1], cfg, dtype) if cfg.mla is not None
                     else L.init_attention(ks[1], cfg, dtype))
        if not cfg.parallel_block:
            p["norm2"] = L.init_norm(ks[2], cfg, cfg.d_model, dtype)
        if is_moe:
            p["moe"] = L.init_moe(ks[3], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype)
    elif kind == "rwkv6":
        p["norm2"] = L.init_norm(ks[2], cfg, cfg.d_model, dtype)
        p["rwkv"] = S.init_rwkv6(ks[1], cfg, dtype)
    elif kind == "mamba2":
        p["mamba"] = S.init_mamba2(ks[1], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def _init_shared_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "norm1": L.init_norm(ks[0], cfg, cfg.d_model, dtype),
        "attn": L.init_attention(ks[1], cfg, dtype),
        "norm2": L.init_norm(ks[2], cfg, cfg.d_model, dtype),
        "mlp": L.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype),
    }


def _apply_block(x, bp, sig: Sig, cfg: ModelConfig, ctx: ShardCtx, positions,
                 cache, t, shared_p, absorb: bool = False):
    """Returns (x, new_cache, aux_loss)."""
    kind, is_moe = sig
    aux = jnp.zeros((), jnp.float32)
    if kind == "shared_attn":
        bp = shared_p
    if kind in ("attn", "shared_attn"):
        h = L.apply_norm(x, bp["norm1"], cfg)
        if cfg.mla is not None and kind == "attn":
            att, new_cache = L.mla_block(h, bp["attn"], cfg, positions, cache, t,
                                         absorb=absorb)
        else:
            att, new_cache = L.attention_block(h, bp["attn"], cfg, positions, cache, t)
        if cfg.pin_proj_outputs:
            att = ctx.cons(att, None, None)
        if cfg.parallel_block:
            f = L.mlp_block(h, bp["mlp"])
            if cfg.pin_proj_outputs:
                f = ctx.cons(f, None, None)
            x = x + att + f
        else:
            x = x + att
            h2 = L.apply_norm(x, bp["norm2"], cfg)
            if is_moe:
                f, aux = L.moe_block(h2, bp["moe"], cfg, ctx)
            else:
                f = L.mlp_block(h2, bp["mlp"])
            if cfg.pin_proj_outputs:
                f = ctx.cons(f, None, None)
            x = x + f
    elif kind == "rwkv6":
        st_tm = None if cache is None else cache["shift_tm"]
        st_wkv = None if cache is None else cache["wkv"]
        st_cm = None if cache is None else cache["shift_cm"]
        h = L.apply_norm(x, bp["norm1"], cfg)
        y, (new_tm, new_wkv) = S.rwkv6_time_mix(h, bp["rwkv"], cfg, st_tm, st_wkv)
        x = x + y
        h2 = L.apply_norm(x, bp["norm2"], cfg)
        y2, new_cm = S.rwkv6_channel_mix(h2, bp["rwkv"], st_cm)
        x = x + y2
        new_cache = None if cache is None else {
            "shift_tm": new_tm, "wkv": new_wkv, "shift_cm": new_cm}
    elif kind == "mamba2":
        h = L.apply_norm(x, bp["norm1"], cfg)
        y, new_cache = S.mamba2_mixer(h, bp["mamba"], cfg, cache)
        if cache is None:
            new_cache = None
        x = x + y
    else:
        raise ValueError(kind)
    x = ctx.cons(x, None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model init / forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    sigs = layer_sigs(cfg)
    segs = find_segments(sigs)
    keys = jax.random.split(key, len(segs) + 3)

    segments = []
    for si, (unit, repeat) in enumerate(segs):
        def init_one(k, unit=unit):
            uks = jax.random.split(k, len(unit))
            return [_init_block(uk, sig, cfg, dtype) for uk, sig in zip(uks, unit)]
        if repeat == 1:
            segments.append(init_one(keys[si]))
        else:
            rep_keys = jax.random.split(keys[si], repeat)
            per = [init_one(k) for k in rep_keys]
            segments.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))

    params: Params = {"segments": segments}
    if cfg.frontend == "audio_stub":
        params["embed"] = {
            "mask_emb": (jax.random.normal(keys[-3], (cfg.d_model,)) * 0.02).astype(dtype)}
    else:
        params["embed"] = {
            "tok": (jax.random.normal(keys[-3], (cfg.vocab_size, cfg.d_model))
                    * cfg.d_model ** -0.5).astype(dtype)}
    if any(k == "shared_attn" for k, _ in sigs):
        params["shared_attn"] = _init_shared_block(keys[-2], cfg, dtype)
    params["final_norm"] = L.init_norm(keys[-1], cfg, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": (jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab_size))
                  * cfg.d_model ** -0.5).astype(dtype)}
    return params


def head_weight(params: Params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["head"]["w"]


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    if cfg.frontend == "audio_stub":
        x = batch["embeds"]
        if "mask" in batch:
            me = params["embed"]["mask_emb"].astype(x.dtype)
            x = jnp.where(batch["mask"][..., None], me, x)
        return x
    return jnp.take(params["embed"]["tok"], batch["tokens"], axis=0)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            ctx: ShardCtx = NULL_CTX, cache=None, t=None, absorb: bool = False,
            unroll: bool = False):
    """Returns (hidden, new_cache, aux). ``cache`` given => single-token decode.

    ``unroll=True`` replaces lax.scan over repeated segments with a python
    loop — used by the dry-run so cost_analysis counts every layer (XLA's
    cost analysis visits a while body once) and every per-layer collective
    appears in the HLO.  Numerics are identical.
    """
    sigs = layer_sigs(cfg)
    segs = find_segments(sigs)
    x = embed_inputs(params, cfg, batch)
    x = ctx.cons(x, None, None)
    if cache is not None:
        b = x.shape[0]
        positions = jnp.broadcast_to(t, (b, 1)).astype(jnp.int32)
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])

    shared_p = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = [] if cache is not None else None

    if cfg.remat_policy == "dots":
        ckpt = functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        ckpt = jax.checkpoint

    for si, (unit, repeat) in enumerate(segs):
        seg_p = params["segments"][si]
        seg_c = None if cache is None else cache[si]

        def unit_apply(x, unit_params, unit_cache, unit=unit):
            aux = jnp.zeros((), jnp.float32)
            new_uc = []
            for ui, sig in enumerate(unit):
                uc = None if unit_cache is None else unit_cache[ui]
                x, nc, a = _apply_block(x, unit_params[ui], sig, cfg, ctx,
                                        positions, uc, t, shared_p, absorb)
                aux = aux + a
                new_uc.append(nc)
            return x, (new_uc if unit_cache is not None else None), aux

        if repeat == 1:
            fn = ckpt(unit_apply) if (cfg.remat and cache is None) else unit_apply
            x, nc, a = fn(x, seg_p, seg_c)
            aux_total = aux_total + a
            if cache is not None:
                new_cache.append(nc)
        elif unroll:
            rep_caches = []
            for ri in range(repeat):
                up = jax.tree.map(lambda a: a[ri], seg_p)
                uc = None if seg_c is None else jax.tree.map(lambda a: a[ri], seg_c)
                fn = ckpt(unit_apply) if (cfg.remat and cache is None) else unit_apply
                x, nc, a = fn(x, up, uc)
                aux_total = aux_total + a
                rep_caches.append(nc)
            if cache is not None:
                new_cache.append(jax.tree.map(lambda *xs: jnp.stack(xs), *rep_caches))
        else:
            def scan_body(carry, xs_in, unit=unit):
                x, aux = carry
                up, uc = xs_in
                fn = ckpt(unit_apply) if (cfg.remat and cache is None) else unit_apply
                x, nc, a = fn(x, up, uc)
                return (x, aux + a), nc
            (x, aux_total), seg_nc = jax.lax.scan(
                scan_body, (x, aux_total), (seg_p, seg_c))
            if cache is not None:
                new_cache.append(seg_nc)

    x = L.apply_norm(x, params["final_norm"], cfg)
    return x, new_cache, aux_total


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_cross_entropy(hidden, w_head, labels, weights=None, chunk: int = 512):
    """Memory-safe CE: logits are materialized one sequence-chunk at a time
    (recomputed in backward via jax.checkpoint) — with a model-sharded vocab
    this caps live logits at (B, chunk, V/tp) instead of (B, S, V)."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad))) if weights is not None else \
            jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    elif weights is None:
        weights = jnp.ones((b, s), jnp.float32)
    nc = hidden.shape[1] // chunk
    hidden = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)
    labels = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    weights = weights.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(h_c, l_c, w_c):
        logits = jnp.einsum("bsd,dv->bsv", h_c, w_head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * w_c), jnp.sum(w_c)

    def body(carry, xs_in):
        tot, cnt = carry
        lsum, wsum = chunk_loss(*xs_in)
        return (tot + lsum, cnt + wsum), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hidden, labels, weights))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX, aux_weight: float = 0.01,
                 unroll: bool = False):
    def loss_fn(params, batch):
        hidden, _, aux = forward(params, cfg, batch, ctx, unroll=unroll)
        w = head_weight(params, cfg)
        weights = batch.get("mask")
        if weights is not None:
            weights = weights.astype(jnp.float32)
        ce = chunked_cross_entropy(hidden, w, batch["labels"], weights)
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer, ctx: ShardCtx = NULL_CTX,
                    aux_weight: float = 0.01, unroll: bool = False):
    """optimizer: repro.optim object with .update(grads, state, params)."""
    loss_fn = make_loss_fn(cfg, ctx, aux_weight, unroll=unroll)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX, absorb: bool = False,
                    unroll: bool = False):
    """One decode step: (params, cache, tokens (B,1), t ()) -> (logits, cache)."""

    def serve_step(params, cache, tokens, t):
        hidden, new_cache, _ = forward(params, cfg, {"tokens": tokens}, ctx,
                                       cache=cache, t=t, absorb=absorb, unroll=unroll)
        logits = jnp.einsum("bsd,dv->bsv", hidden, head_weight(params, cfg))
        return logits, new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX, unroll: bool = False):
    """Forward pass producing logits (inference prefill / encoder forward)."""

    def prefill_step(params, batch):
        hidden, _, _ = forward(params, cfg, batch, ctx, unroll=unroll)
        logits = jnp.einsum("bsd,dv->bsv", hidden, head_weight(params, cfg))
        return logits

    return prefill_step


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, as_shape: bool = False):
    """Nested cache matching forward()'s segment structure.
    ``as_shape=True`` returns jax.ShapeDtypeStruct leaves (for dry-run)."""
    sigs = layer_sigs(cfg)
    segs = find_segments(sigs)

    def mk(shape, dtype):
        if as_shape:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    def block_cache(sig: Sig):
        kind, _ = sig
        cdtype = jnp.dtype(cfg.dtype)
        if kind in ("attn", "shared_attn"):
            if cfg.mla is not None and kind == "attn":
                shapes = L.mla_cache_shape(cfg, batch, max_seq)
            else:
                shapes = L.attention_cache_shape(cfg, batch, max_seq)

            def cache_dtype(name):
                if name.endswith("_scale"):
                    return jnp.float32
                return jnp.int8 if cfg.quantized_cache else cdtype
            return {k: mk(v, cache_dtype(k)) for k, v in shapes.items()}
        if kind == "rwkv6":
            shp = S.rwkv6_state_shape(cfg, batch)
            return {"shift_tm": mk(shp["shift_tm"], cdtype),
                    "shift_cm": mk(shp["shift_cm"], cdtype),
                    "wkv": mk(shp["wkv"], jnp.float32)}
        if kind == "mamba2":
            shp = S.mamba2_state_shape(cfg, batch)
            return {"conv_xs": mk(shp["conv_xs"], cdtype),
                    "conv_bc": mk(shp["conv_bc"], cdtype),
                    "ssm": mk(shp["ssm"], jnp.float32)}
        raise ValueError(kind)

    cache = []
    for unit, repeat in segs:
        unit_c = [block_cache(sig) for sig in unit]
        if repeat > 1:
            def stackit(leaf_shape):
                if as_shape:
                    return jax.ShapeDtypeStruct((repeat,) + leaf_shape.shape,
                                                leaf_shape.dtype)
                return jnp.broadcast_to(leaf_shape, (repeat,) + leaf_shape.shape).copy()
            unit_c = jax.tree.map(stackit, unit_c)
        cache.append(unit_c)
    return cache


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
