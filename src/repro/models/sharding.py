"""Sharding rules: PartitionSpec mirrors of the param / cache / input pytrees.

Megatron-style TP over the ``model`` axis, DP over ``data`` (+ ``pod``).
Specs are assigned by walking the *shape* tree from ``jax.eval_shape`` with
``tree_map_with_path``, so they can never drift structurally from init_params.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (dp_axes, tp_axis) from mesh axis names."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def _path_names(path):
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(k.key)
        elif isinstance(k, SequenceKey):
            out.append(k.idx)
        else:
            out.append(str(k))
    return out


def _div(n: int, k: int) -> bool:
    return n % k == 0


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _param_spec(name: str, ndim: int, shape, cfg: ModelConfig, tp: str, tp_size: int):
    """Sharding rule for one parameter, identified by its dict key."""
    kv_ok = _div(cfg.n_kv_heads, tp_size)
    if name in ("wq", "w_uk", "w_uv", "w_r", "w_k", "w_v", "w_g", "w_lora_b"):
        return P(None, tp, None)                       # (in, heads, hd)
    if name in ("wk", "wv"):
        return P(None, tp, None) if kv_ok else P(None, None, None)
    if name in ("wo", "w_o"):
        return P(tp, None, None)                       # (heads, hd, out)
    if name == "bq":
        return P(tp, None)
    if name in ("bk", "bv"):
        return P(tp, None) if kv_ok else P(None, None)
    if name in ("w_gate", "w_in"):
        return P(tp, None, None) if ndim == 3 else P(None, tp)   # MoE (E,d,ff) / dense
    if name == "w_out":
        return P(tp, None, None) if ndim == 3 else P(tp, None)
    if name == "tok":
        return P(tp, None) if _div(shape[0], tp_size) else P(None, None)
    if name == "w" and ndim == 2:                      # lm head (d, V)
        return P(None, tp) if _div(shape[1], tp_size) else P(None, None)
    if name in ("w0", "u", "ln_out"):
        return P(tp, None)                             # rwkv (H, hd)
    if name in ("w_k_cm",):
        return P(None, tp)
    if name in ("w_v_cm",):
        return P(tp, None)
    if name in ("w_z", "w_xs", "conv_w_xs"):
        return P(None, tp)                             # mamba (d|W, d_in)
    if name == "conv_b_xs":
        return P(tp)
    if name == "norm" and ndim == 1 and shape[0] != cfg.d_model:
        return P(tp)                                   # mamba d_in norm
    if name == "out_proj":
        return P(tp, None)
    # everything else (norms, biases, router, mu_*, loras, small convs): replicate
    return P(*([None] * ndim))


def param_specs(cfg: ModelConfig, mesh: Mesh, fsdp: bool = False):
    """fsdp=True additionally shards every large parameter over the 'data'
    axis (ZeRO-3 / FSDP storage sharding; XLA all-gathers at use sites and
    reduce-scatters gradients).  On the multi-pod mesh the 'pod' axis stays
    replicated — hybrid FSDP: shard over fast intra-pod ICI, replicate over
    the cross-pod link.  Required for the big train cells to fit 16 GB HBM
    (qwen2-72b: 58 GB/chip of params+moments TP-only → 4.2 GB with FSDP)."""
    dp, tp = mesh_axes(mesh)
    tp_size = mesh.shape[tp]
    fsdp_size = mesh.shape["data"]
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.key(0))
    sigs = T.layer_sigs(cfg)
    segs = T.find_segments(sigs)

    def assign(path, leaf):
        names = _path_names(path)
        stacked = names[0] == "segments" and segs[names[1]][1] > 1
        base_ndim = leaf.ndim - (1 if stacked else 0)
        base_shape = leaf.shape[1:] if stacked else leaf.shape
        name = next((n for n in reversed(names) if isinstance(n, str)
                     and n not in ("segments",)), "")
        spec = _param_spec(name, base_ndim, base_shape, cfg, tp, tp_size)
        if fsdp and leaf.size >= (1 << 20):
            entries = list(spec)
            # largest unsharded, data-divisible dim gets the 'data' axis
            cands = [(base_shape[i], i) for i in range(base_ndim)
                     if entries[i] is None and _div(base_shape[i], fsdp_size)]
            if cands:
                _, idx = max(cands)
                entries[idx] = "data"
                spec = P(*entries)
        if stacked:
            spec = P(*((None,) + tuple(spec)))
        return spec

    return jax.tree_util.tree_map_with_path(assign, shapes)


def enforce_divisible(cfg: ModelConfig, mesh: Mesh, specs=None):
    """Downgrade any param-spec entry whose dimension does not divide its
    mesh axes to replicated — EXPLICITLY, returning the fallback report.

    ``param_specs`` already replicates the known-fragile tensors (kv heads,
    tied embeddings, lm head) behind per-rule ``_div`` checks, but a rule
    can still emit a spec a *small* config cannot honor (a smoke config's
    4 heads over model=16).  GSPMD would silently pad-and-shard such a
    leaf; ``shard_map`` — which the LM-loss evaluation backend uses for
    exact control of the numerics — rejects it.  This walk is the one
    place the divisibility contract is enforced tree-wide: every surviving
    entry divides, every downgrade is reported as
    ``(path, dim, axis_entry, dim_size)`` so tests (and a new config's
    author) see exactly which tensors fell back to replication instead of
    discovering it as a silent perf cliff.

    Returns ``(specs, fallbacks)``.
    """
    if specs is None:
        specs = param_specs(cfg, mesh)
    shapes = jax.eval_shape(functools.partial(T.init_params, cfg),
                            jax.random.key(0))
    fallbacks = []

    def fix(path, spec, leaf):
        entries = list(spec)
        for dim, e in enumerate(entries):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if leaf.shape[dim] % size:
                fallbacks.append(("/".join(str(n) for n in _path_names(path)),
                                  dim, e, leaf.shape[dim]))
                entries[dim] = None
        return P(*entries)

    fixed = jax.tree_util.tree_map_with_path(
        fix, specs, shapes, is_leaf=lambda x: isinstance(x, P))
    return fixed, fallbacks


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    dp, tp = mesh_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp_size = mesh.shape[tp]
    b = shape.global_batch
    b_spec = dp if (b > 1 and _div(b, dp_size)) else None
    # sequence dim: over tp normally; over everything when batch can't shard
    s_spec = tp if b_spec is not None else tuple(dp) + (tp,)

    shapes = T.init_cache(cfg, b, shape.seq_len, as_shape=True)
    sigs = T.layer_sigs(cfg)
    segs = T.find_segments(sigs)

    def assign(path, leaf):
        names = _path_names(path)
        stacked = segs[names[0]][1] > 1
        name = names[-1]
        if name in ("k", "v"):
            spec = P(b_spec, s_spec, None, None)
        elif name in ("c_kv", "k_rope"):
            spec = P(b_spec, s_spec, None)
        elif name.endswith("_scale"):
            spec = P(b_spec, s_spec)
        elif name == "wkv":
            h = (leaf.shape[2] if stacked else leaf.shape[1])
            spec = P(b_spec, tp if _div(h, tp_size) else None, None, None)
        elif name == "ssm":
            spec = P(b_spec, tp, None, None)
        elif name == "conv_xs":
            spec = P(b_spec, None, tp)
        elif name == "conv_bc":
            spec = P(b_spec, None, None)
        elif name in ("shift_tm", "shift_cm"):
            spec = P(b_spec, None)
        else:
            raise ValueError(name)
        if stacked:
            spec = P(*((None,) + tuple(spec)))
        return spec

    return jax.tree_util.tree_map_with_path(assign, shapes)


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Returns (batch_sds, batch_pspecs) for the given cell.

    train/prefill: token (or stub-embedding) batch.  decode: (tokens, t) —
    the KV cache is produced separately by cache_specs/init_cache.
    """
    dp, tp = mesh_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b, s = shape.global_batch, shape.seq_len
    b_spec = dp if (b > 1 and _div(b, dp_size)) else None

    if shape.kind == "decode":
        sds = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
               "t": jax.ShapeDtypeStruct((), jnp.int32)}
        specs = {"tokens": P(b_spec, None), "t": P()}
        return sds, specs

    if cfg.frontend == "audio_stub":
        sds = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype)),
               "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
               "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_)}
        specs = {"embeds": P(b_spec, None, None), "labels": P(b_spec, None),
                 "mask": P(b_spec, None)}
    else:
        sds = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
               "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        specs = {"tokens": P(b_spec, None), "labels": P(b_spec, None)}
    if shape.kind == "prefill":
        del sds["labels"], specs["labels"]
        if cfg.frontend == "audio_stub":
            del sds["mask"], specs["mask"]
    return sds, specs


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
