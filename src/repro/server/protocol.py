"""Versioned wire protocol for the FGDO work server (DESIGN.md §9).

Frames.  Every message travels as a length-prefixed frame::

    [4-byte big-endian payload length][1-byte codec][codec-encoded body]

The codec byte makes the framing self-describing (a JSON client can talk
to a msgpack-preferring server and vice versa), and the body always
carries ``"v": PROTOCOL_VERSION`` — a mismatched peer gets a clean
``ProtocolError`` instead of a misparsed field.  msgpack is used when the
``msgpack`` package is importable, JSON otherwise (both round-trip float64
exactly, which the bit-identical resume contract relies on: fitness values
and points cross the wire and must come back the same bits).

Message kinds (client → server)::

    register        {host_id, now}
    request_work    {host_id, now}
    report_result   {host_id, search, wu, y, now}
    heartbeat       {host_id, now}
    shutdown        {now}
    status          {}                     # read-only, never mutates
    subscribe_stats {since}                # read-only cursor long-poll on
                                           # the metrics ring (§13); like
                                           # status: unstamped, uncounted,
                                           # never logged

and replies (server → client)::

    registered     {host_id}
    work           {search, wu, phase, point, alpha, validates, deadline}
    no_work        {retry_after, done}
    ack            {done, iteration, best}
    status         {…summary…}             # incl. ``cache`` counters (hits,
                                           # misses, lanes_saved, store_size)
                                           # when an eval cache is attached,
                                           # else ``cache: null`` (§10);
                                           # service pressure (lease depth,
                                           # intake queue) rides here too
    stats          {snapshots, cursor, interval, stream_v}
                                           # every hub snapshot with
                                           # seq > since, oldest first (§13)
    error          {error}

``wu`` ids are the engine's tickets (unique per search); ``validates``
carries the candidate ticket a quorum replica re-checks — the replica tag
that lets a client know it is voting, not exploring.  ``deadline`` is the
lease expiry: a result reported after it is still assimilated (the
engine's phase-stale filter is the semantic gate) but the server stops
counting on the lease.
"""
from __future__ import annotations

import json
import struct
from typing import Iterator, Optional

import numpy as np

try:                                  # the container ships msgpack; the
    import msgpack                    # JSON fallback keeps the protocol
except ImportError:                   # importable without it
    msgpack = None

PROTOCOL_VERSION = 1
CODEC_JSON, CODEC_MSGPACK = 1, 2
DEFAULT_CODEC = CODEC_MSGPACK if msgpack is not None else CODEC_JSON

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 24                   # 16 MiB — no legitimate message is close


class ProtocolError(Exception):
    pass


def _py(x):
    """Numpy → plain python, recursively (codec-agnostic bodies)."""
    if isinstance(x, np.ndarray):
        return [_py(v) for v in x.tolist()]
    if isinstance(x, np.generic):
        return x.item()
    if isinstance(x, dict):
        return {k: _py(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_py(v) for v in x]
    return x


def encode_message(msg: dict, codec: int = DEFAULT_CODEC) -> bytes:
    body = dict(_py(msg))
    body["v"] = PROTOCOL_VERSION
    if codec == CODEC_MSGPACK:
        if msgpack is None:
            raise ProtocolError("msgpack codec requested but not installed")
        raw = msgpack.packb(body, use_bin_type=True)
    elif codec == CODEC_JSON:
        raw = json.dumps(body).encode("utf-8")
    else:
        raise ProtocolError(f"unknown codec {codec}")
    return bytes([codec]) + raw


def decode_message(payload: bytes) -> dict:
    if not payload:
        raise ProtocolError("empty frame")
    codec, raw = payload[0], payload[1:]
    if codec == CODEC_MSGPACK:
        if msgpack is None:
            raise ProtocolError("peer sent msgpack but it is not installed")
        body = msgpack.unpackb(raw, raw=False)
    elif codec == CODEC_JSON:
        body = json.loads(raw.decode("utf-8"))
    else:
        raise ProtocolError(f"unknown codec byte {codec}")
    if body.get("v") != PROTOCOL_VERSION:
        raise ProtocolError(
            f"protocol version mismatch: peer={body.get('v')} "
            f"ours={PROTOCOL_VERSION}")
    return body


def frame(payload: bytes) -> bytes:
    if len(payload) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(payload)} bytes exceeds cap")
    return _LEN.pack(len(payload)) + payload


class FrameDecoder:
    """Incremental frame splitter for stream transports: feed() raw bytes
    as they arrive, iterate complete payloads."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> Iterator[bytes]:
        self._buf.extend(data)
        while True:
            if len(self._buf) < _LEN.size:
                return
            (n,) = _LEN.unpack_from(self._buf)
            if n > MAX_FRAME:
                raise ProtocolError(f"frame of {n} bytes exceeds cap")
            if len(self._buf) < _LEN.size + n:
                return
            payload = bytes(self._buf[_LEN.size:_LEN.size + n])
            del self._buf[:_LEN.size + n]
            yield payload


# -- message builders (clients) ----------------------------------------------
#
# ``cs`` is the per-host client sequence number — the idempotency key
# (host_id, cs) the server dedups retried/duplicated deliveries on, echoed
# back in the reply so a client can match replies to requests on a stream
# that may carry duplicates.  ``seq`` is the global intake stamp a
# concurrent pool's coordinator assigns at release time; the server's
# sequenced intake handles messages in stamp order regardless of arrival
# interleaving.  Both are optional — a bare client (or the serial pool's
# pre-PR-8 wire traffic) stays valid.

def _stamp(msg: dict, cs: Optional[int], seq: Optional[int]) -> dict:
    if cs is not None:
        msg["cs"] = int(cs)
    if seq is not None:
        msg["intake_seq"] = int(seq)
    return msg


def register(host_id: int, now: float, cs: Optional[int] = None,
             seq: Optional[int] = None) -> dict:
    return _stamp({"kind": "register", "host_id": int(host_id),
                   "now": float(now)}, cs, seq)


def request_work(host_id: int, now: float, cs: Optional[int] = None,
                 seq: Optional[int] = None) -> dict:
    return _stamp({"kind": "request_work", "host_id": int(host_id),
                   "now": float(now)}, cs, seq)


def report_result(host_id: int, search: int, wu: int, y: float,
                  now: float, cs: Optional[int] = None,
                  seq: Optional[int] = None) -> dict:
    return _stamp({"kind": "report_result", "host_id": int(host_id),
                   "search": int(search), "wu": int(wu), "y": float(y),
                   "now": float(now)}, cs, seq)


def heartbeat(host_id: int, now: float, cs: Optional[int] = None,
              seq: Optional[int] = None) -> dict:
    return _stamp({"kind": "heartbeat", "host_id": int(host_id),
                   "now": float(now)}, cs, seq)


def shutdown(now: float) -> dict:
    return {"kind": "shutdown", "now": float(now)}


def status() -> dict:
    return {"kind": "status"}


def subscribe_stats(since: int = -1, from_store: bool = False) -> dict:
    """Long-poll the server's metrics ring for snapshots with
    ``seq > since``.  Deliberately stamp-free, like ``status``: a
    monitoring poll must never consume an intake stamp or a (host, cs)
    slot, so it can interleave with the applied stream at any rate
    without perturbing it.  ``from_store=True`` asks the server to
    backfill snapshots the ring already dropped from its retention
    store, when one is attached (§14) — the key rides the wire only
    when set, so old servers never see it."""
    msg = {"kind": "subscribe_stats", "since": int(since)}
    if from_store:
        msg["from_store"] = True
    return msg


# -- reply builders (server) --------------------------------------------------

def work_reply(search: int, wu: int, phase: int, point, alpha: float,
               validates: Optional[int], deadline: float) -> dict:
    return {"kind": "work", "search": int(search), "wu": int(wu),
            "phase": int(phase), "point": [float(v) for v in point],
            "alpha": float(alpha),
            "validates": None if validates is None else int(validates),
            "deadline": float(deadline)}


def no_work_reply(retry_after: float, done: bool) -> dict:
    return {"kind": "no_work", "retry_after": float(retry_after),
            "done": bool(done)}


def ack_reply(done: bool, iteration: int, best: float) -> dict:
    return {"kind": "ack", "done": bool(done), "iteration": int(iteration),
            "best": float(best)}


def stats_reply(snapshots, cursor: int, interval: float,
                stream_v: int, dropped: int = 0) -> dict:
    # ``dropped``: snapshots the caller's cursor missed because the ring
    # (minus any store backfill) already evicted them — an explicit gap
    # signal instead of silently skipped seqs (§14 satellite)
    return {"kind": "stats", "snapshots": list(snapshots),
            "cursor": int(cursor), "interval": float(interval),
            "stream_v": int(stream_v), "dropped": int(dropped)}


def error_reply(msg: str) -> dict:
    return {"kind": "error", "error": str(msg)}
