"""The FGDO work server: leases, host registry, portfolio routing.

``WorkServer`` is the deterministic heart of the service layer
(DESIGN.md §9): a pure message handler over the BOINC-shaped
``FgdoAnmServer`` adapter (itself a thin substrate over ``AnmEngine`` —
the server builds on the engine's generate/assimilate seam, never on
phase logic).  Every mutation flows through ``handle(msg) -> reply``;
every random draw lives in the engines' rngs, which are part of the
state — so given a state and a message sequence, the server's behavior
is a pure function.  That is the whole crash-recovery story: the
checkpoint layer (``repro/server/checkpoint.py``) snapshots
``state_dict()`` and replays the logged message suffix, and the restored
server is bit-identical to the killed one.

Leases.  Every granted workunit is a lease: ``(search, wu)`` → holder,
issue time, deadline.  A result reported within the lease settles it; a
lease past its deadline lapses (kept aside until the holder next makes
contact, because the crash-restored client world is rebuilt from exactly
these records) — the work itself is NOT re-generated: the paper's any-m
phase semantics already absorb lost work, and validation replicas have
their own reissue path inside ``FgdoAnmServer``.  A result arriving after
its lease lapsed is still assimilated (the engine's phase-stale filter is
the semantic authority) and counted as a late return.

Portfolio.  The server can front one search or a whole multi-search
portfolio: work requests round-robin across live searches (the PR-4
``SearchSpec.build_engine`` is THE spec→engine construction, shared with
the orchestrator), and the ``portfolio`` policy retires searches past
probation that trail the incumbent by the orchestrator's own
``dominated_cut`` margin — the same kill rule, imported, so the two
layers cannot drift.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fgdo import FgdoAnmServer, WorkUnit
from repro.core.orchestrator.director import SearchSpec, dominated_cut
from repro.server import protocol
from repro.server.registry import HostRegistry

RUNNING, DONE, KILLED = "running", "done", "killed"


class SequencedIntake:
    """Reorder buffer at the transport boundary (DESIGN.md §12).

    Concurrent connections deliver messages in whatever order the network
    produces; the coordinator that RELEASED them stamped each with a
    global monotone ``intake_seq``.  ``submit`` parks an early arrival
    until every lower stamp has been handled, so the handler — and hence
    the replay log, the engines, and the committed iterates — observes
    the canonical total order no matter the arrival interleaving.  The
    handler runs under the intake lock: the work server stays the
    single-threaded deterministic object it always was, and this class is
    the ONLY concurrency-aware thing in front of it.

    Deliveries of an already-handled stamp (retries and duplicated
    frames racing their original) are handled immediately instead of
    parked — the server's (host, cs) idempotency layer turns them into
    cached-reply no-ops, so their out-of-band timing is invisible.

    Unstamped messages (a serial client, a monitoring probe) are handled
    at arrival under the same lock WITHOUT consuming a stamp — serial
    traffic flows through untouched and a mid-run status poll can never
    desync the stamped stream, so intake sequencing is strictly additive.
    """

    def __init__(self, handler, timeout: float = 120.0):
        self._handler = handler
        self._cond = threading.Condition()
        self._next = 0
        self.timeout = timeout            # generous: a gap means a bug, and
        self.parked = 0                   # a loud ProtocolError beats a hang
        self.out_of_band = 0

    @property
    def next_seq(self) -> int:
        return self._next

    def submit(self, msg: dict) -> dict:
        with self._cond:
            seq = msg.get("intake_seq")
            if seq is None:
                return self._handler(msg)
            seq = int(seq)
            if seq > self._next:
                self.parked += 1
                deadline = time.monotonic() + self.timeout
                while seq > self._next:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        raise protocol.ProtocolError(
                            f"intake gap: stamp {seq} waited "
                            f"{self.timeout:.0f}s at next={self._next} — a "
                            f"released message never arrived")
                    self._cond.wait(left)
            if seq < self._next:
                self.out_of_band += 1
                return self._handler(msg)
            rep = self._handler(msg)
            self._next = seq + 1
            self._cond.notify_all()
            return rep


@dataclasses.dataclass
class Lease:
    search_id: int
    wu_id: int
    host_id: int
    issued_at: float
    deadline: float
    wu: WorkUnit


@dataclasses.dataclass
class ServerCounters:
    messages: int = 0
    registrations: int = 0
    leases_issued: int = 0
    leases_lapsed: int = 0            # deadline passed before the result
    leases_abandoned: int = 0         # holder re-requested without reporting
    late_returns: int = 0             # result arrived after its lease lapsed
    unknown_results: int = 0          # no lease on record (protocol misuse)
    dropped_results: int = 0          # result for a killed search
    nowork_replies: int = 0
    heartbeats: int = 0
    duplicates_suppressed: int = 0    # same (host, cs) again: cached reply
    stale_duplicates: int = 0         # cs older than the host's last applied
    duplicate_reports: int = 0        # re-report of already-settled work


@dataclasses.dataclass
class SearchEntry:
    search_id: int
    name: str
    fgdo: FgdoAnmServer
    status: str = RUNNING


class WorkServer:
    """Deterministic message handler fronting one or many ANM searches."""

    def __init__(self, specs: Sequence[SearchSpec], *,
                 policy: str = "fixed", kill_margin: float = 0.5,
                 probation_iterations: int = 2,
                 lease_timeout: float = 480.0, idle_retry: float = 5.0,
                 backoff_cap: float = 60.0,
                 val_reissue_timeout: float = 600.0,
                 overcommit: Optional[float] = 2.0,
                 registry: Optional[HostRegistry] = None):
        if policy not in ("fixed", "portfolio"):
            raise ValueError(f"unknown policy {policy!r} (fixed|portfolio)")
        self.specs = list(specs)
        if not self.specs:
            raise ValueError("need at least one SearchSpec")
        self.policy = policy
        self.kill_margin = kill_margin
        self.probation_iterations = probation_iterations
        self.lease_timeout = lease_timeout
        self.idle_retry = idle_retry
        self.backoff_cap = backoff_cap
        self.val_reissue_timeout = val_reissue_timeout
        self.overcommit = overcommit
        self.registry = registry if registry is not None else HostRegistry()
        self.searches = [
            SearchEntry(i, spec.name, FgdoAnmServer(
                cfg=spec.anm, engine=spec.build_engine(),
                val_reissue_timeout=val_reissue_timeout,
                registry=self.registry, overcommit=overcommit))
            for i, spec in enumerate(self.specs)]
        self.leases: Dict[Tuple[int, int], Lease] = {}
        self.lapsed: Dict[Tuple[int, int], Lease] = {}
        self.cursor = 0               # round-robin start for the next grant
        self.now = 0.0
        self.stopping = False
        self.counters = ServerCounters()
        # hot-path indices (derived state, rebuilt on load): the message
        # loop must stay O(1)-ish per message, not O(n_hosts) — a 1024-host
        # fleet sends tens of thousands of messages per run
        self._host_lease: Dict[int, Tuple[int, int]] = {}   # ≤1 per host
        self._host_lapsed: Dict[int, Tuple[int, int]] = {}
        self._next_deadline = float("inf")
        self._last_sweep = float("-inf")
        self.sweep_interval = 5.0     # virtual seconds between churn sweeps
        self._cache_status = None     # read-only eval-cache probe (attach)
        # observability plane (DESIGN.md §13): both attach-only and both
        # outside state_dict — a hub samples AT applied-message boundaries
        # but never mutates server state, an intake probe only reads depth
        # counters, so neither can perturb the replay contract
        self._hub = None
        self._intake_probe = None
        # §14 post-mortem plane, same contract: tracer hooks only read
        # lease state, the retention store is only read to serve backfill
        self._tracer = None
        self._retention = None
        # idempotency layer (DESIGN.md §12): per-host last applied client
        # sequence number + the reply it produced.  Clients are serial per
        # host (one logical message in flight), so a window of 1 is exact:
        # any retransmission is of the host's LATEST message.  Part of
        # state_dict — a restored server keeps deduplicating mid-retry.
        self._client_seq: Dict[int, int] = {}
        self._last_reply: Dict[int, dict] = {}
        # last settled (search, wu) per host: a re-reported result whose
        # lease records are already gone is recognized as a benign
        # retransmit instead of protocol misuse, and can never touch the
        # registry's returned count twice
        self._settled: Dict[int, Tuple[int, int]] = {}
        # False when the last handle() call was absorbed by the dedup
        # layer (or was read-only): the checkpoint layer skips logging it,
        # so the replay log stays exactly the canonical applied sequence
        self.last_applied = True

    def attach_cache(self, cache) -> None:
        """Surface an ``EvalCache``'s counters in the read-only ``status``
        reply (DESIGN.md §10).  Observability only: the probe is NOT part
        of ``state_dict`` — cache persistence is the store's own job
        (checkpoint-dir composition), and status is never logged or
        replayed, so attaching a cache cannot perturb recovery."""
        self._cache_status = cache.status
        if self._hub is not None:
            self._hub.register_probe("cache", self._cache_status,
                                     rates=("hits", "misses"))

    def attach_intake(self, intake) -> None:
        """Surface a ``SequencedIntake``'s pressure counters in ``status``
        (and as a hub probe): next expected stamp, arrivals parked waiting
        for their turn, out-of-band retry deliveries.  Observability only,
        exactly like ``attach_cache``."""
        def probe() -> dict:
            return {"next_seq": intake.next_seq, "parked": intake.parked,
                    "out_of_band": intake.out_of_band}
        self._intake_probe = probe
        if self._hub is not None:
            self._hub.register_probe("intake", probe, plain=True)

    def attach_hub(self, hub) -> None:
        """Publish into a ``MetricsHub`` (DESIGN.md §13): the server
        registers its own probes (service counters + lease depth, registry
        health incl. churn cohort ids) and samples the hub at applied-
        message boundaries in virtual time.  Sampling is read-only w.r.t.
        server state and the hub is not in ``state_dict`` — observability
        cannot enter the replay log or the recovery path."""
        self._hub = hub
        # plain=True: both probes emit freshly-built python scalars (the
        # engine stores best_fitness as float, host ids are ints), so the
        # hub's codec-sanitizing walk is skipped on the per-sample path
        hub.register_probe("server", self._probe_server,
                           rates=("messages", "leases_issued"), plain=True)
        hub.register_probe("registry", self._probe_registry, plain=True)
        if self._cache_status is not None:
            hub.register_probe("cache", self._cache_status,
                               rates=("hits", "misses"))
        if self._intake_probe is not None:
            hub.register_probe("intake", self._intake_probe, plain=True)

    def attach_tracer(self, tracer) -> None:
        """Hook a ``WorkUnitTracer`` (§14) onto the lease lifecycle paths:
        issue, lapse, settle.  Every hook sits behind one ``is not None``
        compare and only READS lease state — the tracer owns no replayable
        state and is not in ``state_dict``, so tracing cannot perturb the
        applied sequence (the §13 argument, unchanged)."""
        self._tracer = tracer

    def attach_retention(self, store) -> None:
        """Expose a retention ``SnapshotStore`` for ``subscribe_stats``
        ``from_store`` backfill and the ``status`` obs block.  The server
        only READS it — the ``RetentionSink`` is the writer."""
        self._retention = store

    def kill_search(self, search_id: int) -> None:
        """Director seam (§14): retire one search by verdict.  Same
        freeze semantics as the portfolio kill — the engine's committed
        history stays a prefix of its solo run.  The defense calls this
        at a deterministic sample boundary (live detectors or a replayed
        schedule), so live and replay runs kill at the same applied
        message."""
        e = self.searches[int(search_id)]
        if e.status == RUNNING:
            e.status = KILLED

    # -- introspection -------------------------------------------------------

    @property
    def done(self) -> bool:
        return self.stopping or all(e.status != RUNNING
                                    for e in self.searches)

    @property
    def engines(self):
        return [e.fgdo.engine for e in self.searches]

    def best(self) -> Tuple[Optional[int], float]:
        """Incumbent (search_id, fitness) over the whole portfolio."""
        best_id, best_y = None, float("inf")
        for e in self.searches:
            y = e.fgdo.engine.best_fitness
            if np.isfinite(y) and y < best_y:
                best_id, best_y = e.search_id, y
        return best_id, best_y

    def fingerprint(self) -> str:
        """Identity stamped into snapshots: restoring a checkpoint into a
        server built from different specs — or the same specs under
        different behavior-affecting knobs (kill margin, lease timeout,
        backoff, feeder throttle…) — must fail loudly, not produce a
        plausible-but-wrong continuation."""
        doc = [{
            "name": s.name, "x0": np.asarray(s.x0).tolist(),
            "lo": np.asarray(s.lo).tolist(),
            "hi": np.asarray(s.hi).tolist(),
            "step": np.asarray(s.step).tolist(),
            "anm": dataclasses.asdict(s.anm),
            "engine_seed": s.engine_seed,
            "validation_quorum": s.validation_quorum,
        } for s in self.specs]
        doc.append({
            "policy": self.policy, "kill_margin": self.kill_margin,
            "probation_iterations": self.probation_iterations,
            "lease_timeout": self.lease_timeout,
            "idle_retry": self.idle_retry,
            "backoff_cap": self.backoff_cap,
            "val_reissue_timeout": self.val_reissue_timeout,
            "overcommit": self.overcommit,
        })
        return hashlib.sha256(
            json.dumps(doc, sort_keys=True).encode()).hexdigest()[:16]

    # -- time / lease sweeps -------------------------------------------------

    def _advance(self, now: float) -> None:
        self.now = max(self.now, now)
        if self.now - self._last_sweep >= self.sweep_interval:
            # churn transitions move at suspect/dead granularity (hundreds
            # of virtual seconds), so sweeping every few virtual seconds
            # is exact enough AND keeps the per-message cost off the
            # O(n_hosts) scan; deterministic — driven by message times
            self.registry.sweep(self.now)
            self._last_sweep = self.now
        if self._next_deadline < self.now:
            nxt = float("inf")
            for k in list(self.leases):
                l = self.leases[k]
                if l.deadline < self.now:
                    self.lapsed[k] = self.leases.pop(k)
                    self._host_lease.pop(l.host_id, None)
                    self._host_lapsed[l.host_id] = k
                    self.counters.leases_lapsed += 1
                    if self._tracer is not None:
                        self._tracer.on_lapse(l.search_id, l.wu_id, self.now)
                else:
                    nxt = min(nxt, l.deadline)
            self._next_deadline = nxt

    def _drop_lapsed_for(self, host_id: int) -> None:
        """A host making contact supersedes its lapsed leases — they were
        kept only so the crash-restored client world could reconstruct
        the host's in-flight computation."""
        k = self._host_lapsed.pop(host_id, None)
        if k is not None:
            self.lapsed.pop(k, None)

    def _abandon_outstanding_for(self, host_id: int) -> None:
        """A host ASKING for work holds nothing (clients compute one
        workunit at a time), so any outstanding lease it still has on
        record is abandoned — it vanished with the result.  Dropping it
        here keeps the per-host lease invariant (≤ 1 record across
        outstanding ∪ lapsed) that the crash-restored client world's
        event rebuild depends on."""
        k = self._host_lease.pop(host_id, None)
        if k is not None:
            del self.leases[k]
            self.counters.leases_abandoned += 1

    # -- message handling ----------------------------------------------------

    def handle(self, msg: dict) -> dict:
        kind = msg.get("kind")
        if kind == "status":
            # read-only by contract: not counted, not logged, no sweep —
            # a monitoring poll must never perturb the replayable state
            self.last_applied = False
            return self._status()
        if kind == "subscribe_stats":
            # same contract as status (§13): unstamped, uncounted, never
            # logged, never sampled — and serving the ring mutates nothing
            self.last_applied = False
            return self._subscribe_stats(msg)
        # idempotent delivery: before ANY state is touched (including the
        # message counter), a (host, cs) the server already applied short-
        # circuits to the cached reply — a retried report can't re-vote, a
        # duplicated request can't re-abandon or double-lease, and the
        # suppressed delivery never reaches the replay log
        cs, host = msg.get("cs"), msg.get("host_id")
        keyed = cs is not None and host is not None
        if keyed:
            cs, host = int(cs), int(host)
            last = self._client_seq.get(host, -1)
            if cs == last:
                self.last_applied = False
                self.counters.duplicates_suppressed += 1
                return dict(self._last_reply[host])
            if cs < last:
                # older than the last applied message: with serial-per-
                # host clients this is a stray duplicate of a reply the
                # client already consumed — refuse rather than guess (cs
                # still echoed so a reply-matching client isn't stranded)
                self.last_applied = False
                self.counters.stale_duplicates += 1
                rep = protocol.error_reply(
                    f"stale duplicate: host {host} cs={cs} already past "
                    f"{last}")
                rep["cs"], rep["host_id"] = cs, host
                return rep
        self.last_applied = True
        self.counters.messages += 1
        rep = self._dispatch(kind, msg)
        hub = self._hub
        if hub is not None and \
                (hub.next_sample_at is None or self.now >= hub.next_sample_at):
            # sample on the message-derived clock AFTER the mutation it
            # carries: boundaries (and hence snapshot seqs and defense
            # verdicts) are a pure function of the applied sequence.  The
            # interval check is inlined so the per-message cost of an
            # attached hub is one attribute compare, not a call
            hub.maybe_sample(self.now)
        if keyed:
            # (host_id, cs) is the client's reply-matching key — cs alone
            # is ambiguous on a connection multiplexing several hosts
            rep = dict(rep)
            rep["cs"], rep["host_id"] = cs, host
            self._client_seq[host] = cs
            self._last_reply[host] = rep
        return rep

    def _dispatch(self, kind: str, msg: dict) -> dict:
        if kind == "register":
            return self._register(msg)
        if kind == "request_work":
            return self._request_work(msg)
        if kind == "report_result":
            return self._report_result(msg)
        if kind == "heartbeat":
            return self._heartbeat(msg)
        if kind == "shutdown":
            self.stopping = True
            _, best_y = self.best()
            return protocol.ack_reply(True, max(
                e.fgdo.engine.iteration for e in self.searches), best_y)
        return protocol.error_reply(f"unknown message kind {kind!r}")

    def _register(self, msg: dict) -> dict:
        self._advance(msg["now"])
        rec = self.registry.register(int(msg["host_id"]), msg["now"])
        # a freshly registered client requests immediately: pin its next
        # contact so a crash between register and first request rebuilds
        # the schedule exactly
        rec.next_contact_at = float(msg["now"])
        self.counters.registrations += 1
        return {"kind": "registered", "host_id": int(msg["host_id"])}

    def _request_work(self, msg: dict) -> dict:
        host, now = int(msg["host_id"]), float(msg["now"])
        self._advance(now)
        self.registry.touch(host, now)
        self._drop_lapsed_for(host)
        self._abandon_outstanding_for(host)
        if not self.done:
            n = len(self.searches)
            for i in range(n):
                e = self.searches[(self.cursor + i) % n]
                if e.status != RUNNING:
                    continue
                if e.fgdo.engine.done:
                    e.status = DONE
                    continue
                wu = e.fgdo.generate_work(host, now)
                if wu is None:
                    continue
                self.cursor = (e.search_id + 1) % n
                deadline = now + self.lease_timeout
                key = (e.search_id, wu.wu_id)
                self.leases[key] = Lease(
                    e.search_id, wu.wu_id, host, now, deadline, wu)
                self._host_lease[host] = key
                self._next_deadline = min(self._next_deadline, deadline)
                self.counters.leases_issued += 1
                if self._tracer is not None:
                    self._tracer.on_issue(e.search_id, wu.wu_id, host, now,
                                          wu.phase_id, wu.validates)
                # the registry's on_issue cleared next_contact_at: this
                # host's next contact now derives from the lease
                return protocol.work_reply(e.search_id, wu.wu_id,
                                           wu.phase_id, wu.point, wu.alpha,
                                           wu.validates, deadline)
        rec = self.registry.record(host)
        retry = min(self.idle_retry * (2 ** rec.nowork_streak),
                    self.backoff_cap)
        self.registry.on_no_work(host, now, retry)
        self.counters.nowork_replies += 1
        return protocol.no_work_reply(retry, self.done)

    def _report_result(self, msg: dict) -> dict:
        host, now = int(msg["host_id"]), float(msg["now"])
        search, wu_id = int(msg["search"]), int(msg["wu"])
        self._advance(now)
        key = (search, wu_id)
        late = False
        lease = self.leases.pop(key, None)
        if lease is not None:
            if self._host_lease.get(lease.host_id) == key:
                del self._host_lease[lease.host_id]
        else:
            lease = self.lapsed.pop(key, None)
            if lease is not None:
                late = True
                self.counters.late_returns += 1
                if self._host_lapsed.get(lease.host_id) == key:
                    del self._host_lapsed[lease.host_id]
        self._drop_lapsed_for(host)
        e = self.searches[search] if 0 <= search < len(self.searches) \
            else None
        if lease is None and self._settled.get(host) == key:
            # the host re-reported work this server already settled (its
            # first report raced a lapse, or an ack was lost below the cs
            # window) — a benign retransmit, NOT protocol misuse, and it
            # must never reach registry.on_result: ``returned`` (the
            # reliability numerator) counts each workunit at most once
            self.counters.duplicate_reports += 1
            self.registry.touch(host, now)
        elif lease is None or e is None:
            # no lease on record: without the workunit payload there is
            # nothing safe to assimilate — count and acknowledge
            self.counters.unknown_results += 1
            self.registry.touch(host, now)
        elif e.status == KILLED:
            # a killed search's engine is frozen (its committed history
            # stays a prefix of the solo run, like the orchestrator's
            # kill) — track the host's return, drop the result
            self.registry.on_result(host, now,
                                    max(now - lease.issued_at, 1e-9))
            self.counters.dropped_results += 1
            if self._tracer is not None:
                self._tracer.on_settle(search, wu_id, now, "dropped", late)
        else:
            tr = self._tracer
            if tr is not None:
                # read-only peeks BEFORE assimilation: stale is the §5
                # phase compare the engine itself applies, commit shows as
                # an iteration delta
                was_stale = lease.wu.phase_id != e.fgdo.engine.phase_id
                it0 = e.fgdo.engine.iteration
            e.fgdo.assimilate(lease.wu, float(msg["y"]), host, now)
            if tr is not None:
                tr.on_settle(
                    search, wu_id, now,
                    "stale" if was_stale
                    else ("committed" if e.fgdo.engine.iteration > it0
                          else "assimilated"), late)
            if e.fgdo.engine.done:
                e.status = DONE
            if self.policy == "portfolio":
                self._apply_portfolio()
        if lease is not None:
            self._settled[host] = key
        _, best_y = self.best()
        iteration = (e.fgdo.engine.iteration if e is not None
                     else 0)
        return protocol.ack_reply(self.done, iteration, best_y)

    def _heartbeat(self, msg: dict) -> dict:
        self._advance(msg["now"])
        self.registry.touch(int(msg["host_id"]), msg["now"])
        self.counters.heartbeats += 1
        _, best_y = self.best()
        return protocol.ack_reply(self.done, 0, best_y)

    def _status(self) -> dict:
        # read-only on purpose: the checkpoint layer skips logging it
        best_id, best_y = self.best()
        return {
            "kind": "status", "now": self.now, "done": self.done,
            "searches": [{
                "search_id": e.search_id, "name": e.name,
                "status": e.status,
                "phase": e.fgdo.phase,
                "iteration": e.fgdo.engine.iteration,
                "best": e.fgdo.engine.best_fitness,
            } for e in self.searches],
            "incumbent": best_id, "best": best_y,
            "leases": len(self.leases), "lapsed": len(self.lapsed),
            "counters": dataclasses.asdict(self.counters),
            "registry": self.registry.summary(),
            "cache": (None if self._cache_status is None
                      else self._cache_status()),
            # service pressure (§13 satellite): lease depth is ``leases``
            # above; intake queue depth rides here when one is attached
            "intake": (None if self._intake_probe is None
                       else self._intake_probe()),
            # §14: the obs plane's own configuration + retention depth —
            # ring size and cadence are construction-path knobs now, so
            # the reply is where an operator confirms what a server runs
            "obs": (None if self._hub is None else {
                "interval": self._hub.interval,
                "ring": self._hub.ring,
                "snapshots": self._hub.seq,
                "tracer": (None if self._tracer is None
                           else self._tracer.summary()),
                "retention": (None if self._retention is None
                              else self._retention.summary()),
            }),
        }

    def _subscribe_stats(self, msg: dict) -> dict:
        if self._hub is None:
            return protocol.error_reply(
                "no metrics hub attached (stats are opt-in server-side)")
        from repro.obs.metrics import STREAM_VERSION
        since = int(msg.get("since", -1))
        snaps, cursor, dropped = self._hub.since(since)
        if dropped and msg.get("from_store") and self._retention is not None:
            # §14 backfill: serve ring-evicted history from the retention
            # store's CURRENT epoch (same seq numbering as the live ring).
            # The store may itself have compacted — whatever it still
            # holds shrinks the reported gap, the rest stays ``dropped``.
            oldest = int(snaps[0]["seq"]) if snaps else cursor + 1
            backfill = [s for s in
                        self._retention.snapshots(epoch=self._retention.epoch)
                        if since < int(s["seq"]) < oldest]
            if backfill:
                snaps = backfill + snaps
                dropped = max(0, dropped - len(backfill))
        return protocol.stats_reply(snaps, cursor, self._hub.interval,
                                    STREAM_VERSION, dropped)

    # -- hub probes (read-only views over existing state, §13) ---------------

    def _probe_server(self) -> dict:
        # vars() copy, not dataclasses.asdict: the counters dataclass is
        # flat, and the recursive walk costs ~10x on the per-sample path
        d = dict(vars(self.counters))
        d["lease_depth"] = len(self.leases)
        d["lapsed_depth"] = len(self.lapsed)
        d["done"] = self.done
        _, best_y = self.best()
        d["best"] = best_y
        d["searches"] = [{
            "search_id": e.search_id, "status": e.status,
            "phase": e.fgdo.phase, "iteration": e.fgdo.engine.iteration,
            "best": e.fgdo.engine.best_fitness,
        } for e in self.searches]
        return d

    def _probe_registry(self) -> dict:
        # include_ids: the cohort ids the anomaly detector pages on ride
        # the summary's single pass instead of two extra registry scans
        return self.registry.summary(include_ids=True)

    def _apply_portfolio(self) -> None:
        _, best_y = self.best()
        if not np.isfinite(best_y):
            return
        cut = dominated_cut(best_y, self.kill_margin)
        for e in self.searches:
            if (e.status == RUNNING
                    and e.fgdo.engine.iteration >= self.probation_iterations
                    and e.fgdo.engine.best_fitness > cut):
                e.status = KILLED

    # -- crash-restore seams -------------------------------------------------

    def world_view(self) -> dict:
        """Everything a deterministic client world needs to rebuild its
        event schedule after a restore: the lease tables (outstanding AND
        lapsed — a lapsed lease's holder is still out there computing)
        and each known host's next contact time."""
        def lease_doc(l: Lease) -> dict:
            return {"search": l.search_id, "wu": l.wu_id,
                    "host_id": l.host_id, "issued_at": l.issued_at,
                    "deadline": l.deadline,
                    "phase": l.wu.phase_id,
                    "point": np.asarray(l.wu.point),
                    "alpha": l.wu.alpha, "validates": l.wu.validates}
        return {
            "now": self.now,
            "leases": [lease_doc(l) for l in self.leases.values()],
            "lapsed": [lease_doc(l) for l in self.lapsed.values()],
            "hosts": [{"host_id": h, "state": r.state,
                       "next_contact_at": r.next_contact_at,
                       # the host's last applied cs: a resumed client pool
                       # continues its per-host counters from here, so the
                       # regenerated future traffic carries the same
                       # idempotency keys as the uninterrupted run's
                       "client_seq": self._client_seq.get(h, -1)}
                      for h, r in self.registry.hosts.items()],
        }

    def state_dict(self) -> dict:
        return {
            "v": 2,
            "now": self.now, "cursor": self.cursor,
            "stopping": self.stopping,
            "counters": dataclasses.asdict(self.counters),
            "registry": self.registry.state_dict(),
            "searches": [{"search_id": e.search_id, "status": e.status,
                          "fgdo": e.fgdo.state_dict()}
                         for e in self.searches],
            "leases": [self._lease_state(l) for l in self.leases.values()],
            "lapsed": [self._lease_state(l) for l in self.lapsed.values()],
            # v2: the idempotency layer survives the crash — a retry that
            # straddles a restore must still deduplicate
            "client_seq": {str(h): c for h, c in self._client_seq.items()},
            "last_reply": {str(h): r for h, r in self._last_reply.items()},
            "settled": {str(h): list(k) for h, k in self._settled.items()},
        }

    @staticmethod
    def _lease_state(l: Lease) -> dict:
        return {"search_id": l.search_id, "wu_id": l.wu_id,
                "host_id": l.host_id, "issued_at": l.issued_at,
                "deadline": l.deadline,
                "wu": {"wu_id": l.wu.wu_id, "phase_id": l.wu.phase_id,
                       "point": np.asarray(l.wu.point),
                       "alpha": l.wu.alpha, "validates": l.wu.validates,
                       "issued_at": l.wu.issued_at}}

    @staticmethod
    def _lease_from_state(d: dict) -> Lease:
        w = d["wu"]
        wu = WorkUnit(int(w["wu_id"]), int(w["phase_id"]),
                      np.asarray(w["point"], np.float64), float(w["alpha"]),
                      None if w["validates"] is None else int(w["validates"]),
                      issued_at=float(w["issued_at"]))
        return Lease(int(d["search_id"]), int(d["wu_id"]),
                     int(d["host_id"]), float(d["issued_at"]),
                     float(d["deadline"]), wu)

    def load_state(self, d: dict) -> None:
        if len(d["searches"]) != len(self.searches):
            raise ValueError("state has a different number of searches")
        self.now = float(d["now"])
        self.cursor = int(d["cursor"])
        self.stopping = bool(d["stopping"])
        self.counters = ServerCounters(
            **{k: int(v) for k, v in d["counters"].items()})
        self.registry.load_state(d["registry"])
        for e, s in zip(self.searches, d["searches"]):
            e.status = s["status"]
            e.fgdo.load_state(s["fgdo"])
        self.leases = {}
        self._host_lease = {}
        self._next_deadline = float("inf")
        for ld in d["leases"]:
            l = self._lease_from_state(ld)
            self.leases[(l.search_id, l.wu_id)] = l
            self._host_lease[l.host_id] = (l.search_id, l.wu_id)
            self._next_deadline = min(self._next_deadline, l.deadline)
        self.lapsed = {}
        self._host_lapsed = {}
        for ld in d["lapsed"]:
            l = self._lease_from_state(ld)
            self.lapsed[(l.search_id, l.wu_id)] = l
            self._host_lapsed[l.host_id] = (l.search_id, l.wu_id)
        # v2 fields absent from a v1 snapshot default empty (the replayed
        # suffix then rebuilds whatever dedup state its messages carry)
        self._client_seq = {int(h): int(c)
                            for h, c in d.get("client_seq", {}).items()}
        self._last_reply = {int(h): dict(r)
                            for h, r in d.get("last_reply", {}).items()}
        self._settled = {int(h): (int(k[0]), int(k[1]))
                         for h, k in d.get("settled", {}).items()}
        self._last_sweep = float("-inf")
