"""Transports for the work server: in-process loopback and TCP sockets.

Both speak the same framed protocol (``protocol.frame`` + codec byte), and
both present the same two-sided API::

    transport.start(handler)      # handler: dict message -> dict reply
    conn = transport.connect()    # client side
    reply = conn.call(msg)        # one request/reply round-trip
    conn.close(); transport.stop()

**Loopback** round-trips every message through real ``encode``/``decode``
bytes (so serialization bugs cannot hide behind in-process object passing)
but stays single-threaded and allocation-cheap — the deterministic
transport the tests, dryrun smoke and benchmarks drive.

**TCP** runs an asyncio server on a background thread; each connection is
served frame-by-frame in arrival order.  Determinism over TCP comes from
the CLIENT, not the transport: the simulated client pool issues one
request at a time and waits for the reply, so the server observes a total
order identical to loopback.  (Nothing stops a real deployment from
running many concurrent volunteer connections — frames interleave at
message granularity and the handler remains single-threaded inside the
asyncio loop — but then message order, and hence the trajectory, is up to
the network, exactly like a real BOINC server.)
"""
from __future__ import annotations

import asyncio
import socket
import struct
import threading
from typing import Callable, Optional

from repro.server.protocol import (DEFAULT_CODEC, FrameDecoder, ProtocolError,
                                   decode_message, encode_message,
                                   error_reply, frame)

Handler = Callable[[dict], dict]
_LEN = struct.Struct(">I")


class LoopbackConnection:
    def __init__(self, handler: Handler, codec: int):
        self._handler = handler
        self._codec = codec
        self.calls = 0

    def call(self, msg: dict) -> dict:
        self.calls += 1
        req = decode_message(frame(encode_message(msg, self._codec))[4:])
        rep = self._handler(req)
        return decode_message(encode_message(rep, self._codec))

    def close(self) -> None:
        pass


class LoopbackTransport:
    name = "loopback"

    def __init__(self, codec: int = DEFAULT_CODEC):
        self.codec = codec
        self._handler: Optional[Handler] = None

    def start(self, handler: Handler) -> "LoopbackTransport":
        self._handler = handler
        return self

    def connect(self) -> LoopbackConnection:
        if self._handler is None:
            raise RuntimeError("transport not started")
        return LoopbackConnection(self._handler, self.codec)

    def stop(self) -> None:
        self._handler = None


class TcpConnection:
    """Blocking request/reply client over one TCP socket."""

    def __init__(self, host: str, port: int, codec: int = DEFAULT_CODEC,
                 timeout: float = 60.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._codec = codec
        self.calls = 0

    def _read_exactly(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise ConnectionError("server closed the connection")
            buf.extend(chunk)
        return bytes(buf)

    def call(self, msg: dict) -> dict:
        self.calls += 1
        self._sock.sendall(frame(encode_message(msg, self._codec)))
        (n,) = _LEN.unpack(self._read_exactly(4))
        return decode_message(self._read_exactly(n))

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class TcpTransport:
    """asyncio TCP server on a background thread; handler runs inside the
    loop thread, one frame at a time per connection."""

    name = "tcp"

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 codec: int = DEFAULT_CODEC):
        self.host = host
        self.port = port                  # 0: ephemeral, resolved by start()
        self.codec = codec
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._server = None
        self._started = threading.Event()
        self._start_error: Optional[BaseException] = None

    def start(self, handler: Handler) -> "TcpTransport":
        async def serve_connection(reader, writer):
            dec = FrameDecoder()
            try:
                while True:
                    data = await reader.read(65536)
                    if not data:
                        break
                    for payload in dec.feed(data):
                        try:
                            rep = handler(decode_message(payload))
                        except ProtocolError as e:
                            rep = error_reply(str(e))
                        except Exception as e:  # noqa: BLE001 — a bad
                            # frame from an untrusted client (well-formed
                            # but missing fields, say) must produce an
                            # error REPLY, not a dead connection the
                            # client only discovers at its socket timeout
                            rep = error_reply(
                                f"{type(e).__name__}: {e}")
                        writer.write(frame(encode_message(rep, self.codec)))
                    await writer.drain()
            except (ConnectionError, asyncio.IncompleteReadError):
                pass
            finally:
                try:
                    writer.close()
                except Exception:
                    pass

        async def main():
            self._server = await asyncio.start_server(
                serve_connection, self.host, self.port)
            self.port = self._server.sockets[0].getsockname()[1]
            self._started.set()
            async with self._server:
                await self._server.serve_forever()

        def run():
            self._loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self._loop)
            try:
                self._loop.run_until_complete(main())
            except asyncio.CancelledError:
                pass
            except BaseException as e:      # surface bind errors to start()
                self._start_error = e
                self._started.set()
            finally:
                self._loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="fgdo-tcp-server")
        self._thread.start()
        self._started.wait(timeout=30.0)
        if self._start_error is not None:
            raise RuntimeError(
                f"TCP transport failed to start: {self._start_error}")
        if not self._started.is_set():
            raise RuntimeError("TCP transport failed to start (timeout)")
        return self

    def connect(self) -> TcpConnection:
        return TcpConnection(self.host, self.port, self.codec)

    def stop(self) -> None:
        if self._loop is not None and self._loop.is_running():
            def shutdown():
                for task in asyncio.all_tasks(self._loop):
                    task.cancel()
            self._loop.call_soon_threadsafe(shutdown)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        self._loop = None
        self._thread = None


def make_transport(name: str, **kwargs):
    """The transport registry: ``loopback`` or ``tcp``."""
    if name == "loopback":
        return LoopbackTransport(**kwargs)
    if name == "tcp":
        return TcpTransport(**kwargs)
    raise ValueError(f"unknown transport {name!r} (loopback|tcp)")
