"""Transports for the work server: in-process loopback and TCP sockets.

Both speak the same framed protocol (``protocol.frame`` + codec byte), and
both present the same two-sided API::

    transport.start(handler)      # handler: dict message -> dict reply
    conn = transport.connect()    # client side
    reply = conn.call(msg)        # one request/reply round-trip
    conn.close(); transport.stop()

**Loopback** round-trips every message through real ``encode``/``decode``
bytes (so serialization bugs cannot hide behind in-process object passing)
but stays single-threaded and allocation-cheap — the deterministic
transport the tests, dryrun smoke and benchmarks drive.

**TCP** runs an asyncio server on a background thread; each connection is
served frame-by-frame in arrival order.  With the default in-loop handler,
determinism over TCP comes from the CLIENT: the serial client pool issues
one request at a time, so the server observes a total order identical to
loopback.  With ``blocking_handler=True`` the handler may BLOCK (the
sequenced-intake handler parks a message until its stamp's turn —
DESIGN.md §12), so it runs on a dedicated thread pool instead of the loop
thread: each connection still processes its own frames strictly in order
(≤1 outstanding handler call per connection), but other connections'
frames proceed while one is parked — which is exactly what lets N
concurrent volunteer connections interleave arbitrarily at the socket
while the server commits messages in intake-stamp order.

Both connection types additionally expose the raw stream half-steps
``send_bytes``/``read_reply`` that ``chaos.ChaosConnection`` composes into
faulty deliveries (torn writes, duplicated frames, lost replies); on
loopback the byte stream is emulated through a real ``FrameDecoder`` and
a reply queue, so even the in-process transport exercises stream framing.
"""
from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import socket
import struct
import threading
from typing import Callable, Optional

from repro.server.protocol import (DEFAULT_CODEC, FrameDecoder, ProtocolError,
                                   decode_message, encode_message,
                                   error_reply, frame)

Handler = Callable[[dict], dict]
_LEN = struct.Struct(">I")


class LoopbackConnection:
    def __init__(self, handler: Handler, codec: int):
        self._handler = handler
        self.codec = codec
        self.calls = 0
        self._decoder = FrameDecoder()
        self._replies = collections.deque()

    def call(self, msg: dict) -> dict:
        self.calls += 1
        req = decode_message(frame(encode_message(msg, self.codec))[4:])
        rep = self._handler(req)
        return decode_message(encode_message(rep, self.codec))

    # -- emulated byte stream (the chaos layer's substrate) ------------------

    def send_bytes(self, data: bytes) -> None:
        """Feed raw framed bytes exactly like a server's read loop would:
        complete frames are handled (errors become error replies, as over
        TCP), partial frames wait in the decoder for more bytes — so a
        torn write followed by close() genuinely loses the fragment."""
        for payload in self._decoder.feed(data):
            try:
                rep = self._handler(decode_message(payload))
            except ProtocolError as e:
                rep = error_reply(str(e))
            except Exception as e:  # noqa: BLE001 — mirror the TCP server
                rep = error_reply(f"{type(e).__name__}: {e}")
            self._replies.append(encode_message(rep, self.codec))

    def read_reply(self) -> dict:
        if not self._replies:
            raise ConnectionError("no reply pending on loopback stream")
        return decode_message(self._replies.popleft())

    def close(self) -> None:
        self._decoder = FrameDecoder()
        self._replies.clear()


class LoopbackTransport:
    name = "loopback"

    def __init__(self, codec: int = DEFAULT_CODEC):
        self.codec = codec
        self._handler: Optional[Handler] = None

    def start(self, handler: Handler) -> "LoopbackTransport":
        self._handler = handler
        return self

    def connect(self) -> LoopbackConnection:
        if self._handler is None:
            raise RuntimeError("transport not started")
        return LoopbackConnection(self._handler, self.codec)

    def stop(self) -> None:
        self._handler = None


class TcpConnection:
    """Blocking request/reply client over one TCP socket."""

    def __init__(self, host: str, port: int, codec: int = DEFAULT_CODEC,
                 timeout: float = 60.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.codec = codec
        self.calls = 0

    def _read_exactly(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise ConnectionError("server closed the connection")
            buf.extend(chunk)
        return bytes(buf)

    def send_bytes(self, data: bytes) -> None:
        self._sock.sendall(data)

    def read_reply(self) -> dict:
        (n,) = _LEN.unpack(self._read_exactly(4))
        return decode_message(self._read_exactly(n))

    def call(self, msg: dict) -> dict:
        self.calls += 1
        self.send_bytes(frame(encode_message(msg, self.codec)))
        return self.read_reply()

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class TcpTransport:
    """asyncio TCP server on a background thread.  By default the handler
    runs inside the loop thread, one frame at a time per connection; with
    ``blocking_handler=True`` it runs on a dedicated thread pool so a
    handler that PARKS (the sequenced intake waiting for a stamp's turn)
    stalls only its own connection while the loop keeps reading others —
    per-connection frame order is still strict (each frame is awaited
    before the next is dispatched)."""

    name = "tcp"

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 codec: int = DEFAULT_CODEC, blocking_handler: bool = False,
                 handler_workers: int = 64):
        self.host = host
        self.port = port                  # 0: ephemeral, resolved by start()
        self.codec = codec
        self.blocking_handler = blocking_handler
        self.handler_workers = handler_workers
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._server = None
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._started = threading.Event()
        self._start_error: Optional[BaseException] = None

    def start(self, handler: Handler) -> "TcpTransport":
        if self.blocking_handler:
            # one frame in flight per connection, so n_clients workers
            # suffice; 64 covers every pool size the smokes drive
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=self.handler_workers,
                thread_name_prefix="fgdo-intake")

        def handle_one(payload: bytes) -> dict:
            try:
                return handler(decode_message(payload))
            except ProtocolError as e:
                return error_reply(str(e))
            except Exception as e:  # noqa: BLE001 — a bad frame from an
                # untrusted client (well-formed but missing fields, say)
                # must produce an error REPLY, not a dead connection the
                # client only discovers at its socket timeout
                return error_reply(f"{type(e).__name__}: {e}")

        async def serve_connection(reader, writer):
            dec = FrameDecoder()
            try:
                while True:
                    data = await reader.read(65536)
                    if not data:
                        break
                    for payload in dec.feed(data):
                        if self._executor is not None:
                            rep = await asyncio.get_running_loop() \
                                .run_in_executor(self._executor,
                                                 handle_one, payload)
                        else:
                            rep = handle_one(payload)
                        writer.write(frame(encode_message(rep, self.codec)))
                    await writer.drain()
            except (ConnectionError, asyncio.IncompleteReadError):
                pass
            except ProtocolError:
                # an unframeable stream (oversized length prefix from a
                # torn write's garbage) — drop the connection cleanly;
                # the client reconnects with a fresh stream
                pass
            finally:
                try:
                    writer.close()
                except Exception:
                    pass

        async def main():
            self._server = await asyncio.start_server(
                serve_connection, self.host, self.port)
            self.port = self._server.sockets[0].getsockname()[1]
            self._started.set()
            async with self._server:
                await self._server.serve_forever()

        def run():
            self._loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self._loop)
            try:
                self._loop.run_until_complete(main())
            except asyncio.CancelledError:
                pass
            except BaseException as e:      # surface bind errors to start()
                self._start_error = e
                self._started.set()
            finally:
                self._loop.close()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="fgdo-tcp-server")
        self._thread.start()
        self._started.wait(timeout=30.0)
        if self._start_error is not None:
            raise RuntimeError(
                f"TCP transport failed to start: {self._start_error}")
        if not self._started.is_set():
            raise RuntimeError("TCP transport failed to start (timeout)")
        return self

    def connect(self) -> TcpConnection:
        return TcpConnection(self.host, self.port, self.codec)

    def stop(self) -> None:
        if self._loop is not None and self._loop.is_running():
            def shutdown():
                for task in asyncio.all_tasks(self._loop):
                    task.cancel()
            self._loop.call_soon_threadsafe(shutdown)
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None
        self._loop = None
        self._thread = None


def make_transport(name: str, **kwargs):
    """The transport registry: ``loopback``, ``tcp``, or ``chaos`` — the
    fault-injection decorator over either (``inner=`` names the wrapped
    transport, ``plan=`` a ``chaos.PRESETS`` name, ``FaultPlan`` doc dict,
    or ``FaultPlan`` instance)."""
    if name == "loopback":
        return LoopbackTransport(**kwargs)
    if name == "tcp":
        return TcpTransport(**kwargs)
    if name == "chaos":
        from repro.server.chaos import ChaosTransport, FaultPlan, PRESETS
        inner = kwargs.pop("inner", "tcp")
        plan = kwargs.pop("plan", "degraded")
        if isinstance(plan, str):
            plan = PRESETS[plan]
        elif isinstance(plan, dict):
            plan = FaultPlan.from_doc(plan)
        return ChaosTransport(make_transport(inner, **kwargs), plan)
    raise ValueError(f"unknown transport {name!r} (loopback|tcp|chaos)")
