"""Simulated volunteer clients + the end-to-end server substrate.

``SimClientPool`` replays the batched grid's host distributions — lognormal
speeds, result loss, malicious corruption, staggered arrival, all from
``grid.sample_hosts``/``GridConfig`` — as REAL protocol clients: every
interaction is a framed request/reply through a transport (loopback bytes
or TCP sockets), driven in virtual time by a deterministic event loop.

Determinism and crash recovery hang on one property: **the client world is
a pure function of the server's state**.  Per-workunit behavior (latency
noise, result loss, the malicious draw) is keyed on ``(fleet seed, host,
wu)`` — counter-based, not sequential — so a host computing workunit X
produces the same result at the same virtual time whether or not the
server was killed and restored in between.  After a restore,
``resume_from(server.world_view())`` rebuilds the entire event schedule
from the lease tables (outstanding AND lapsed) plus each idle host's
``next_contact_at``: outstanding work is re-leased to exactly the hosts
that held it, so the restored run replays the uninterrupted future —
bit-identical committed iterates, the contract the dryrun smoke gates.

Event ordering is canonical — ``(time, kind-priority, host)``, completions
before requests — NOT insertion order, so a rebuilt queue sorts exactly
like the original.  Fitness evaluation is lazily batched: all in-flight
points with unknown values go through ONE ``EvalBackend`` bucket (with
on-device malicious-corruption lanes) the first time any of them is
needed, which is what keeps the loopback server within striking distance
of the direct batched grid in the benchmark overhead row.

``ConcurrentClientPool`` is the same fleet with the serialization removed
(DESIGN.md §12): a coordinator thread owns the canonical virtual-time
heap and assigns each message a global **intake stamp at release time, in
canonical order**, while worker threads — each with its own REAL
connection — deliver them concurrently (optionally through the chaos
fault injector).  Release is governed by a lower-bound rule: the heap
minimum is released only once it provably sorts before every in-flight
host's earliest possible FOLLOW-UP event, so the stamp order equals the
serial pool's processing order exactly.  The server's sequenced intake
then handles arrivals in stamp order, and (host, cs) idempotency absorbs
retries/duplicates — which is why N racing connections under a seeded
fault schedule still commit bit-identical iterates to the serial
fault-free baseline.

``ServerSubstrate`` wires it all together: build (or recover) a
``WorkServer``, attach the checkpoint manager, start a transport (with
``concurrent``/``chaos``, a sequenced intake and/or fault-plan wrapper),
run the pool to completion.  ``python -m repro.server.sim`` runs a seeded
single-search smoke — the dryrun kill/restore harness launches it as a
subprocess, SIGKILLs it mid-search, and relaunches with ``--resume``.
"""
from __future__ import annotations

import dataclasses
import heapq
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.grid import GridConfig, sample_hosts
from repro.core.orchestrator.director import SearchSpec
from repro.core.substrates.eval_backend import EvalBackend
from repro.core.substrates.eval_cache import CachingSubmitter, EvalCache
from repro.server import protocol
from repro.server.chaos import ChaosTransport, FaultPlan, PRESETS
from repro.server.checkpoint import CheckpointManager
from repro.server.server import SequencedIntake, WorkServer
from repro.server.transport import make_transport

PRIO_COMPLETE, PRIO_REQUEST = 0, 1

#: domain salts for the counter-based per-host / per-(host, wu) draws —
#: distinct streams that can never collide with each other or the
#: sequential ``sample_hosts`` population draw
_ONLINE_SALT = 0x0F51DE
_WU_SALT = 0x5EEDED


class SimulatedCrash(RuntimeError):
    """Raised by the pool when ``max_messages`` is hit — the in-process
    stand-in for a SIGKILL (tests recover from the checkpoint dir without
    paying for a subprocess)."""


def _wu_draws(fleet_seed: int, host: int, wu: int) -> Tuple[float, float, float]:
    """(latency noise in [0.8, 1.2], loss uniform, malicious u in
    [0.2, 0.8]) for one (host, workunit) pair — keyed, not sequential, so
    the draw survives a server crash/restore unchanged."""
    rng = np.random.default_rng(
        np.random.SeedSequence((_WU_SALT, fleet_seed, host, wu)))
    return (float(rng.uniform(0.8, 1.2)), float(rng.random()),
            float(rng.uniform(0.2, 0.8)))


@dataclasses.dataclass
class PoolStats:
    messages: int = 0
    work_received: int = 0
    results_reported: int = 0
    no_work: int = 0
    failed: int = 0                   # results lost to vanishing hosts
    corrupted: int = 0                # malicious lanes evaluated
    eval_batches: int = 0
    evals: int = 0
    resumed_leases: int = 0           # in-flight work rebuilt after restore
    sim_time: float = 0.0


@dataclasses.dataclass
class _InFlight:
    search: int
    wu: int
    point: np.ndarray
    issued_at: float


class SimClientPool:
    """Deterministic virtual-time client fleet over one connection."""

    def __init__(self, cfg: GridConfig, backend: EvalBackend,
                 max_messages: Optional[int] = None,
                 silence_at: Optional[float] = None,
                 silence_frac: float = 0.25):
        self.cfg = cfg
        self.backend = backend
        self.max_messages = max_messages
        # injected fleet failure (the obs smoke's churn anomaly): from
        # virtual time ``silence_at`` on, the deterministic cohort of the
        # ``silence_frac``·n_hosts LOWEST host ids stops contacting the
        # server — events are swallowed at pop time, so the server only
        # ever sees silence: leases lapse, the registry sweep flips the
        # cohort suspect, and the anomaly detector has something to page
        self.silence_at = None if silence_at is None else float(silence_at)
        n_sil = 0 if silence_at is None \
            else int(round(float(silence_frac) * cfg.n_hosts))
        self.silenced = frozenset(range(n_sil))
        self.speeds, self.malicious, _ = sample_hosts(cfg)
        online_rng = np.random.default_rng(
            np.random.SeedSequence((_ONLINE_SALT, cfg.seed)))
        self.online = online_rng.uniform(0, cfg.base_eval_time / 10,
                                         cfg.n_hosts)
        self.stats = PoolStats()
        self._events: List[Tuple[float, int, int]] = []
        self._inflight: Dict[int, _InFlight] = {}
        self._ycache: Dict[Tuple[int, int], float] = {}
        self._registered: set = set()
        self._stopped: set = set()
        self._seeded = False          # resume_from pre-seeded the schedule
        # per-host client sequence counters — the idempotency keys every
        # message carries (serial traffic too, so the wire is uniform);
        # after a resume they continue from the server's last applied cs
        self._cs: Dict[int, int] = {}
        self.request_wall: List[float] = []   # request_work round-trip walls

    # -- crash-restore rebuild ----------------------------------------------

    def resume_from(self, world: dict) -> None:
        """Rebuild the event schedule from a restored server's
        ``world_view()``: leased hosts resume their in-flight computation
        (completion or vanish-retry at the deterministic per-(host, wu)
        time), idle hosts re-contact at ``next_contact_at``, and hosts
        the run never saw come online on their original stagger."""
        leased = set()
        for l in world["leases"] + world["lapsed"]:
            h, wu = int(l["host_id"]), int(l["wu"])
            if h in leased:           # server keeps ≤ 1 lease per host
                continue
            leased.add(h)
            self._registered.add(h)
            noise, loss, _ = _wu_draws(self.cfg.seed, h, wu)
            dt = self.cfg.base_eval_time / self.speeds[h] * noise
            t0 = float(l["issued_at"])
            if loss < self.cfg.failure_prob:
                self.stats.failed += 1
                heapq.heappush(self._events, (t0 + 4 * dt, PRIO_REQUEST, h))
            else:
                self._inflight[h] = _InFlight(
                    int(l["search"]), wu,
                    np.asarray(l["point"], np.float64), t0)
                heapq.heappush(self._events, (t0 + dt, PRIO_COMPLETE, h))
            self.stats.resumed_leases += 1
        for rec in world["hosts"]:
            h = int(rec["host_id"])
            # cs continuity: the resumed fleet keeps counting from the
            # server's last applied message per host, so its traffic can
            # never collide with (or be deduplicated against) the prefix
            self._cs[h] = int(rec.get("client_seq", -1)) + 1
            if h in leased or rec["next_contact_at"] is None:
                continue
            self._registered.add(h)
            heapq.heappush(self._events,
                           (float(rec["next_contact_at"]), PRIO_REQUEST, h))
        known = leased | self._registered
        for h in range(self.cfg.n_hosts):
            if h not in known:
                heapq.heappush(self._events,
                               (float(self.online[h]), PRIO_REQUEST, h))
        self._seeded = True

    # -- evaluation ----------------------------------------------------------

    def _value(self, search: int, wu: int) -> float:
        key = (search, wu)
        y = self._ycache.pop(key, None)
        if y is not None:
            return y
        # lazily batch every in-flight unknown into ONE backend bucket;
        # row-independence (the repo-wide width-invariance contract) means
        # batch composition cannot change any lane's value
        todo = sorted((inf.search, inf.wu, h)
                      for h, inf in self._inflight.items()
                      if (inf.search, inf.wu) not in self._ycache)
        pts = np.stack([self._inflight[h].point for _, _, h in todo])
        mal_u = np.full(len(todo), np.nan)
        for i, (_, w, h) in enumerate(todo):
            if self.malicious[h]:
                mal_u[i] = _wu_draws(self.cfg.seed, h, w)[2]
                self.stats.corrupted += 1
        ys = self.backend(pts, mal_u)
        self.stats.eval_batches += 1
        self.stats.evals += len(todo)
        for (s, w, _), yv in zip(todo, ys):
            self._ycache[(s, w)] = float(yv)
        return self._ycache.pop(key)

    # -- the virtual-time loop ----------------------------------------------

    def _gone_silent(self, t: float, h: int) -> bool:
        """Whether this event belongs to the silenced cohort after the
        silence time (deterministic in virtual time, so every run sharing
        the silence parameters swallows exactly the same events)."""
        return self.silence_at is not None and t >= self.silence_at \
            and h in self.silenced

    def _next_cs(self, h: int) -> int:
        c = self._cs.get(h, 0)
        self._cs[h] = c + 1
        return c

    def _call(self, conn, msg: dict) -> dict:
        if self.max_messages is not None and \
                self.stats.messages >= self.max_messages:
            raise SimulatedCrash(
                f"simulated crash after {self.stats.messages} messages")
        self.stats.messages += 1
        t0 = time.perf_counter()
        rep = conn.call(msg)
        if msg.get("kind") == "request_work":
            self.request_wall.append(time.perf_counter() - t0)
        return rep

    def run(self, conn) -> PoolStats:
        cfg = self.cfg
        if not self._seeded:
            for h in range(cfg.n_hosts):
                heapq.heappush(self._events,
                               (float(self.online[h]), PRIO_REQUEST, h))
        done = False
        while self._events and not done:
            t, prio, h = heapq.heappop(self._events)
            if h in self._stopped or self._gone_silent(t, h):
                continue
            self.stats.sim_time = max(self.stats.sim_time, t)
            if prio == PRIO_REQUEST:
                if h not in self._registered:
                    self._call(conn,
                               protocol.register(h, t, cs=self._next_cs(h)))
                    self._registered.add(h)
                rep = self._call(
                    conn, protocol.request_work(h, t, cs=self._next_cs(h)))
                if rep["kind"] == "work":
                    self.stats.work_received += 1
                    wu = int(rep["wu"])
                    noise, loss, _ = _wu_draws(cfg.seed, h, wu)
                    dt = cfg.base_eval_time / self.speeds[h] * noise
                    if loss < cfg.failure_prob:
                        # the host vanishes with the result and re-requests
                        # much later — the server only ever sees silence
                        self.stats.failed += 1
                        heapq.heappush(self._events,
                                       (t + 4 * dt, PRIO_REQUEST, h))
                    else:
                        self._inflight[h] = _InFlight(
                            int(rep["search"]), wu,
                            np.asarray(rep["point"], np.float64), t)
                        heapq.heappush(self._events,
                                       (t + dt, PRIO_COMPLETE, h))
                else:                 # no_work (or done)
                    self.stats.no_work += 1
                    if rep.get("done"):
                        self._stopped.add(h)
                    else:
                        heapq.heappush(
                            self._events,
                            (t + float(rep["retry_after"]), PRIO_REQUEST, h))
            else:                     # PRIO_COMPLETE
                inf = self._inflight[h]
                y = self._value(inf.search, inf.wu)  # batches all in-flight
                del self._inflight[h]
                rep = self._call(conn, protocol.report_result(
                    h, inf.search, inf.wu, y, t, cs=self._next_cs(h)))
                self.stats.results_reported += 1
                if rep.get("done"):
                    done = True       # engines sealed; drain and stop
                else:
                    heapq.heappush(self._events, (t, PRIO_REQUEST, h))
        return self.stats


class ConcurrentClientPool(SimClientPool):
    """The same deterministic fleet, delivered by racing threads.

    A coordinator (the calling thread) pops the canonical virtual-time
    heap and RELEASES each event: it stamps the event's messages with
    consecutive global intake sequence numbers and hands them to one of
    ``n_workers`` worker threads (hosts are multiplexed host→worker, so a
    host's own messages stay ordered on one connection) which deliver
    them over real, concurrently racing connections.  Determinism is by
    construction, not by luck:

      * stamps are assigned at RELEASE time in canonical heap order, and
        the server's ``SequencedIntake`` handles messages in stamp order
        — so the applied sequence is the serial pool's sequence no matter
        how arrivals interleave (or how chaos delays/duplicates them);
      * the heap minimum ``e`` is released only when ``e`` sorts before
        every in-flight host's earliest possible follow-up event (a
        completion's follow-up request lands at the same virtual time;
        a request's earliest follow-up is bounded by the minimum
        latency-noise completion and the minimum no-work retry), so no
        event that the serial order would process before ``e`` can still
        be created by an outstanding reply;
      * replies only ever touch the reporting host's own schedule, so
        absorbing them as they arrive (in any order) commutes.

    Fitness values are computed by the coordinator at completion-release
    time through the same lazily-batched ``_value`` — the backend stays
    single-threaded, and row-independence makes batch composition
    value-neutral.  ``max_messages`` counts RELEASED messages, so the
    simulated-crash point is deterministic here too; because the server
    applies a stamp-prefix of the released sequence, the crashed state is
    always a canonical prefix and ``resume_from`` replays the same
    future.
    """

    #: generous safety net — a stuck reply means a real bug (or an
    #: exhausted chaos retry budget), and a loud error beats a hang
    REPLY_TIMEOUT = 120.0

    def __init__(self, cfg: GridConfig, backend: EvalBackend,
                 max_messages: Optional[int] = None, n_workers: int = 8,
                 silence_at: Optional[float] = None,
                 silence_frac: float = 0.25):
        super().__init__(cfg, backend, max_messages=max_messages,
                         silence_at=silence_at, silence_frac=silence_frac)
        self.n_workers = max(1, int(n_workers))
        self.next_stamp = 0
        self._crash: Optional[BaseException] = None
        self._done = False

    # -- release machinery ---------------------------------------------------

    def _follow_lb(self, t: float, prio: int, h: int):
        """Strict lower bound on the follow-up event an in-flight
        (t, prio, h) can push when its reply lands."""
        if prio == PRIO_COMPLETE:
            # a report's follow-up is the host's next request at the SAME
            # virtual time (or nothing, if the run is done)
            return (t, PRIO_REQUEST, h)
        # a request's follow-up: completion at t + dt (dt ≥ 0.8·base/speed
        # — the latency-noise floor), vanish-retry at t + 4·dt, or no-work
        # retry at t + retry_after (≥ idle_retry); prio 0 / host -1 keep
        # the bound below any real event at that time
        dt_min = 0.8 * self.cfg.base_eval_time / self.speeds[h]
        return (t + min(dt_min, self.cfg.idle_retry), PRIO_COMPLETE, -1)

    def _stamped(self, msg: dict) -> dict:
        if self.max_messages is not None and \
                self.stats.messages >= self.max_messages:
            raise SimulatedCrash(
                f"simulated crash after {self.stats.messages} messages")
        self.stats.messages += 1
        msg["intake_seq"] = self.next_stamp
        self.next_stamp += 1
        return msg

    def _release(self, ev, jobs, pending) -> None:
        """Build the event's message(s), stamp them in canonical order,
        and enqueue for the host's worker.  Raises SimulatedCrash at the
        configured release count — exactly like the serial pool, AFTER
        any earlier message of the same event went out (a crash can split
        a register+request pair, and recovery must cope)."""
        t, prio, h = ev
        self.stats.sim_time = max(self.stats.sim_time, t)
        msgs, crash = [], None
        try:
            if prio == PRIO_REQUEST:
                if h not in self._registered:
                    msgs.append(self._stamped(
                        protocol.register(h, t, cs=self._next_cs(h))))
                    self._registered.add(h)
                msgs.append(self._stamped(
                    protocol.request_work(h, t, cs=self._next_cs(h))))
            else:
                inf = self._inflight[h]
                y = self._value(inf.search, inf.wu)
                del self._inflight[h]
                msgs.append(self._stamped(protocol.report_result(
                    h, inf.search, inf.wu, y, t, cs=self._next_cs(h))))
        except SimulatedCrash as e:
            crash = e
        if msgs:
            # partial=True: the reply is drained but not absorbed — the
            # run is crashing and recovery rebuilds the world from the
            # server, exactly as for a mid-pair SIGKILL
            pending[h] = self._follow_lb(t, prio, h)
            jobs[h % self.n_workers].put((ev, msgs, crash is not None))
        if crash is not None:
            raise crash

    def _absorb(self, result, pending) -> None:
        ev, rep, err, partial = result
        t, prio, h = ev
        pending.pop(h, None)
        if err is not None:
            if self._crash is None:
                self._crash = err
            self._done = True
            return
        if partial:
            return
        if prio == PRIO_REQUEST:
            if rep["kind"] == "work":
                self.stats.work_received += 1
                wu = int(rep["wu"])
                noise, loss, _ = _wu_draws(self.cfg.seed, h, wu)
                dt = self.cfg.base_eval_time / self.speeds[h] * noise
                if loss < self.cfg.failure_prob:
                    self.stats.failed += 1
                    heapq.heappush(self._events,
                                   (t + 4 * dt, PRIO_REQUEST, h))
                else:
                    self._inflight[h] = _InFlight(
                        int(rep["search"]), wu,
                        np.asarray(rep["point"], np.float64), t)
                    heapq.heappush(self._events, (t + dt, PRIO_COMPLETE, h))
            else:
                self.stats.no_work += 1
                if rep.get("done"):
                    self._stopped.add(h)
                else:
                    heapq.heappush(
                        self._events,
                        (t + float(rep["retry_after"]), PRIO_REQUEST, h))
        else:
            self.stats.results_reported += 1
            if rep.get("done"):
                self._done = True
            else:
                heapq.heappush(self._events, (t, PRIO_REQUEST, h))

    # -- the concurrent loop -------------------------------------------------

    def run(self, transport) -> PoolStats:   # noqa: D102 — see class doc
        cfg = self.cfg
        if not self._seeded:
            for h in range(cfg.n_hosts):
                heapq.heappush(self._events,
                               (float(self.online[h]), PRIO_REQUEST, h))
        jobs = [queue.Queue() for _ in range(self.n_workers)]
        results: "queue.Queue" = queue.Queue()

        def worker(wid: int) -> None:
            conn = transport.connect()
            try:
                while True:
                    job = jobs[wid].get()
                    if job is None:
                        return
                    ev, msgs, partial = job
                    try:
                        rep = None
                        for m in msgs:
                            t0 = time.perf_counter()
                            rep = conn.call(m)
                            if m.get("kind") == "request_work":
                                self.request_wall.append(
                                    time.perf_counter() - t0)
                        results.put((ev, rep, None, partial))
                    except BaseException as e:  # noqa: BLE001 — surfaced
                        results.put((ev, None, e, partial))
            finally:
                conn.close()

        threads = [threading.Thread(target=worker, args=(i,), daemon=True,
                                    name=f"sim-client-{i}")
                   for i in range(self.n_workers)]
        for th in threads:
            th.start()
        pending: Dict[int, tuple] = {}
        try:
            while True:
                # absorb whatever replies already landed (order-free)
                while True:
                    try:
                        self._absorb(results.get_nowait(), pending)
                    except queue.Empty:
                        break
                if self._done:
                    if not pending:
                        break
                    self._absorb(results.get(timeout=self.REPLY_TIMEOUT),
                                 pending)
                    continue
                ev = self._events[0] if self._events else None
                while ev is not None and (ev[2] in self._stopped
                                          or self._gone_silent(ev[0], ev[2])):
                    heapq.heappop(self._events)
                    ev = self._events[0] if self._events else None
                releasable = ev is not None and all(
                    ev < lb for lb in pending.values())
                if releasable:
                    self._release(heapq.heappop(self._events), jobs,
                                  pending)
                elif pending:
                    self._absorb(results.get(timeout=self.REPLY_TIMEOUT),
                                 pending)
                elif ev is None:
                    break
                else:        # unreachable: nothing pending blocks release
                    raise RuntimeError("release stalled with empty pending")
        except queue.Empty:
            raise RuntimeError(
                f"no reply within {self.REPLY_TIMEOUT:.0f}s with "
                f"{len(pending)} deliveries in flight — lost message?")
        finally:
            for q in jobs:
                q.put(None)
            for th in threads:
                th.join(timeout=10.0)
        if self._crash is not None:
            raise self._crash
        return self.stats


@dataclasses.dataclass
class ServerRunResult:
    server: WorkServer
    pool: PoolStats
    resumed: bool = False
    replayed: int = 0                 # log records re-handled at recovery
    recovered_done: bool = False      # nothing left to do after restore
    cache: Optional[dict] = None      # eval-cache counters, when enabled
    chaos: Optional[dict] = None      # injected-fault counters + plan doc
    intake: Optional[dict] = None     # sequenced-intake counters
    request_p99_ms: Optional[float] = None  # p99 request_work round-trip
    obs: Optional[dict] = None        # metrics-hub summary, when observed
    subscriber: Optional[dict] = None  # live stats-poller summary
    defense: Optional[dict] = None    # anomaly summary + recorded schedule
    retention: Optional[dict] = None  # §14 sink + store summary
    trace: Optional[dict] = None      # §14 tracer counters

    @property
    def engines(self):
        return self.server.engines


class ServerSubstrate:
    """Run one search (or a portfolio) end-to-end through the work server:
    the BOINC bridge built on the engine's generate/assimilate seam
    (DESIGN.md §1/§9), exercised by the simulated client fleet over a real
    transport.  With ``ckpt_dir`` set the run is crash-recoverable: pass
    ``resume=True`` to continue a killed run from its snapshot + replay
    log."""

    def __init__(self, specs, fleet: GridConfig, backend: EvalBackend, *,
                 transport: str = "loopback", policy: str = "fixed",
                 kill_margin: float = 0.5, probation_iterations: int = 2,
                 ckpt_dir: Optional[str] = None, snapshot_every: int = 500,
                 lease_timeout: Optional[float] = None,
                 max_messages: Optional[int] = None,
                 throttle_s: float = 0.0, warm: bool = True,
                 cache: Optional[EvalCache] = None,
                 concurrent: int = 0, chaos=None,
                 chaos_seed: Optional[int] = None,
                 obs: bool = False, stats_interval: float = 25.0,
                 stats_ring: int = 256,
                 subscribe: bool = False, defense: bool = False,
                 defense_schedule: Optional[dict] = None,
                 retain: bool = False, retain_dir: Optional[str] = None,
                 retain_backend: str = "jsonl",
                 retain_max_records: Optional[int] = 20_000,
                 trace_rate: float = 0.0, trace_seed: int = 0,
                 stall_window: int = 0, turnaround_drift: float = 0.0,
                 silence_at: Optional[float] = None,
                 silence_frac: float = 0.25):
        self.specs = [specs] if isinstance(specs, SearchSpec) else list(specs)
        self.fleet = fleet
        self.backend = backend
        # the memo layer (DESIGN.md §10): the client pool evaluates
        # through it, so re-leased points after a crash-restore — and any
        # byte-identical re-evaluation — are served instead of paid for.
        # Bit-exact-only serving keeps the restored trajectory identical.
        self.cache = cache
        self.eval_backend = (backend if cache is None
                             else CachingSubmitter(backend, cache))
        self.transport_name = transport
        self.policy = policy
        self.kill_margin = kill_margin
        self.probation_iterations = probation_iterations
        self.ckpt_dir = ckpt_dir
        self.snapshot_every = snapshot_every
        self.lease_timeout = (8.0 * fleet.base_eval_time
                              if lease_timeout is None else lease_timeout)
        self.max_messages = max_messages
        self.throttle_s = throttle_s
        # concurrency + chaos (DESIGN.md §12): ``concurrent`` > 0 runs the
        # fleet as that many racing client threads behind a sequenced
        # intake; ``chaos`` (preset name | FaultPlan doc | FaultPlan)
        # wraps the transport in the fault injector, ``chaos_seed``
        # re-seeds a named plan without redefining it
        self.concurrent = int(concurrent)
        if chaos is None or isinstance(chaos, FaultPlan):
            plan = chaos
        elif isinstance(chaos, str):
            plan = PRESETS[chaos]
        elif isinstance(chaos, dict):
            plan = FaultPlan.from_doc(chaos)
        else:
            raise TypeError(f"chaos must be None|str|dict|FaultPlan, "
                            f"got {type(chaos).__name__}")
        if plan is not None and chaos_seed is not None:
            plan = dataclasses.replace(plan, seed=int(chaos_seed))
        self.chaos_plan: Optional[FaultPlan] = plan
        # observability plane (DESIGN.md §13): ``obs`` attaches a
        # MetricsHub sampled every ``stats_interval`` virtual seconds at
        # applied-message boundaries; ``subscribe`` runs a live
        # background poller over the raw transport; ``defense`` arms the
        # anomaly detectors (``defense_schedule`` replays a recorded run
        # instead).  Any of them implies the hub.
        self.subscribe = bool(subscribe)
        self.defense = bool(defense)
        self.defense_schedule = defense_schedule
        # §14 post-mortem plane: ``retain`` spills samples into a
        # SnapshotStore under retain_dir (default: the ckpt_dir),
        # ``trace_rate`` > 0 hooks a WorkUnitTracer onto the lease paths,
        # and the window-defense knobs arm the §14 detectors (implying a
        # live defense).  All of it implies the hub.
        self.retain_dir = retain_dir
        self.retain = bool(retain or retain_dir is not None)
        self.retain_backend = str(retain_backend)
        self.retain_max_records = retain_max_records
        self.trace_rate = float(trace_rate)
        self.trace_seed = int(trace_seed)
        self.stall_window = int(stall_window)
        self.turnaround_drift = float(turnaround_drift)
        if self.stall_window or self.turnaround_drift:
            self.defense = True
        self.obs = bool(obs or subscribe or self.defense
                        or defense_schedule is not None
                        or self.retain or self.trace_rate > 0)
        self.stats_interval = float(stats_interval)
        self.stats_ring = int(stats_ring)
        self.silence_at = silence_at
        self.silence_frac = float(silence_frac)
        if warm:
            # in-flight unknowns are bounded by the fleet (≤ 1 lease per
            # host), so warming the ladder to n_hosts guarantees zero
            # compiles once the run starts
            self.backend.warm(len(np.asarray(self.specs[0].x0)),
                              fleet.n_hosts)

    def _build_server(self) -> WorkServer:
        return WorkServer(self.specs, policy=self.policy,
                          kill_margin=self.kill_margin,
                          probation_iterations=self.probation_iterations,
                          lease_timeout=self.lease_timeout,
                          idle_retry=self.fleet.idle_retry)

    def run(self, resume: bool = False) -> ServerRunResult:
        replayed = 0
        mgr = None
        if resume:
            if self.ckpt_dir is None:
                raise ValueError("resume=True needs a ckpt_dir")
            server, mgr, replayed = CheckpointManager.recover(
                self.ckpt_dir, self._build_server,
                snapshot_every=self.snapshot_every)
        else:
            server = self._build_server()
            if self.ckpt_dir is not None:
                mgr = CheckpointManager(self.ckpt_dir,
                                        snapshot_every=self.snapshot_every)
        recovered_done = server.done
        if self.cache is not None:
            server.attach_cache(self.cache)       # status counters (§10)
            if mgr is not None:
                mgr.attach_store(self.cache.store)
        # obs attaches AFTER recovery: the replayed prefix re-applies with
        # no hub (no samples), and the hub owns no replayable state — §13's
        # recovery-compatibility argument
        hub = None
        fleet_defense = None
        tracer = None
        store = None
        sink = None
        if self.obs:
            from repro.obs import (FleetDefense, MetricsHub, RetentionSink,
                                   WorkUnitTracer, obs_store_path,
                                   open_snapshot_store)
            hub = MetricsHub(interval=self.stats_interval,
                             ring=self.stats_ring)
            server.attach_hub(hub)
            if self.trace_rate > 0:
                tracer = WorkUnitTracer(sample_rate=self.trace_rate,
                                        seed=self.trace_seed)
                server.attach_tracer(tracer)
            if self.defense_schedule is not None:
                # replay mode: recorded verdicts (incl. §14 stall kills)
                # re-applied at recorded seqs; the server is the director
                fleet_defense = FleetDefense.replay(server.registry, hub,
                                                    self.defense_schedule,
                                                    director=server)
            elif self.defense:
                fleet_defense = FleetDefense(
                    server.registry, hub, director=server,
                    stall_window=self.stall_window,
                    turnaround_drift=self.turnaround_drift)
            if self.retain:
                rdir = self.retain_dir or self.ckpt_dir
                if rdir is None:
                    raise ValueError("retain=True needs retain_dir or "
                                     "ckpt_dir")
                store = open_snapshot_store(
                    obs_store_path(rdir, self.retain_backend),
                    max_records=self.retain_max_records)
                sink = RetentionSink(hub, store, tracer=tracer,
                                     defense=fleet_defense)
                server.attach_retention(store)
                if mgr is not None:
                    # flushed at every snapshot, closed with the manager —
                    # the same §10 composition as the eval-cache store
                    mgr.attach_store(store)
        if mgr is None:
            handler = server.handle
        else:
            def handler(msg, _mgr=mgr, _srv=server):
                rep = _srv.handle(msg)
                _mgr.record(msg, _srv)
                if self.throttle_s:
                    time.sleep(self.throttle_s)
                return rep
        intake = None
        if self.concurrent:
            # the sequenced intake is what turns N racing connections into
            # the canonical applied order; a TCP handler that PARKS must
            # run off the loop thread (blocking_handler)
            intake = SequencedIntake(handler)
            handler = intake.submit
            server.attach_intake(intake)  # queue-depth in status + hub
        elif self.subscribe:
            # a live subscriber shares the handler with the serial pool:
            # serialize them (the intake's lock does this in concurrent
            # mode) so an unstamped poll can never interleave inside an
            # applied message's handle+record pair
            lock = threading.Lock()

            def handler(msg, _lk=lock, _inner=handler):
                with _lk:
                    return _inner(msg)
        tkwargs = {}
        if self.transport_name == "tcp" and self.concurrent:
            tkwargs["blocking_handler"] = True
        transport = make_transport(self.transport_name, **tkwargs)
        # the monitoring side-channel connects to the RAW transport: chaos
        # draws are keyed on (host, cs), which unstamped monitoring polls
        # do not carry — and perturbing the fault schedule with extra
        # traffic would defeat the chaos-parity gates
        raw_transport = transport
        if self.chaos_plan is not None:
            transport = ChaosTransport(transport, self.chaos_plan)
        transport.start(handler)
        subscriber = None
        if self.subscribe:
            from repro.obs import BackgroundSubscriber
            subscriber = BackgroundSubscriber(raw_transport.connect).start()
        if self.concurrent:
            pool = ConcurrentClientPool(self.fleet, self.eval_backend,
                                        max_messages=self.max_messages,
                                        n_workers=self.concurrent,
                                        silence_at=self.silence_at,
                                        silence_frac=self.silence_frac)
        else:
            pool = SimClientPool(self.fleet, self.eval_backend,
                                 max_messages=self.max_messages,
                                 silence_at=self.silence_at,
                                 silence_frac=self.silence_frac)
        if resume:
            pool.resume_from(server.world_view())
        conn = None
        cache_status = None
        retention_doc = None
        try:
            if self.concurrent:
                pool.run(transport)       # workers open their own conns
            else:
                conn = transport.connect()
                pool.run(conn)
            # read the counters BEFORE the finally closes the store — a
            # sqlite-backed cache cannot answer len() once closed
            if self.cache is not None:
                cache_status = self.cache.status()
        finally:
            if subscriber is not None:
                subscriber.stop()
            if conn is not None:
                conn.close()
            transport.stop()
            if sink is not None:
                sink.drain_remaining()    # spans settled after last sample
                # summarized while the store can still answer (sqlite
                # cannot be queried once the manager closes it)
                retention_doc = sink.summary()
            if mgr is not None:
                mgr.close()               # closes attached cache stores too
            elif self.cache is not None:
                self.cache.store.flush()
            if store is not None and mgr is None:
                store.close()
        p99 = None
        if pool.request_wall:
            p99 = float(np.percentile(np.asarray(pool.request_wall),
                                      99.0) * 1000.0)
        obs_doc = None
        if hub is not None:
            latest = hub.latest()
            obs_doc = {"snapshots": hub.seq, "interval": hub.interval,
                       "ring": hub.ring,
                       "last_registry": None if latest is None
                       else latest["groups"].get("registry")}
        defense_doc = None
        if fleet_defense is not None:
            defense_doc = dict(fleet_defense.summary())
            defense_doc["schedule"] = fleet_defense.schedule_doc()
        return ServerRunResult(server=server, pool=pool.stats,
                               resumed=resume, replayed=replayed,
                               recovered_done=recovered_done,
                               cache=cache_status,
                               chaos=None if self.chaos_plan is None else {
                                   "plan": self.chaos_plan.to_doc(),
                                   **dataclasses.asdict(transport.stats)},
                               intake=None if intake is None else {
                                   "next_seq": intake.next_seq,
                                   "parked": intake.parked,
                                   "out_of_band": intake.out_of_band},
                               request_p99_ms=p99, obs=obs_doc,
                               subscriber=None if subscriber is None
                               else subscriber.summary(),
                               defense=defense_doc,
                               retention=retention_doc,
                               trace=None if tracer is None
                               else tracer.summary())


# -- the seeded smoke problem + CLI (dryrun's kill/restore subprocess) --------

def smoke_problem(n_stars: int = 400, n_hosts: int = 192, m: int = 24,
                  iterations: int = 4, engine_seed: int = 7,
                  grid_seed: int = 9, failure: float = 0.05,
                  malicious: float = 0.02, quorum: int = 2):
    """The fixed seeded workload every kill/restore gate compares across
    runs: (spec, fleet, f_batch).  Parameters ARE the identity — the
    dryrun harness passes the same values to every subprocess."""
    from repro.core.anm import AnmConfig
    from repro.data import sdss

    stripe = sdss.make_stripe("server_smoke", n_stars=n_stars, seed=23)
    f_batch, _ = sdss.make_fitness(stripe)
    rng = np.random.default_rng(3)
    x0 = np.clip(stripe.truth + rng.normal(0, 0.2, 8).astype(np.float32),
                 sdss.LO, sdss.HI)
    fleet = GridConfig(n_hosts=n_hosts, failure_prob=failure,
                       malicious_prob=malicious, seed=grid_seed)
    spec = SearchSpec(
        name="server_smoke", x0=np.asarray(x0, np.float64),
        lo=np.asarray(sdss.LO, np.float64),
        hi=np.asarray(sdss.HI, np.float64),
        step=np.asarray(sdss.DEFAULT_STEP, np.float64),
        anm=AnmConfig(m_regression=m, m_line_search=m,
                      max_iterations=iterations),
        grid=fleet, engine_seed=engine_seed, validation_quorum=quorum)
    return spec, fleet, f_batch


def lm_problem(arch: str = "rwkv6-7b", k: int = 6, n_hosts: int = 48,
               m: int = 12, iterations: int = 2, engine_seed: int = 7,
               grid_seed: int = 9, failure: float = 0.05,
               malicious: float = 0.02, quorum: int = 2,
               workload_seed: int = 3):
    """The LM-loss counterpart of ``smoke_problem``: the search space is
    the k-dim subspace-coefficient box of an ``LmWorkload`` over one of
    the smoke model configs, and every fitness evaluation is a real
    forward + loss (``LmLossEvalBackend``).  Returns (spec, fleet,
    workload); the caller picks the evaluation mesh when it builds the
    backend.  Parameters are the workload identity, exactly as for the
    SDSS smoke — same values in two processes ⇒ bit-identical search."""
    from repro.core.anm import AnmConfig
    from repro.core.substrates.lm_loss import make_lm_workload

    wl = make_lm_workload(arch, k=k, seed=workload_seed)
    fleet = GridConfig(n_hosts=n_hosts, failure_prob=failure,
                       malicious_prob=malicious, seed=grid_seed)
    spec = SearchSpec(
        name=f"lm_{arch}", x0=wl.x0, lo=wl.lo, hi=wl.hi, step=wl.step,
        anm=AnmConfig(m_regression=m, m_line_search=m,
                      max_iterations=iterations),
        grid=fleet, engine_seed=engine_seed, validation_quorum=quorum)
    return spec, fleet, wl


def result_doc(res: ServerRunResult) -> dict:
    """JSON-able run outcome: the full committed trajectory + stats, the
    exact objects the kill/restore gates compare bit-for-bit (float64
    round-trips exactly through JSON)."""
    eng = res.server.engines[0]
    return {
        "resumed": res.resumed, "replayed": res.replayed,
        "recovered_done": res.recovered_done,
        "iteration": eng.iteration,
        "best_fitness": eng.best_fitness,
        "history": {
            "centers": [r.center.tolist() for r in eng.history],
            "best_fitness": [r.best_fitness for r in eng.history],
            "best_alpha": [r.best_alpha for r in eng.history],
            "evals_used": [r.evals_used for r in eng.history],
        },
        "engine_stats": dataclasses.asdict(eng.stats),
        "counters": dataclasses.asdict(res.server.counters),
        "registry": res.server.registry.summary(),
        "pool": dataclasses.asdict(res.pool),
        "cache": res.cache,
        "chaos": res.chaos,
        "intake": res.intake,
        "request_p99_ms": res.request_p99_ms,
        "obs": res.obs,
        "subscriber": res.subscriber,
        "defense": res.defense,
        "retention": res.retention,
        "trace": res.trace,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser(
        description="seeded single-search server smoke (the dryrun "
                    "kill/restore subprocess)")
    ap.add_argument("--transport", default="loopback",
                    choices=["loopback", "tcp"])
    ap.add_argument("--backend", default="in_process",
                    choices=["in_process", "pod_mesh"])
    ap.add_argument("--problem", default="sdss", choices=["sdss", "lm"],
                    help="sdss: the 8-param stream fit; lm: the subspace-"
                         "Newton LM-loss workload (--arch/--k)")
    ap.add_argument("--arch", default="rwkv6-7b",
                    help="smoke model config for --problem lm")
    ap.add_argument("--k", type=int, default=6,
                    help="subspace dimension for --problem lm")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None, help="result JSON path")
    ap.add_argument("--n-hosts", type=int, default=192)
    ap.add_argument("--n-stars", type=int, default=400)
    ap.add_argument("--m", type=int, default=24)
    ap.add_argument("--iterations", type=int, default=4)
    ap.add_argument("--engine-seed", type=int, default=7)
    ap.add_argument("--grid-seed", type=int, default=9)
    ap.add_argument("--failure", type=float, default=0.05)
    ap.add_argument("--malicious", type=float, default=0.02)
    ap.add_argument("--snapshot-every", type=int, default=250)
    ap.add_argument("--cache", action="store_true",
                    help="evaluate through a persistent eval cache "
                         "(JSONL store in --ckpt-dir, in-memory without "
                         "one); a --resume run warms from the survivor")
    ap.add_argument("--throttle-s", type=float, default=0.0,
                    help="wall-clock sleep per handled message (widens the "
                         "SIGKILL window; virtual time is unaffected, so "
                         "the trajectory is identical)")
    ap.add_argument("--concurrent", type=int, default=0,
                    help="run the fleet as N racing client threads behind "
                         "the sequenced intake (0: serial single-conn)")
    ap.add_argument("--chaos", default=None,
                    choices=sorted(PRESETS),
                    help="inject faults per this preset FaultPlan")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="re-seed the chosen --chaos plan")
    ap.add_argument("--obs", action="store_true",
                    help="attach the metrics hub (DESIGN.md §13): sampled "
                         "stats snapshots + the subscribe_stats wire "
                         "extension; the trajectory is unchanged")
    ap.add_argument("--stats-interval", type=float, default=25.0,
                    help="virtual seconds between hub snapshots")
    ap.add_argument("--stats-ring", type=int, default=256,
                    help="hub snapshot ring size (construction-path knob)")
    ap.add_argument("--retain", action="store_true",
                    help="spill snapshots/spans/anomalies into the §14 "
                         "retention store under --retain-dir or --ckpt-dir "
                         "(implies --obs)")
    ap.add_argument("--retain-dir", default=None,
                    help="retention store directory (default: --ckpt-dir)")
    ap.add_argument("--retain-backend", default="jsonl",
                    choices=["jsonl", "sqlite"])
    ap.add_argument("--trace-rate", type=float, default=0.0,
                    help="fraction of workunits lifecycle-traced, keyed "
                         "deterministically on workunit id (implies --obs)")
    ap.add_argument("--stall-window", type=int, default=0,
                    help="kill a search with no committed improvement for "
                         "this many snapshots (implies --defense)")
    ap.add_argument("--turnaround-drift", type=float, default=0.0,
                    help="page a state cohort whose fast turnaround EWMA "
                         "drifts this fraction above the slow baseline "
                         "(implies --defense)")
    ap.add_argument("--subscribe", action="store_true",
                    help="run a live background subscribe_stats poller "
                         "over the transport (implies --obs)")
    ap.add_argument("--silence-at", type=float, default=None,
                    help="inject fleet churn: the lowest --silence-frac "
                         "of host ids go silent at this virtual time")
    ap.add_argument("--silence-frac", type=float, default=0.25)
    ap.add_argument("--defense", action="store_true",
                    help="arm the anomaly detectors: suspect cohorts are "
                         "quarantined out of the reliable set, and the "
                         "verdict schedule is recorded (implies --obs)")
    ap.add_argument("--defense-out", default=None,
                    help="write the recorded anomaly schedule JSON here")
    ap.add_argument("--defense-replay", default=None,
                    help="replay a recorded anomaly schedule instead of "
                         "detecting (the solo-reproducibility twin)")
    args = ap.parse_args(argv)

    if args.problem == "lm":
        spec, fleet, wl = lm_problem(
            arch=args.arch, k=args.k, n_hosts=args.n_hosts, m=args.m,
            iterations=args.iterations, engine_seed=args.engine_seed,
            grid_seed=args.grid_seed, failure=args.failure,
            malicious=args.malicious)
        from repro.core.substrates.lm_loss import LmLossEvalBackend
        if args.backend == "pod_mesh":
            from repro.launch.mesh import make_production_mesh
            backend = LmLossEvalBackend(wl, mesh=make_production_mesh())
        else:
            backend = LmLossEvalBackend(wl)
    else:
        spec, fleet, f_batch = smoke_problem(
            n_stars=args.n_stars, n_hosts=args.n_hosts, m=args.m,
            iterations=args.iterations, engine_seed=args.engine_seed,
            grid_seed=args.grid_seed, failure=args.failure,
            malicious=args.malicious)
        if args.backend == "pod_mesh":
            from repro.core.substrates.pod_mesh import PodMeshEvalBackend
            backend = PodMeshEvalBackend(f_batch)
        else:
            from repro.core.substrates.eval_backend import InProcessEvalBackend
            backend = InProcessEvalBackend(f_batch)
    cache = None
    if args.cache:
        from repro.core.substrates.eval_cache import JsonlCacheStore
        from repro.server.checkpoint import eval_cache_path
        # the fingerprint names the OBJECTIVE identity (stripe + fleet
        # shape — or the LM workload), so every process over the same
        # smoke problem — baseline, killed, resumed — shares keys, and a
        # different problem never collides
        if args.problem == "lm":
            fp = (f"lm_subspace/{args.arch}/{args.k}/{args.n_hosts}/"
                  f"{args.m}/{args.iterations}")
        else:
            fp = (f"server_smoke/{args.n_stars}/{args.n_hosts}/{args.m}/"
                  f"{args.iterations}")
        store = (JsonlCacheStore(eval_cache_path(args.ckpt_dir))
                 if args.ckpt_dir else None)
        cache = EvalCache(store, fingerprint=fp)
    defense_schedule = None
    if args.defense_replay:
        with open(args.defense_replay) as f:
            defense_schedule = json.load(f)
    sub = ServerSubstrate(spec, fleet, backend, transport=args.transport,
                          ckpt_dir=args.ckpt_dir,
                          snapshot_every=args.snapshot_every,
                          throttle_s=args.throttle_s, cache=cache,
                          concurrent=args.concurrent, chaos=args.chaos,
                          chaos_seed=args.chaos_seed,
                          obs=args.obs, stats_interval=args.stats_interval,
                          stats_ring=args.stats_ring,
                          subscribe=args.subscribe, defense=args.defense,
                          defense_schedule=defense_schedule,
                          retain=args.retain, retain_dir=args.retain_dir,
                          retain_backend=args.retain_backend,
                          trace_rate=args.trace_rate,
                          stall_window=args.stall_window,
                          turnaround_drift=args.turnaround_drift,
                          silence_at=args.silence_at,
                          silence_frac=args.silence_frac)
    res = sub.run(resume=args.resume)
    doc = result_doc(res)
    if args.defense_out and res.defense is not None:
        os.makedirs(os.path.dirname(os.path.abspath(args.defense_out)),
                    exist_ok=True)
        with open(args.defense_out, "w") as f:
            json.dump(res.defense["schedule"], f, indent=2)
    doc["transport"] = args.transport
    doc["backend"] = args.backend
    doc["problem"] = args.problem
    doc["concurrent"] = args.concurrent
    if args.problem == "lm":
        doc["arch"] = args.arch
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                    exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
    cache_note = ""
    if res.cache is not None:
        cache_note = (f" cache_hits={res.cache['hits']}"
                      f" cache_store={res.cache['store_size']}")
    if res.chaos is not None:
        cache_note += (f" chaos={res.chaos['plan']['name']}"
                       f" retries={res.chaos['retries']}")
    if args.concurrent:
        cache_note += f" workers={args.concurrent}"
    if res.obs is not None:
        cache_note += f" obs_snapshots={res.obs['snapshots']}"
    if res.subscriber is not None:
        cache_note += (f" subscribed={res.subscriber['snapshots']}"
                       f" stamped_ok={res.subscriber['stamped_ok']}")
    if res.defense is not None:
        cache_note += (f" defense={res.defense['mode']}"
                       f" anomalies={res.defense['events']}"
                       f" quarantined={res.defense['quarantined_now']}")
    print(f"[server.sim] transport={args.transport} backend={args.backend} "
          f"resumed={res.resumed} replayed={res.replayed} "
          f"iters={doc['iteration']} best={doc['best_fitness']:.6f} "
          f"messages={doc['pool']['messages']}{cache_note}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
