"""Crash-recoverable server state: append-only replay log + snapshots.

Recovery model (DESIGN.md §9).  The work server is a DETERMINISTIC message
handler: given a state and a request message, ``handle`` computes the next
state (every random draw lives inside the engine rng, which is part of the
state).  So durability needs exactly two artifacts:

  * an **append-only replay log** — one JSONL record per handled message,
    written (and flushed) right after the in-memory state change;
  * periodic **snapshots** — the full serialized server state every
    ``snapshot_every`` messages, written atomically (tmp + rename).

``recover`` loads the newest intact snapshot and re-handles every logged
message after it, which reconstructs the exact in-memory state the server
held at the last durable log record.  A SIGKILL can lose only a SUFFIX of
the log (appends are sequential), so the recovered state is always a
valid PREFIX state of the run — and because the simulated client world is
itself a deterministic function of the server's lease table and registry
(see ``repro/server/sim.py``), continuing from a prefix state replays the
exact same future: the restored run commits bit-identical iterates to an
uninterrupted one.  A half-written final line (the append the kill
interrupted) is detected and ignored, not fatal.

Snapshots are JSON, not msgpack, on purpose: the engine rng state carries
128-bit PCG64 integers that msgpack cannot represent, while Python's JSON
round-trips arbitrary ints and exact float64 (repr shortest-round-trip).
Numpy arrays are tagged (``{"__nd__": dtype, shape, data}``) by
``to_jsonable``/``from_jsonable`` so dtypes survive exactly.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Iterator, Optional, Tuple

import numpy as np

SNAP_PREFIX = "snapshot_"
LOG_NAME = "replay.jsonl"
#: canonical eval-cache file inside a checkpoint dir (DESIGN.md §10): the
#: warm cross-search evaluation cache rides the same crash-recovery
#: lifecycle as the replay log — append-only, flushed at every snapshot
#: (see ``CheckpointManager.attach_store``), torn-tail tolerant on load
CACHE_NAME = "eval_cache.jsonl"


def eval_cache_path(ckpt_dir: str) -> str:
    """Where a crash-recoverable run persists its eval cache — one
    convention, so a ``--resume`` process finds the warm cache without
    any extra plumbing."""
    return os.path.join(ckpt_dir, CACHE_NAME)


def to_jsonable(obj):
    """Plain-python view of a state tree: numpy arrays become tagged dicts
    (dtype + shape preserved), numpy scalars become python scalars."""
    if isinstance(obj, np.ndarray):
        return {"__nd__": str(obj.dtype), "shape": list(obj.shape),
                "data": [v.item() for v in obj.ravel()]}
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, dict):
        return {k: to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_jsonable(v) for v in obj]
    return obj


def from_jsonable(obj):
    if isinstance(obj, dict):
        if "__nd__" in obj:
            return np.array(obj["data"],
                            np.dtype(obj["__nd__"])).reshape(obj["shape"])
        return {k: from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [from_jsonable(v) for v in obj]
    return obj


def save_snapshot(ckpt_dir: str, seq: int, state: dict,
                  fingerprint: str, keep: int = 2) -> str:
    """Atomic snapshot write: a crash mid-write leaves the previous
    snapshot intact (tmp file + ``os.replace``)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"{SNAP_PREFIX}{seq:010d}.json"
    tmp = os.path.join(ckpt_dir, f".tmp_{name}_{os.getpid()}")
    with open(tmp, "w") as f:
        json.dump({"seq": seq, "fingerprint": fingerprint,
                   "state": to_jsonable(state)}, f,
                  separators=(",", ":"))
    path = os.path.join(ckpt_dir, name)
    os.replace(tmp, path)
    snaps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith(SNAP_PREFIX))
    for old in snaps[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, old))
        except OSError:
            pass
    return path


def latest_snapshot(ckpt_dir: str) -> Optional[Tuple[int, dict, str]]:
    """Newest INTACT snapshot as (seq, state, fingerprint) — a snapshot
    that fails to parse (torn write of a pre-replace tmp never surfaces
    here, but a corrupt file might) falls back to the one before it."""
    try:
        snaps = sorted((d for d in os.listdir(ckpt_dir)
                        if d.startswith(SNAP_PREFIX)), reverse=True)
    except FileNotFoundError:
        return None
    for name in snaps:
        try:
            with open(os.path.join(ckpt_dir, name)) as f:
                doc = json.load(f)
            return int(doc["seq"]), from_jsonable(doc["state"]), \
                doc.get("fingerprint", "")
        except (OSError, ValueError, KeyError):
            continue
    return None


class ReplayLog:
    """Append-only JSONL of handled messages.  ``replay`` tolerates a
    truncated final line — the telltale of a kill mid-append.

    Appends are flushed every ``flush_every`` records (and on close):
    writes are sequential either way, so a kill still loses only a
    SUFFIX — recovery correctness never depends on the flush cadence,
    only the worst-case replay distance does — while per-message flushes
    would dominate the whole server loop."""

    def __init__(self, path: str, flush_every: int = 32):
        self.path = path
        self.flush_every = max(int(flush_every), 1)
        self._since_flush = 0
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a")

    def append(self, record: dict) -> None:
        self._f.write(json.dumps(to_jsonable(record),
                                 separators=(",", ":")) + "\n")
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        self._f.flush()
        self._since_flush = 0

    def close(self) -> None:
        self._f.close()

    @staticmethod
    def repair(path: str) -> int:
        """Truncate a SIGKILL-torn trailing partial line (no final
        newline) so a resumed run's appends start on a fresh line —
        without this, the first post-resume record would concatenate
        onto the torn fragment into one corrupt merged line, and a
        SECOND crash's recovery would stop replaying there, silently
        discarding every durable record after the first crash.  Returns
        the number of bytes dropped."""
        try:
            with open(path, "rb+") as f:
                data = f.read()
                if not data or data.endswith(b"\n"):
                    return 0
                keep = data.rfind(b"\n") + 1
                f.truncate(keep)
                return len(data) - keep
        except FileNotFoundError:
            return 0

    @staticmethod
    def replay(path: str) -> Iterator[dict]:
        try:
            f = open(path)
        except FileNotFoundError:
            return
        with f:
            for line in f:
                if not line.endswith("\n"):
                    return            # torn tail: the kill's half-append
                try:
                    yield from_jsonable(json.loads(line))
                except ValueError:
                    return            # corrupt tail record: stop, don't die


class CheckpointManager:
    """Wires a server's ``handle`` loop to the log + snapshot cadence.

    Usage::

        mgr = CheckpointManager(ckpt_dir, snapshot_every=500)
        ...
        reply = server.handle(msg)
        mgr.record(msg, server)        # log (flushed) + periodic snapshot

    Read-only message kinds (``status``) are neither logged nor counted —
    replaying them would be harmless but pointlessly bloats the log.
    """

    READ_ONLY = frozenset({"status", "subscribe_stats"})

    def __init__(self, ckpt_dir: str, snapshot_every: int = 1000,
                 keep: int = 2):
        self.ckpt_dir = ckpt_dir
        self.snapshot_every = max(int(snapshot_every), 1)
        self.keep = keep
        self.seq = 0
        self.snapshots_written = 0
        os.makedirs(ckpt_dir, exist_ok=True)
        self._log = ReplayLog(os.path.join(ckpt_dir, LOG_NAME))
        self._stores: list = []

    def attach_store(self, store) -> None:
        """Durability composition for auxiliary append-only stores (the
        eval cache): flushed alongside the replay log at every snapshot
        and closed with the manager.  The cache never needs to be AHEAD
        of the log — a lost suffix only costs re-evaluations, never
        correctness (bit-exact serving is value-neutral) — but flushing
        on the snapshot cadence guarantees a restored run warms from at
        least the snapshot's cache."""
        self._stores.append(store)

    def record(self, msg: dict, server) -> None:
        if msg.get("kind") in self.READ_ONLY:
            return
        if not getattr(server, "last_applied", True):
            # the dedup layer absorbed this delivery (duplicate/stale cs):
            # it mutated nothing, and logging it would make the replay log
            # depend on the fault schedule — the log must stay exactly the
            # canonical applied-message sequence (DESIGN.md §12)
            return
        self.seq += 1
        self._log.append({"seq": self.seq, "msg": msg})
        if self.seq % self.snapshot_every == 0:
            self.snapshot(server)

    def snapshot(self, server) -> None:
        self._log.flush()             # the snapshot must never be AHEAD
        for store in self._stores:
            store.flush()
        save_snapshot(self.ckpt_dir, self.seq, server.state_dict(),
                      server.fingerprint(), keep=self.keep)
        self.snapshots_written += 1

    def close(self) -> None:
        self._log.close()
        for store in self._stores:
            store.close()

    @classmethod
    def recover(cls, ckpt_dir: str, build_server: Callable[[], "object"],
                snapshot_every: int = 1000,
                keep: int = 2) -> Tuple["object", "CheckpointManager", int]:
        """Rebuild the server at the last durable log record: newest intact
        snapshot + replay of the logged suffix.  Returns
        ``(server, manager, replayed)`` with the manager positioned to
        continue appending (seq picks up where the log left off)."""
        server = build_server()
        snap = latest_snapshot(ckpt_dir)
        seq0 = 0
        if snap is not None:
            seq0, state, fp = snap
            if fp and fp != server.fingerprint():
                raise ValueError(
                    "checkpoint fingerprint mismatch: the snapshot was "
                    "taken for a different server spec")
            server.load_state(state)
        replayed = 0
        last_seq = seq0
        log_path = os.path.join(ckpt_dir, LOG_NAME)
        ReplayLog.repair(log_path)    # drop the kill's torn half-line
        for rec in ReplayLog.replay(log_path):
            seq = int(rec["seq"])
            if seq <= seq0:
                continue
            server.handle(rec["msg"])
            replayed += 1
            last_seq = seq
        mgr = cls(ckpt_dir, snapshot_every=snapshot_every, keep=keep)
        mgr.seq = last_seq
        return server, mgr, replayed
