"""Fault-injection transport layer: seeded chaos for the work service.

Real volunteer fleets sit behind lossy networks: requests vanish, replies
vanish, frames are duplicated by retransmitting middleboxes, writes tear
mid-frame, connections reset, and independent clients' messages interleave
arbitrarily.  The service's robustness claim (DESIGN.md §12) is that NONE
of that perturbs the committed trajectory — and a claim like that needs
the faults injected on purpose, reproducibly, not merely tolerated by
accident.

``FaultPlan`` is the reproducible schedule: every fault decision is a
counter-based draw keyed on ``(plan seed, host, client_seq, attempt)`` —
the same keying discipline as the client pool's per-workunit draws — so a
chaos run is determined by its plan, not by wall-clock races.  The plan
serializes (``to_doc``/``from_doc``) and is recorded into every chaos
artifact, which is what makes a failing schedule replayable.

``ChaosConnection`` wraps a real client connection (loopback or TCP) and
injects at the client edge of the wire, where every fault class a network
can produce is expressible:

  * **drop request** — the frame is never sent; the client retries;
  * **drop reply**   — the frame is sent and handled, the reply is lost;
    the retry re-sends the SAME ``client_seq``, exercising server-side
    idempotency (a retried report must not double-count a quorum vote);
  * **duplicate**    — the frame is sent twice back-to-back: two copies
    reach the handler, the second must be suppressed;
  * **delay**        — the send is held briefly; with concurrent clients
    this REORDERS arrival across connections, which the server's
    sequenced intake must absorb;
  * **torn write**   — a truncated prefix of the frame is written and the
    connection is torn down (a partial frame desyncs a byte stream, so
    tear-down is part of the fault, exactly like a real broken write);
  * **reset**        — the connection is closed before the send; the
    retry reconnects.

Retries use exponential backoff with seeded jitter (paper-adjacent BOINC
client behavior); because every injection is client-side, the retry loop
never needs a wall-clock timeout — it KNOWS what it broke — so chaos runs
stay fast while the server sees exactly the byte stream a faulty network
would have delivered.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.server.protocol import ProtocolError, encode_message, frame

#: domain salt for the chaos draw stream — distinct from the client pool's
#: ``_ONLINE_SALT``/``_WU_SALT`` so plans can never collide with workload
#: randomness
_CHAOS_SALT = 0xC4A05


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, serializable fault schedule.  Probabilities are per
    delivery attempt; all draws are keyed on (seed, host, client_seq,
    attempt) so the plan fully determines the fault sequence."""
    name: str = "custom"
    seed: int = 0
    drop_request: float = 0.0         # request frame vanishes before send
    drop_reply: float = 0.0           # handled, but the reply is lost
    duplicate: float = 0.0            # request frame delivered twice
    delay: float = 0.0                # send held briefly (reorders arrival)
    delay_ms: float = 2.0             # max hold per delayed send
    torn_write: float = 0.0           # truncated frame + connection teardown
    reset: float = 0.0                # connection reset before the send
    max_attempts: int = 64            # retry budget per logical message
    backoff_base_ms: float = 0.05     # exponential backoff base (wall ms)
    backoff_cap_ms: float = 2.0       # backoff ceiling

    def to_doc(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: dict) -> "FaultPlan":
        return cls(**doc)

    def draws(self, host: int, cs: int, attempt: int) -> Dict[str, float]:
        """The per-attempt fault coin flips — counter-based, so a plan's
        decision for (host, message, attempt) is independent of every
        other message and of thread timing."""
        rng = np.random.default_rng(np.random.SeedSequence(
            (_CHAOS_SALT, int(self.seed), int(host), int(cs), int(attempt))))
        u = rng.random(6)
        return {
            "reset": u[0] < self.reset,
            "drop_request": u[1] < self.drop_request,
            "duplicate": u[2] < self.duplicate,
            "delay": u[3] < self.delay,
            "torn_write": u[4] < self.torn_write,
            "drop_reply": u[5] < self.drop_reply,
            "delay_frac": float(rng.random()),     # fraction of delay_ms
            "tear_frac": float(rng.random()),      # fraction of frame kept
            "jitter": float(rng.random()),         # backoff jitter in [0,1)
        }

    def backoff_s(self, attempt: int, jitter: float) -> float:
        """Full-jitter exponential backoff (attempt 0 pays nothing)."""
        if attempt <= 0:
            return 0.0
        cap = min(self.backoff_base_ms * (2.0 ** (attempt - 1)),
                  self.backoff_cap_ms)
        return cap * jitter / 1000.0


#: the named plans the parity gates cycle through — three distinct fault
#: mixes (loss+duplication, reordering delay, resets+torn writes) plus the
#: ledger's degraded-mode operating point (10% drop / 5% duplication)
PRESETS: Dict[str, FaultPlan] = {
    "drop_dup": FaultPlan(name="drop_dup", seed=101, drop_request=0.08,
                          drop_reply=0.06, duplicate=0.10),
    "reorder_delay": FaultPlan(name="reorder_delay", seed=202, delay=0.25,
                               delay_ms=2.0, duplicate=0.05),
    "reset_torn": FaultPlan(name="reset_torn", seed=303, reset=0.05,
                            torn_write=0.05, drop_reply=0.04),
    "degraded": FaultPlan(name="degraded", seed=404, drop_request=0.10,
                          duplicate=0.05),
}


@dataclasses.dataclass
class ChaosStats:
    sent: int = 0                     # frames actually written to the wire
    delivered: int = 0                # logical messages acknowledged
    drops_request: int = 0
    drops_reply: int = 0
    duplicates: int = 0
    delays: int = 0
    torn_writes: int = 0
    resets: int = 0
    retries: int = 0                  # attempts beyond the first
    stale_replies: int = 0            # non-matching replies skipped


class ChaosConnection:
    """A client connection with a fault injector between ``call`` and the
    wire.  Request/reply matching is by the ``cs`` (client_seq) echo: a
    duplicated frame produces two replies, and the read loop returns the
    first reply matching the in-flight ``cs``, discarding strays — which
    is why duplication is safe end-to-end."""

    def __init__(self, transport, plan: FaultPlan,
                 stats: Optional[ChaosStats] = None):
        self._transport = transport
        self.plan = plan
        self.stats = stats if stats is not None else ChaosStats()
        self._conn = None

    # -- inner-connection plumbing -------------------------------------------

    def _ensure(self):
        if self._conn is None:
            self._conn = self._transport.connect()
        return self._conn

    def _teardown(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except Exception:
                pass
            self._conn = None

    # -- the retry loop -------------------------------------------------------

    def call(self, msg: dict) -> dict:
        plan = self.plan
        host = int(msg.get("host_id", 0))
        cs = int(msg.get("cs", 0))
        seq = msg.get("intake_seq")
        last_err: Optional[BaseException] = None
        for attempt in range(plan.max_attempts):
            d = plan.draws(host, cs, attempt)
            if attempt:
                self.stats.retries += 1
                time.sleep(plan.backoff_s(attempt, d["jitter"]))
            if d["reset"]:
                self.stats.resets += 1
                self._teardown()
                continue
            if d["drop_request"]:
                self.stats.drops_request += 1
                continue
            try:
                conn = self._ensure()
                data = frame(encode_message(msg, conn.codec))
                if d["delay"]:
                    # holding THIS send while other connections proceed is
                    # exactly an arrival reorder at the server's intake
                    self.stats.delays += 1
                    time.sleep(plan.delay_ms * d["delay_frac"] / 1000.0)
                if d["torn_write"]:
                    # a partial frame desyncs the stream: write a strict
                    # prefix, then tear the connection down (the server's
                    # decoder is left holding an incomplete frame, which
                    # the disconnect discards)
                    self.stats.torn_writes += 1
                    keep = max(1, int(len(data) * 0.9 * d["tear_frac"]))
                    conn.send_bytes(data[:keep])
                    self._teardown()
                    continue
                copies = 2 if d["duplicate"] else 1
                if d["duplicate"]:
                    self.stats.duplicates += 1
                conn.send_bytes(data * copies)
                self.stats.sent += copies
                rep = self._read_matching(
                    conn, host, cs if "cs" in msg else None)
                if d["drop_reply"]:
                    # the server handled it; the client never hears back.
                    # The retry re-sends the same cs — idempotency's job.
                    self.stats.drops_reply += 1
                    continue
                self.stats.delivered += 1
                return rep
            except (ConnectionError, OSError, ProtocolError) as e:
                last_err = e
                self._teardown()
                continue
        raise ProtocolError(
            f"chaos retries exhausted for host={host} cs={cs} "
            f"(intake_seq={seq}, last_err={last_err})")

    def _read_matching(self, conn, host: int, cs: Optional[int]) -> dict:
        """Read replies until one matches the in-flight ``(host_id, cs)``
        echo (strays are duplicate acks of earlier frames — skip them).
        cs alone would be ambiguous: it is a PER-HOST counter, and one
        connection can multiplex several hosts.  Messages without a cs
        take the first reply, classic request/reply."""
        while True:
            rep = conn.read_reply()
            if cs is None or (rep.get("cs") == cs
                              and rep.get("host_id") == host):
                return rep
            self.stats.stale_replies += 1

    def close(self) -> None:
        self._teardown()


class ChaosTransport:
    """Transport decorator: the inner transport (loopback or TCP) carries
    the bytes; every connection handed out is chaos-wrapped under one
    shared ``FaultPlan`` + stats."""

    name = "chaos"

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.stats = ChaosStats()

    def start(self, handler) -> "ChaosTransport":
        self.inner.start(handler)
        return self

    def connect(self) -> ChaosConnection:
        return ChaosConnection(self.inner, self.plan, self.stats)

    def stop(self) -> None:
        self.inner.stop()
