"""Host registry: per-host reliability, latency and churn (DESIGN.md §9).

The FGDO/BOINC server model assumes nothing about a volunteer host except
what it has OBSERVED about it: how much work it took, how much it returned,
how fast, and when it was last heard from.  ``HostRegistry`` is that
observation store, shared by every layer that schedules work —

  * ``core/fgdo.py`` reads the reliable-host gates (``returns_work`` /
    ``reliable``) when handing out latency-critical validation replicas;
  * the work server (``repro/server/server.py``) records every protocol
    message here (issue/result/heartbeat/no-work backoff) and serializes
    the registry into its crash checkpoints;
  * the simulated client pool rebuilds its event schedule from
    ``next_contact_at`` after a crash restore.

Churn model: a host is ``alive`` while it keeps contacting the server,
decays to ``suspect`` after ``suspect_after`` seconds of silence and to
``dead`` after ``dead_after`` (swept lazily from message timestamps, so the
transitions are deterministic in virtual time).  Any contact revives it —
volunteer hosts come and go, and the pull model means a returning host
simply starts requesting work again.

Reliability gates (semantics carried over from the pre-registry
``FgdoAnmServer``, pinned by ``tests/test_fgdo.py``):

  * **return-rate gate** (``returns_work``): a host that takes work and
    vanishes records no turnaround at all, so turnaround alone is
    failure-blind — judge hosts by what they RETURN.  Cold-start grace:
    the gate only engages after ``min_issued_for_rate`` workunits have
    been issued, so a brand-new host with 1 issued / 0 returned (a 0%
    return rate it never had a chance to improve) is not excluded before
    its first result can possibly arrive;
  * **latency gate** (``reliable``): below-median EWMA turnaround among
    observed hosts, with benefit of the doubt while fewer than
    ``min_latency_samples`` hosts have recorded one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

ALIVE, SUSPECT, DEAD = "alive", "suspect", "dead"


@dataclasses.dataclass
class HostRecord:
    """Everything the server knows about one host — all of it learned from
    protocol messages, all of it serializable."""
    host_id: int
    registered_at: float = 0.0
    last_seen: float = 0.0
    issued: int = 0                   # workunits handed to this host
    returned: int = 0                 # results it actually reported
    stale: int = 0                    # returns that arrived phase-stale
    ewma_latency: Optional[float] = None
    state: str = ALIVE
    nowork_streak: int = 0            # consecutive empty-handed requests
    # when this host will next contact us (set on every reply; None while
    # it holds a lease — its next contact derives from the lease).  The
    # crash-restored client world is rebuilt from exactly this field.
    next_contact_at: Optional[float] = 0.0

    @property
    def valid_rate(self) -> float:
        """Fraction of returned results that were still usable (not
        phase-stale) — observability, not a scheduling gate."""
        return (self.returned - self.stale) / self.returned \
            if self.returned else 1.0


class HostRegistry:
    def __init__(self, min_return_rate: float = 0.5,
                 min_issued_for_rate: int = 4, latency_alpha: float = 0.3,
                 min_latency_samples: int = 4, suspect_after: float = 300.0,
                 dead_after: float = 1200.0):
        self.min_return_rate = min_return_rate
        self.min_issued_for_rate = min_issued_for_rate
        self.latency_alpha = latency_alpha
        self.min_latency_samples = min_latency_samples
        self.suspect_after = suspect_after
        self.dead_after = dead_after
        self.hosts: Dict[int, HostRecord] = {}

    # -- bookkeeping ---------------------------------------------------------

    def record(self, host_id: int) -> HostRecord:
        rec = self.hosts.get(host_id)
        if rec is None:
            rec = self.hosts[host_id] = HostRecord(host_id)
        return rec

    def register(self, host_id: int, now: float) -> HostRecord:
        """Idempotent: re-registering (a client reconnecting after a server
        crash) revives and touches the record, never resets its history."""
        rec = self.record(host_id)
        if rec.registered_at == 0.0 and rec.last_seen == 0.0:
            rec.registered_at = now
        return self.touch(host_id, now)

    def touch(self, host_id: int, now: float) -> HostRecord:
        """Any contact proves liveness and revives a suspect/dead host."""
        rec = self.record(host_id)
        rec.last_seen = max(rec.last_seen, now)
        rec.state = ALIVE
        return rec

    def on_issue(self, host_id: int, now: float) -> None:
        rec = self.touch(host_id, now)
        rec.issued += 1
        rec.nowork_streak = 0
        rec.next_contact_at = None    # next contact derives from the lease

    def on_result(self, host_id: int, now: float, turnaround: float,
                  stale: bool = False) -> None:
        rec = self.touch(host_id, now)
        rec.returned += 1
        if stale:
            rec.stale += 1
        ta = max(turnaround, 1e-9)
        a = self.latency_alpha
        rec.ewma_latency = ta if rec.ewma_latency is None \
            else (1 - a) * rec.ewma_latency + a * ta
        rec.nowork_streak = 0
        rec.next_contact_at = now     # a client re-requests immediately

    def on_no_work(self, host_id: int, now: float, retry_after: float) -> None:
        rec = self.touch(host_id, now)
        rec.nowork_streak += 1
        rec.next_contact_at = now + retry_after

    def sweep(self, now: float) -> None:
        """Lazy churn transitions from message-time silence.  Deterministic:
        driven only by the virtual timestamps messages carry."""
        for rec in self.hosts.values():
            silent = now - rec.last_seen
            if silent > self.dead_after:
                rec.state = DEAD
            elif silent > self.suspect_after:
                rec.state = SUSPECT

    # -- scheduling gates ----------------------------------------------------

    def returns_work(self, host_id: int) -> bool:
        """Return-rate gate with the cold-start minimum-sample grace."""
        rec = self.hosts.get(host_id)
        if rec is None:
            return True
        return not (rec.issued >= self.min_issued_for_rate and
                    rec.returned < self.min_return_rate * rec.issued)

    def reliable(self, host_id: int) -> bool:
        """Latency-critical work gate: returns work AND below-median EWMA
        turnaround (unknown hosts get the benefit of the doubt while the
        sample is small)."""
        if not self.returns_work(host_id):
            return False
        rec = self.hosts.get(host_id)
        t = None if rec is None else rec.ewma_latency
        known = [r.ewma_latency for r in self.hosts.values()
                 if r.ewma_latency is not None]
        if t is None or len(known) < self.min_latency_samples:
            return True
        return t <= float(np.median(known))

    # -- observability -------------------------------------------------------

    def counts(self) -> Dict[str, int]:
        out = {ALIVE: 0, SUSPECT: 0, DEAD: 0}
        for rec in self.hosts.values():
            out[rec.state] += 1
        return out

    def summary(self) -> dict:
        recs = self.hosts.values()
        lat = [r.ewma_latency for r in recs if r.ewma_latency is not None]
        return {
            "hosts": len(self.hosts), "states": self.counts(),
            "issued": sum(r.issued for r in recs),
            "returned": sum(r.returned for r in recs),
            "stale_returns": sum(r.stale for r in recs),
            "median_latency": float(np.median(lat)) if lat else None,
            "excluded_by_return_rate": sum(
                0 if self.returns_work(r.host_id) else 1 for r in recs),
        }

    # -- serialization -------------------------------------------------------

    def state_dict(self) -> dict:
        # vars() copy, not dataclasses.asdict: the recursive walk is ~50x
        # slower and snapshots serialize thousands of host records
        return {"hosts": {str(h): dict(vars(rec))
                          for h, rec in self.hosts.items()}}

    def load_state(self, d: dict) -> None:
        self.hosts = {}
        for h, rec in d["hosts"].items():
            rec = dict(rec)
            rec["host_id"] = int(rec["host_id"])
            self.hosts[int(h)] = HostRecord(**rec)
